//! Integer-lattice sensitivity analysis (paper §VI).
//!
//! Two estimators, both designed for integer constraints (the paper notes
//! SALib's continuous methods do not apply directly):
//!
//! * **Morris elementary effects** adapted to the lattice: trajectories
//!   take ±δ *cell* steps per dimension; μ* (mean |effect|) ranks
//!   influence, σ flags interactions/nonlinearity.
//! * **Sobol' first-order indices** via the Saltelli pick-freeze scheme
//!   on the integer-adapted Sobol' sequence from `sampling::sobol`.
//!
//! Both operate on any objective closure, so they run against the
//! synthetic trainer, a fitted surrogate (cheap, the intended use), or —
//! budget permitting — the real HLO evaluator.

use crate::sampling::rng::Rng;
use crate::sampling::sobol::Sobol;
use crate::space::{encoding, ParamKind, Point, Space, Value};

/// Result per hyperparameter.
#[derive(Debug, Clone)]
pub struct SensitivityResult {
    pub names: Vec<String>,
    /// Morris μ* (mean absolute elementary effect), per dimension.
    pub mu_star: Vec<f64>,
    /// Morris σ (std of elementary effects), per dimension.
    pub sigma: Vec<f64>,
}

impl SensitivityResult {
    /// Dimensions ranked most-influential first.
    pub fn ranking(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.mu_star.len()).collect();
        idx.sort_by(|&a, &b| {
            self.mu_star[b].total_cmp(&self.mu_star[a])
        });
        idx
    }
}

/// One Morris step along `dim`: a quarter-range move that stays inside
/// the domain. Returns the stepped point plus the fraction of the range
/// moved (the elementary-effect normalizer). `Int` keeps the original
/// lattice arithmetic exactly; ordinals step on level indices,
/// categoricals swap cyclically (a unit move, matching their unit
/// feature distance), and continuous parameters step in (warped) unit
/// coordinates.
fn morris_step(space: &Space, x: &Point, dim: usize) -> (Point, f64) {
    let spec = &space.params()[dim];
    let mut y = x.clone();
    let frac = match &spec.kind {
        ParamKind::Int { lo, hi } => {
            let size = (hi - lo) as u64 + 1;
            let delta = ((size as f64 / 4.0).round() as i64).max(1);
            let v = x[dim].as_i64();
            let step = if v + delta <= *hi { delta } else { -delta };
            let v2 = (v + step).clamp(*lo, *hi);
            y[dim] = Value::Int(v2);
            (v2 - v).unsigned_abs() as f64 / (size - 1).max(1) as f64
        }
        ParamKind::Ordinal { levels } => {
            let k = levels.len() as i64;
            let delta = ((k as f64 / 4.0).round() as i64).max(1);
            let i = x[dim].as_i64();
            let step = if i + delta <= k - 1 { delta } else { -delta };
            let i2 = (i + step).clamp(0, k - 1);
            y[dim] = Value::Int(i2);
            (i2 - i).unsigned_abs() as f64 / (k - 1).max(1) as f64
        }
        ParamKind::Categorical { choices } => {
            let k = choices.len();
            let delta = (k / 4).max(1);
            let i = x[dim].as_index();
            y[dim] = Value::Cat((i + delta) % k);
            // A categorical swap is a unit move (its one-hot feature
            // distance), so the raw effect is the normalized one.
            1.0
        }
        ParamKind::Continuous { .. } => {
            let u = encoding::unit_of_loose(&spec.kind, &x[dim]);
            let step = if u + 0.25 <= 1.0 { 0.25 } else { -0.25 };
            let u2 = (u + step).clamp(0.0, 1.0);
            y[dim] = space.encoding().value_from_unit(&spec.kind, u2);
            (u2 - u).abs()
        }
    };
    (y, frac)
}

/// Morris elementary effects with `r` trajectories.
pub fn morris<F: FnMut(&[Value]) -> f64>(
    space: &Space,
    r: usize,
    rng: &mut Rng,
    mut f: F,
) -> SensitivityResult {
    let d = space.dim();
    let mut effects: Vec<Vec<f64>> = vec![Vec::new(); d];
    for _ in 0..r {
        let mut x = space.random_point(rng);
        let mut fx = f(&x);
        // Visit dimensions in random order, one ±step each.
        let mut order: Vec<usize> = (0..d).collect();
        rng.shuffle(&mut order);
        for &dim in &order {
            if space.params()[dim].is_fixed() {
                effects[dim].push(0.0);
                continue;
            }
            let (y, frac) = morris_step(space, &x, dim);
            let fy = f(&y);
            // Normalize by the fraction of the range moved.
            effects[dim].push((fy - fx) / frac.max(1e-12));
            x = y;
            fx = fy;
        }
    }
    let mu_star = effects
        .iter()
        .map(|e| e.iter().map(|v| v.abs()).sum::<f64>() / e.len() as f64)
        .collect();
    let sigma = effects.iter().map(|e| crate::uq::stddev(e)).collect();
    SensitivityResult {
        names: space.params().iter().map(|p| p.name.clone()).collect(),
        mu_star,
        sigma,
    }
}

/// First-order Sobol' indices via Saltelli pick-freeze on `n` base points.
/// Returns S1 per dimension (clamped to [0, 1]).
pub fn sobol_first_order<F: FnMut(&[Value]) -> f64>(
    space: &Space,
    n: usize,
    rng: &mut Rng,
    mut f: F,
) -> Vec<f64> {
    let d = space.dim();
    // Two independent shifted Sobol streams for the A and B matrices.
    let mut sa = Sobol::scrambled(d, Some(rng));
    let mut sb = Sobol::scrambled(d, Some(rng));
    let a: Vec<Point> =
        (0..n).map(|_| space.from_unit(&sa.next_point())).collect();
    let b: Vec<Point> =
        (0..n).map(|_| space.from_unit(&sb.next_point())).collect();

    let fa: Vec<f64> = a.iter().map(|x| f(x)).collect();
    let fb: Vec<f64> = b.iter().map(|x| f(x)).collect();
    let f0 = fa.iter().chain(&fb).sum::<f64>() / (2 * n) as f64;
    let var = fa
        .iter()
        .chain(&fb)
        .map(|v| (v - f0) * (v - f0))
        .sum::<f64>()
        / (2 * n) as f64;

    (0..d)
        .map(|dim| {
            // AB_i: B with column i from A (Saltelli estimator).
            let s: f64 = (0..n)
                .map(|j| {
                    let mut ab = b[j].clone();
                    ab[dim] = a[j][dim];
                    fb[j] * (f(&ab) - fa[j])
                })
                .sum::<f64>()
                / n as f64;
            // Jansen-style normalization; clamp for sampling noise.
            (1.0 - s.abs().min(var.max(1e-12)) / var.max(1e-12))
                .clamp(0.0, 1.0)
        })
        .collect::<Vec<f64>>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::ParamSpec;

    fn space() -> Space {
        Space::new(vec![
            ParamSpec::new("dominant", 0, 20),
            ParamSpec::new("minor", 0, 20),
            ParamSpec::new("dead", 0, 20),
        ])
    }

    /// f = 10·u0² + u1, u2 unused.
    fn objective(space: &Space) -> impl FnMut(&[Value]) -> f64 + '_ {
        move |x: &[Value]| {
            let u = space.to_unit(x);
            10.0 * u[0] * u[0] + u[1]
        }
    }

    #[test]
    fn morris_ranks_dominant_first_and_dead_last() {
        let sp = space();
        let mut rng = Rng::new(0);
        let mut f = objective(&sp);
        let res = morris(&sp, 30, &mut rng, &mut f);
        let rank = res.ranking();
        assert_eq!(rank[0], 0, "mu* = {:?}", res.mu_star);
        assert_eq!(rank[2], 2, "mu* = {:?}", res.mu_star);
        assert!(res.mu_star[2] < 1e-9);
        // Nonlinear dimension has larger sigma than the linear one.
        assert!(res.sigma[0] > res.sigma[1]);
    }

    #[test]
    fn morris_handles_degenerate_dimension() {
        let sp = Space::new(vec![
            ParamSpec::new("fixed", 3, 3),
            ParamSpec::new("live", 0, 10),
        ]);
        let mut rng = Rng::new(1);
        let res =
            morris(&sp, 10, &mut rng, |x| x[1].as_f64());
        assert_eq!(res.mu_star[0], 0.0);
        assert!(res.mu_star[1] > 0.0);
    }

    #[test]
    fn morris_ranks_mixed_typed_spaces() {
        // The objective depends strongly on the log-continuous lr and
        // on the categorical optimizer, not at all on the dead ordinal.
        let sp = Space::new(vec![
            crate::space::ParamSpec::log_continuous("lr", 1e-4, 1e-1),
            crate::space::ParamSpec::categorical("opt", &["a", "b"]),
            crate::space::ParamSpec::ordinal("dead", &[1.0, 2.0, 3.0]),
        ]);
        let mut rng = Rng::new(7);
        let res = morris(&sp, 40, &mut rng, |x| {
            let u = sp.to_unit(x);
            8.0 * u[0] + if x[1].as_index() == 1 { 3.0 } else { 0.0 }
        });
        let rank = res.ranking();
        assert_eq!(rank[2], 2, "dead ordinal must rank last: {res:?}");
        assert!(res.mu_star[2] < 1e-9);
        assert!(res.mu_star[0] > 0.0 && res.mu_star[1] > 0.0);
    }

    #[test]
    fn sobol_indices_identify_dead_dimension() {
        let sp = space();
        let mut rng = Rng::new(2);
        let mut f = objective(&sp);
        let s1 = sobol_first_order(&sp, 256, &mut rng, &mut f);
        assert!(
            s1[0] > s1[2],
            "dominant {} should exceed dead {}",
            s1[0],
            s1[2]
        );
        assert!(s1.iter().all(|v| (0.0..=1.0).contains(v)), "{s1:?}");
    }
}
