//! History persistence: save/resume optimization state — the coordinator
//! "state management" piece. A long HPO campaign (days of training on the
//! paper's testbed) must survive restarts; the history round-trips
//! through the JSON substrate and `optimizer::run_sync`-compatible
//! structures.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::eval::EvalSummary;
use crate::optimizer::{EvalRecord, History};
use crate::space::Value;
use crate::uq::LossInterval;
use crate::util::json::{parse, write, Json};

/// Current history-file schema version. Version 1 (the pre-typed-space
/// format, where every θ coordinate was a plain integer) is still
/// accepted on read: plain numbers parse as [`Value::Int`], which is
/// exactly what they meant.
pub const HISTORY_VERSION: i64 = 2;

/// Encode an f64, representing non-finite values (diverged trainings
/// produce inf/NaN losses) as strings — `Json::Num` would serialize them
/// as invalid JSON and make the file unreadable.
fn num(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else if v.is_nan() {
        Json::Str("nan".into())
    } else if v > 0.0 {
        Json::Str("inf".into())
    } else {
        Json::Str("-inf".into())
    }
}

fn num_back(v: &Json) -> Option<f64> {
    match v {
        Json::Num(n) => Some(*n),
        Json::Str(s) => match s.as_str() {
            "inf" => Some(f64::INFINITY),
            "-inf" => Some(f64::NEG_INFINITY),
            "nan" => Some(f64::NAN),
            _ => None,
        },
        _ => None,
    }
}

/// Serialize one typed θ coordinate (schema v2, shared by history files
/// and `exec::checkpoint`):
///
/// * `Value::Int(v)` → a plain JSON number — byte-identical to the v1
///   schema, which is what makes v1 files parse losslessly. Magnitudes
///   above 2⁵³ (exactly representable in `f64` no longer) fall back to
///   `{"i": "<decimal string>"}`, the same precision rule the u64
///   seed/RNG fields follow.
/// * `Value::Float(v)` → `{"f": v}` (non-finite values as strings, like
///   every other float field).
/// * `Value::Cat(i)` → `{"c": i}`.
pub fn value_to_json(v: &Value) -> Json {
    match v {
        Value::Int(v) if v.unsigned_abs() <= (1u64 << 53) => {
            Json::Num(*v as f64)
        }
        Value::Int(v) => {
            let mut o = BTreeMap::new();
            o.insert("i".into(), Json::Str(v.to_string()));
            Json::Obj(o)
        }
        Value::Float(v) => {
            let mut o = BTreeMap::new();
            o.insert("f".into(), num(*v));
            Json::Obj(o)
        }
        Value::Cat(i) => {
            let mut o = BTreeMap::new();
            o.insert("c".into(), Json::Num(*i as f64));
            Json::Obj(o)
        }
    }
}

/// Parse one typed θ coordinate; plain numbers (the v1 schema) read as
/// [`Value::Int`].
pub fn value_from_json(v: &Json) -> Result<Value> {
    match v {
        // A plain number is the v1 integer encoding; a fractional value
        // here is a corrupt file, not an int to round (floats always
        // travel as {"f": v}), and magnitudes beyond 2⁵³ cannot have
        // round-tripped exactly through the f64 substrate (the writer
        // uses the {"i": "decimal"} escape for those).
        Json::Num(n) if n.fract() == 0.0 && n.abs() <= (1u64 << 53) as f64 =>
        {
            Ok(Value::Int(*n as i64))
        }
        Json::Num(n) => Err(anyhow!(
            "bad bare coordinate {n} (floats use {{\"f\": v}}, wide ints \
             {{\"i\": \"decimal\"}})"
        )),
        Json::Obj(o) => {
            if let Some(f) = o.get("f") {
                return num_back(f)
                    .map(Value::Float)
                    .ok_or_else(|| anyhow!("bad float coordinate"));
            }
            if let Some(c) = o.get("c") {
                return c
                    .as_i64()
                    .map(|i| Value::Cat(i as usize))
                    .ok_or_else(|| anyhow!("bad categorical coordinate"));
            }
            if let Some(i) = o.get("i") {
                let s = i
                    .as_str()
                    .ok_or_else(|| anyhow!("bad wide-int coordinate"))?;
                return s
                    .parse::<i64>()
                    .map(Value::Int)
                    .map_err(|e| anyhow!("bad wide-int {s:?}: {e}"));
            }
            Err(anyhow!("unknown typed coordinate {o:?}"))
        }
        other => Err(anyhow!("bad theta coordinate {other:?}")),
    }
}

/// Serialize one evaluation record to a JSON object (shared with the
/// `exec::checkpoint` format, which embeds records verbatim).
pub fn record_to_json(r: &EvalRecord) -> Json {
    let mut o = BTreeMap::new();
    o.insert("id".into(), num(r.id as f64));
    o.insert(
        "theta".into(),
        Json::Arr(r.theta.iter().map(value_to_json).collect()),
    );
    o.insert("center".into(), num(r.summary.interval.center));
    o.insert("radius".into(), num(r.summary.interval.radius));
    o.insert("trained_mean".into(), num(r.summary.trained_mean));
    o.insert("trained_std".into(), num(r.summary.trained_std));
    o.insert("v_model_g".into(), num(r.summary.v_model_g));
    o.insert(
        "cost_us".into(),
        num(r.summary.total_cost.as_micros() as f64),
    );
    o.insert("n_params".into(), num(r.n_params as f64));
    o.insert(
        "provenance".into(),
        Json::Arr(r.provenance.iter().map(|v| num(*v as f64)).collect()),
    );
    Json::Obj(o)
}

/// Parse one evaluation record from its [`record_to_json`] form.
pub fn record_from_json(v: &Json) -> Result<EvalRecord> {
    let theta = v
        .get("theta")
        .as_arr()
        .context("theta")?
        .iter()
        .map(|x| value_from_json(x).context("theta item"))
        .collect::<Result<Vec<Value>>>()?;
    let provenance = v
        .get("provenance")
        .as_arr()
        .context("provenance")?
        .iter()
        .map(|x| x.as_i64().map(|i| i as usize).context("prov item"))
        .collect::<Result<Vec<usize>>>()?;
    let g = |k: &str| -> Result<f64> {
        num_back(v.get(k)).ok_or_else(|| anyhow!("missing {k}"))
    };
    Ok(EvalRecord {
        id: g("id")? as usize,
        theta,
        summary: EvalSummary {
            interval: LossInterval {
                center: g("center")?,
                radius: g("radius")?,
            },
            trained_mean: g("trained_mean")?,
            trained_std: g("trained_std")?,
            v_model_g: g("v_model_g")?,
            total_cost: Duration::from_micros(g("cost_us")? as u64),
        },
        n_params: g("n_params")? as u64,
        provenance,
    })
}

/// Serialize a history to JSON text (schema [`HISTORY_VERSION`]).
pub fn history_to_json(h: &History) -> String {
    let mut root = BTreeMap::new();
    root.insert("version".into(), num(HISTORY_VERSION as f64));
    root.insert(
        "records".into(),
        Json::Arr(h.records.iter().map(record_to_json).collect()),
    );
    write(&Json::Obj(root))
}

/// Parse a history back. Accepts schema v1 (all-integer θ) and v2
/// (typed θ); v1 coordinates migrate losslessly to `Value::Int`.
pub fn history_from_json(text: &str) -> Result<History> {
    let root =
        parse(text).map_err(|e| anyhow!("history parse: {e}"))?;
    let version = root.get("version").as_i64();
    if !matches!(version, Some(1) | Some(2)) {
        anyhow::bail!("unsupported history version {version:?}");
    }
    let records = root
        .get("records")
        .as_arr()
        .context("records")?
        .iter()
        .map(record_from_json)
        .collect::<Result<Vec<_>>>()?;
    Ok(History { records })
}

pub fn save<P: AsRef<Path>>(h: &History, path: P) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path.as_ref(), history_to_json(h))
        .with_context(|| format!("writing {}", path.as_ref().display()))
}

pub fn load<P: AsRef<Path>>(path: P) -> Result<History> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    history_from_json(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::synthetic::SyntheticEvaluator;
    use crate::optimizer::{run_sync, HpoConfig};
    use crate::space::{ParamSpec, Space};

    fn sample_history() -> History {
        let space = Space::new(vec![
            ParamSpec::new("a", 0, 10),
            ParamSpec::new("b", 0, 10),
        ]);
        let ev = SyntheticEvaluator::new(space, 1);
        run_sync(
            &ev,
            &HpoConfig {
                max_evaluations: 12,
                n_init: 4,
                n_trials: 2,
                seed: 3,
                ..Default::default()
            },
        )
    }

    #[test]
    fn roundtrip_preserves_everything_relevant() {
        let h = sample_history();
        let h2 = history_from_json(&history_to_json(&h)).unwrap();
        assert_eq!(h.len(), h2.len());
        for (a, b) in h.records.iter().zip(&h2.records) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.theta, b.theta);
            assert_eq!(a.provenance, b.provenance);
            assert_eq!(a.n_params, b.n_params);
            assert!(
                (a.summary.interval.center - b.summary.interval.center)
                    .abs()
                    < 1e-9
            );
            assert!(
                (a.objective(0.7) - b.objective(0.7)).abs() < 1e-9
            );
        }
        // Derived queries agree.
        assert_eq!(h.best(0.0).unwrap().id, h2.best(0.0).unwrap().id);
    }

    #[test]
    fn save_and_load_file() {
        let h = sample_history();
        let p = std::env::temp_dir().join("hyppo_hist_test.json");
        save(&h, &p).unwrap();
        let h2 = load(&p).unwrap();
        assert_eq!(h.len(), h2.len());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_garbage_and_wrong_version() {
        assert!(history_from_json("not json").is_err());
        assert!(history_from_json("{\"version\":9,\"records\":[]}")
            .is_err());
        // A fractional bare θ coordinate is corruption, not an int.
        assert!(value_from_json(&Json::Num(0.001)).is_err());
        assert_eq!(
            value_from_json(&Json::Num(3.0)).unwrap(),
            Value::Int(3)
        );
    }

    #[test]
    fn typed_theta_roundtrips_and_v1_files_migrate() {
        let mut h = sample_history();
        // Mix every kind into one θ, including an Int beyond the f64
        // mantissa (exercises the decimal-string wide-int fallback).
        h.records[0].theta = vec![
            Value::Int(-3),
            Value::Float(1.25e-3),
            Value::Cat(2),
            Value::Int(i64::MAX - 7),
        ];
        let h2 = history_from_json(&history_to_json(&h)).unwrap();
        assert_eq!(h2.records[0].theta, h.records[0].theta);

        // A v1 file: version 1, θ as plain integers. Must parse, with
        // every coordinate landing as Value::Int.
        let v1 = history_to_json(&sample_history())
            .replace("\"version\":2", "\"version\":1");
        let hv1 = history_from_json(&v1).unwrap();
        assert_eq!(hv1.len(), sample_history().len());
        for (a, b) in hv1.records.iter().zip(&sample_history().records) {
            assert_eq!(a.theta, b.theta);
            assert!(a
                .theta
                .iter()
                .all(|v| matches!(v, Value::Int(_))));
        }
    }

    #[test]
    fn non_finite_losses_roundtrip() {
        // Diverged trainings produce inf/NaN losses; the file must stay
        // valid JSON and the values must come back.
        let mut h = sample_history();
        h.records[0].summary.interval.center = f64::INFINITY;
        h.records[1].summary.trained_std = f64::NAN;
        h.records[2].summary.v_model_g = f64::NEG_INFINITY;
        let text = history_to_json(&h);
        let h2 = history_from_json(&text).unwrap();
        assert_eq!(
            h2.records[0].summary.interval.center,
            f64::INFINITY
        );
        assert!(h2.records[1].summary.trained_std.is_nan());
        assert_eq!(
            h2.records[2].summary.v_model_g,
            f64::NEG_INFINITY
        );
    }
}
