//! Post-hoc analysis tools: hyperparameter sensitivity (the paper's §VI
//! roadmap — "if we could identify the subset of hyperparameters that
//! most impact the model's performance, we could significantly reduce
//! the number of hyperparameter sets that need to be tried") and history
//! persistence for resumable runs.

pub mod persistence;
pub mod sensitivity;
