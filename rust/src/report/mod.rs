//! Report emitters: turn optimization histories into the CSV series /
//! printed tables that EXPERIMENTS.md records per figure and table.

use std::io::Result;
use std::path::Path;

use crate::exec::SweepCell;
use crate::optimizer::History;
use crate::util::csv::CsvWriter;

/// Fig. 2 / Fig. 9-style per-evaluation dump: loss center, CI radius,
/// trained-trial std, MAD inputs, parameter count.
pub fn write_history_csv<P: AsRef<Path>>(
    history: &History,
    gamma: f64,
    path: P,
) -> Result<()> {
    let mut w = CsvWriter::create(
        path,
        &[
            "id", "theta", "objective", "center", "radius",
            "trained_mean", "trained_std", "n_params", "provenance_len",
            "cost_ms",
        ],
    )?;
    for r in &history.records {
        let theta = r
            .theta
            .iter()
            .map(|v| v.to_string())
            .collect::<Vec<_>>()
            .join(" ");
        w.row(&[
            r.id.to_string(),
            theta,
            format!("{:.6e}", r.objective(gamma)),
            format!("{:.6e}", r.summary.interval.center),
            format!("{:.6e}", r.summary.interval.radius),
            format!("{:.6e}", r.summary.trained_mean),
            format!("{:.6e}", r.summary.trained_std),
            r.n_params.to_string(),
            r.provenance.len().to_string(),
            format!("{:.3}", r.summary.total_cost.as_secs_f64() * 1e3),
        ])?;
    }
    w.finish()
}

/// Fig. 3 / Fig. 4-style convergence series: best objective after each
/// evaluation, one column per labeled method.
pub fn write_convergence_csv<P: AsRef<Path>>(
    series: &[(&str, Vec<f64>)],
    path: P,
) -> Result<()> {
    let mut header = vec!["eval".to_string()];
    header.extend(series.iter().map(|(n, _)| n.to_string()));
    let header_refs: Vec<&str> =
        header.iter().map(String::as_str).collect();
    let mut w = CsvWriter::create(path, &header_refs)?;
    let rows = series.iter().map(|(_, v)| v.len()).max().unwrap_or(0);
    for i in 0..rows {
        let mut row = vec![(i + 1).to_string()];
        for (_, v) in series {
            row.push(
                v.get(i)
                    .or(v.last())
                    .map(|x| format!("{x:.6e}"))
                    .unwrap_or_default(),
            );
        }
        w.row(&row)?;
    }
    w.finish()
}

/// Per-cell dump of a `hyppo sweep` grid: seed, topology, best result,
/// wall time, and the executor's refit counters.
pub fn write_sweep_csv<P: AsRef<Path>>(
    cells: &[SweepCell],
    path: P,
) -> Result<()> {
    let mut w = CsvWriter::create(
        path,
        &[
            "seed", "steps", "tasks", "evaluations", "best_objective",
            "best_theta", "wall_s", "incremental_refits", "full_refits",
        ],
    )?;
    for c in cells {
        let theta = c
            .best_theta
            .iter()
            .map(|v| v.to_string())
            .collect::<Vec<_>>()
            .join(" ");
        w.row(&[
            c.seed.to_string(),
            c.topology.steps.to_string(),
            c.topology.tasks_per_step.to_string(),
            c.evaluations.to_string(),
            format!("{:.6e}", c.best_objective),
            theta,
            format!("{:.3}", c.wall.as_secs_f64()),
            c.stats.refits.incremental.to_string(),
            c.stats.refits.full.to_string(),
        ])?;
    }
    w.finish()
}

/// Simple aligned table printer for terminal summaries (Table I etc.).
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> =
        header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!(
        "{}",
        fmt_row(
            &header.iter().map(|s| s.to_string()).collect::<Vec<_>>()
        )
    );
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::EvalSummary;
    use crate::optimizer::EvalRecord;
    use crate::uq::LossInterval;
    use std::time::Duration;

    fn history() -> History {
        let mut h = History::default();
        for i in 0..3 {
            h.records.push(EvalRecord {
                id: i,
                theta: crate::space::ints(&[i as i64, 2 * i as i64]),
                summary: EvalSummary {
                    interval: LossInterval {
                        center: 1.0 / (i + 1) as f64,
                        radius: 0.1,
                    },
                    trained_mean: 1.0,
                    trained_std: 0.2,
                    v_model_g: 0.0,
                    total_cost: Duration::from_millis(5),
                },
                n_params: 100 * (i as u64 + 1),
                provenance: (0..i).collect(),
            });
        }
        h
    }

    #[test]
    fn history_csv_written() {
        let p = std::env::temp_dir().join("hyppo_report_h.csv");
        write_history_csv(&history(), 0.0, &p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 4);
        assert!(text.lines().next().unwrap().starts_with("id,theta"));
        assert!(text.contains("0 0"));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn convergence_csv_pads_short_series() {
        let p = std::env::temp_dir().join("hyppo_report_c.csv");
        write_convergence_csv(
            &[
                ("a", vec![3.0, 2.0, 1.0]),
                ("b", vec![5.0]),
            ],
            &p,
        )
        .unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0], "eval,a,b");
        // b padded with its last value.
        assert!(lines[3].contains("1.0"));
        assert!(lines[3].contains("5.0"));
        std::fs::remove_file(&p).ok();
    }
}
