//! PJRT execution engine: loads HLO-text artifacts, compiles them once on
//! the CPU PJRT client, and executes them from the Layer-3 hot path.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! serializes protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects, while the text parser reassigns ids (see DESIGN.md §3 and
//! /opt/xla-example/README.md).
//!
//! Thread-safety: the `xla` crate's wrappers are raw C++ pointers without
//! `Send`/`Sync` markers. `SharedEngine` serializes *all* access behind one
//! `Mutex` and is the only way the rest of the crate touches PJRT, which
//! makes the unsafe `Send` marker sound (objects are only ever used by the
//! lock holder; PJRT CPU itself is thread-safe).

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::runtime::registry::{ArtifactSpec, Manifest};

/// Single-threaded engine core.
pub struct Engine {
    client: PjRtClient,
    manifest: Manifest,
    cache: HashMap<(String, String), PjRtLoadedExecutable>,
    pub executions: u64,
    pub compilations: u64,
}

impl Engine {
    /// Create a CPU engine over an artifact directory.
    pub fn load<P: AsRef<Path>>(artifact_dir: P) -> Result<Engine> {
        let manifest = Manifest::load(&artifact_dir)?;
        let client = PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Engine {
            client,
            manifest,
            cache: HashMap::new(),
            executions: 0,
            compilations: 0,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn compile(&mut self, spec: &ArtifactSpec) -> Result<()> {
        let key = (spec.arch.clone(), spec.role.clone());
        if self.cache.contains_key(&key) {
            return Ok(());
        }
        let proto = HloModuleProto::from_text_file(&spec.path)
            .map_err(|e| {
                anyhow!("parsing {}: {e:?}", spec.path.display())
            })?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", spec.arch))?;
        self.compilations += 1;
        self.cache.insert(key, exe);
        Ok(())
    }

    /// Ensure (arch, role) is compiled; returns its spec.
    pub fn prepare(&mut self, arch: &str, role: &str) -> Result<ArtifactSpec> {
        let spec = self
            .manifest
            .find(arch, role)
            .with_context(|| format!("no artifact {arch}/{role}"))?
            .clone();
        self.compile(&spec)?;
        Ok(spec)
    }

    /// Execute (arch, role) on literal inputs; returns the unpacked output
    /// tuple (aot.py lowers everything with `return_tuple=True`).
    pub fn exec(
        &mut self,
        arch: &str,
        role: &str,
        inputs: &[Literal],
    ) -> Result<Vec<Literal>> {
        let spec = self.prepare(arch, role)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{arch}/{role}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        let exe = self
            .cache
            .get(&(arch.to_string(), role.to_string()))
            .expect("prepared above");
        let result = exe
            .execute::<Literal>(inputs)
            .map_err(|e| anyhow!("executing {arch}/{role}: {e:?}"))?;
        self.executions += 1;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))
    }
}

/// The process-wide, thread-shareable engine handle.
pub struct SharedEngine {
    inner: Mutex<Engine>,
}

// SAFETY: `Engine` holds raw PJRT pointers. They are moved between threads
// only under the exclusive Mutex above; PJRT's CPU client is internally
// thread-safe for the operations we perform. No references to the inner
// objects escape the lock.
unsafe impl Send for SharedEngine {}
unsafe impl Sync for SharedEngine {}

impl SharedEngine {
    pub fn load<P: AsRef<Path>>(artifact_dir: P) -> Result<Self> {
        Ok(SharedEngine { inner: Mutex::new(Engine::load(artifact_dir)?) })
    }

    /// Run a closure with exclusive engine access.
    pub fn with<R>(&self, f: impl FnOnce(&mut Engine) -> R) -> R {
        let mut guard = self.inner.lock().expect("engine mutex poisoned");
        f(&mut guard)
    }

    pub fn exec(
        &self,
        arch: &str,
        role: &str,
        inputs: &[Literal],
    ) -> Result<Vec<Literal>> {
        self.with(|e| e.exec(arch, role, inputs))
    }

    pub fn manifest_archs(&self, family: &str) -> Vec<String> {
        self.with(|e| e.manifest().archs(family))
    }
}

// ---------------------------------------------------------------------------
// Literal construction/extraction helpers.
// ---------------------------------------------------------------------------

/// f32 tensor literal of the given shape.
pub fn f32_tensor(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("shape {shape:?} wants {n} elements, got {}", data.len());
    }
    let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
    Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

pub fn f32_scalar(v: f32) -> Literal {
    Literal::scalar(v)
}

pub fn i32_scalar(v: i32) -> Literal {
    Literal::scalar(v)
}

/// Extract an f32 literal into a Vec.
pub fn to_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
}

/// Extract a scalar f32 (also accepts 1-element tensors).
pub fn to_f32_scalar(lit: &Literal) -> Result<f32> {
    let v = to_f32_vec(lit)?;
    if v.len() != 1 {
        bail!("expected scalar, got {} elements", v.len());
    }
    Ok(v[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_tensor_shape_checked() {
        assert!(f32_tensor(&[1.0, 2.0], &[3]).is_err());
        let t = f32_tensor(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(t.element_count(), 4);
    }

    #[test]
    fn scalar_roundtrip() {
        let s = f32_scalar(2.5);
        assert_eq!(to_f32_scalar(&s).unwrap(), 2.5);
        let v = f32_tensor(&[1.0, 2.0], &[2]).unwrap();
        assert!(to_f32_scalar(&v).is_err());
        assert_eq!(to_f32_vec(&v).unwrap(), vec![1.0, 2.0]);
    }
}
