//! Layer-3 runtime: PJRT client, artifact registry, and the model training
//! driver that executes the AOT-compiled Layer-1/2 computations.

pub mod batch;
#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod model;
pub mod registry;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

pub use batch::{make_batch, Batch};
#[cfg(feature = "pjrt")]
pub use engine::{Engine, SharedEngine};
#[cfg(feature = "pjrt")]
pub use model::Model;
pub use registry::{ArtifactSpec, Manifest, TensorSpec};
#[cfg(not(feature = "pjrt"))]
pub use stub::{Engine, Model, SharedEngine};

/// Conventional artifact directory (relative to the repo root / CWD).
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory: `$HYPPO_ARTIFACTS`, CWD, or upward from
/// CWD (so tests and examples work from any subdirectory).
pub fn artifact_dir() -> Option<std::path::PathBuf> {
    if let Ok(dir) = std::env::var("HYPPO_ARTIFACTS") {
        let p = std::path::PathBuf::from(dir);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    let mut cur = std::env::current_dir().ok()?;
    loop {
        let cand = cur.join(DEFAULT_ARTIFACT_DIR);
        if cand.join("manifest.json").exists() {
            return Some(cand);
        }
        if !cur.pop() {
            return None;
        }
    }
}
