//! Layer-3 runtime: PJRT client, artifact registry, and the model training
//! driver that executes the AOT-compiled Layer-1/2 computations.

pub mod engine;
pub mod model;
pub mod registry;

pub use engine::{Engine, SharedEngine};
pub use model::{make_batch, Batch, Model};
pub use registry::{ArtifactSpec, Manifest, TensorSpec};

/// Conventional artifact directory (relative to the repo root / CWD).
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory: `$HYPPO_ARTIFACTS`, CWD, or upward from
/// CWD (so tests and examples work from any subdirectory).
pub fn artifact_dir() -> Option<std::path::PathBuf> {
    if let Ok(dir) = std::env::var("HYPPO_ARTIFACTS") {
        let p = std::path::PathBuf::from(dir);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    let mut cur = std::env::current_dir().ok()?;
    loop {
        let cand = cur.join(DEFAULT_ARTIFACT_DIR);
        if cand.join("manifest.json").exists() {
            return Some(cand);
        }
        if !cur.pop() {
            return None;
        }
    }
}
