//! Artifact registry: parses `artifacts/manifest.json` emitted by
//! `python/compile/aot.py` and resolves (architecture, role) pairs to HLO
//! files plus their I/O signatures. This is the only contract between the
//! build-time Python layers and the Rust request path.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{parse, Json};

/// Shape + dtype of one executable input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One manifest entry: a role (`init`, `train_step`, `predict`,
/// `predict_dropout`, `eval_loss`) of one architecture.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub family: String,
    pub arch: String,
    pub role: String,
    pub path: PathBuf,
    pub n_param_arrays: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: BTreeMap<String, Json>,
}

impl ArtifactSpec {
    pub fn meta_i64(&self, key: &str) -> Option<i64> {
        self.meta.get(key).and_then(Json::as_i64)
    }

    pub fn meta_f64(&self, key: &str) -> Option<f64> {
        self.meta.get(key).and_then(Json::as_f64)
    }
}

/// The loaded manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    artifacts: Vec<ArtifactSpec>,
    index: BTreeMap<(String, String), usize>,
}

fn tensor_specs(v: &Json) -> Result<Vec<TensorSpec>> {
    let arr = v.as_arr().context("expected array of tensor specs")?;
    arr.iter()
        .map(|t| {
            let shape = t
                .get("shape")
                .as_arr()
                .context("missing shape")?
                .iter()
                .map(|d| d.as_i64().map(|v| v as usize))
                .collect::<Option<Vec<usize>>>()
                .context("bad shape entry")?;
            let dtype = t
                .get("dtype")
                .as_str()
                .context("missing dtype")?
                .to_string();
            Ok(TensorSpec { shape, dtype })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let root = parse(&text)
            .map_err(|e| anyhow::anyhow!("manifest parse error: {e}"))?;
        if root.get("version").as_i64() != Some(1) {
            bail!("unsupported manifest version");
        }
        let mut artifacts = Vec::new();
        let mut index = BTreeMap::new();
        for entry in root
            .get("artifacts")
            .as_arr()
            .context("manifest missing 'artifacts'")?
        {
            let spec = ArtifactSpec {
                family: entry
                    .get("family")
                    .as_str()
                    .context("family")?
                    .to_string(),
                arch: entry.get("arch").as_str().context("arch")?.to_string(),
                role: entry.get("role").as_str().context("role")?.to_string(),
                path: dir.join(
                    entry.get("path").as_str().context("path")?,
                ),
                n_param_arrays: entry
                    .get("n_param_arrays")
                    .as_i64()
                    .context("n_param_arrays")?
                    as usize,
                inputs: tensor_specs(entry.get("inputs"))?,
                outputs: tensor_specs(entry.get("outputs"))?,
                meta: entry
                    .get("meta")
                    .as_obj()
                    .cloned()
                    .unwrap_or_default(),
            };
            let key = (spec.arch.clone(), spec.role.clone());
            if index.insert(key, artifacts.len()).is_some() {
                bail!("duplicate manifest entry {}/{}", spec.arch, spec.role);
            }
            artifacts.push(spec);
        }
        Ok(Manifest { dir, artifacts, index })
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    pub fn find(&self, arch: &str, role: &str) -> Option<&ArtifactSpec> {
        self.index
            .get(&(arch.to_string(), role.to_string()))
            .map(|i| &self.artifacts[*i])
    }

    /// All architectures of a family (sorted, deduplicated).
    pub fn archs(&self, family: &str) -> Vec<String> {
        let mut v: Vec<String> = self
            .artifacts
            .iter()
            .filter(|a| a.family == family)
            .map(|a| a.arch.clone())
            .collect();
        v.sort();
        v.dedup();
        v
    }

    pub fn iter(&self) -> impl Iterator<Item = &ArtifactSpec> {
        self.artifacts.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        let mut f =
            std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"family":"mlp","arch":"mlp_a","role":"init","path":"a_init.hlo.txt",
         "n_param_arrays":2,
         "inputs":[{"shape":[],"dtype":"int32"}],
         "outputs":[{"shape":[4,8],"dtype":"float32"},{"shape":[8],"dtype":"float32"}],
         "meta":{"layers":1,"width":8,"mult":1.5}},
        {"family":"mlp","arch":"mlp_a","role":"predict","path":"a_pred.hlo.txt",
         "n_param_arrays":2,
         "inputs":[{"shape":[4,8],"dtype":"float32"}],
         "outputs":[{"shape":[32,1],"dtype":"float32"}],
         "meta":{}}
      ]
    }"#;

    #[test]
    fn loads_and_indexes() {
        let dir = std::env::temp_dir().join("hyppo_manifest_test");
        write_manifest(&dir, SAMPLE);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.len(), 2);
        let init = m.find("mlp_a", "init").unwrap();
        assert_eq!(init.n_param_arrays, 2);
        assert_eq!(init.outputs[0].shape, vec![4, 8]);
        assert_eq!(init.meta_i64("width"), Some(8));
        assert_eq!(init.meta_f64("mult"), Some(1.5));
        assert!(m.find("mlp_a", "train_step").is_none());
        assert_eq!(m.archs("mlp"), vec!["mlp_a"]);
        assert!(m.archs("unet").is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_version() {
        let dir = std::env::temp_dir().join("hyppo_manifest_test_v2");
        write_manifest(&dir, r#"{"version":2,"artifacts":[]}"#);
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_duplicates() {
        let dir = std::env::temp_dir().join("hyppo_manifest_test_dup");
        let dup = SAMPLE.replace("\"role\":\"predict\"", "\"role\":\"init\"");
        write_manifest(&dir, &dup);
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_error() {
        let dir = std::env::temp_dir().join("hyppo_manifest_absent");
        std::fs::remove_dir_all(&dir).ok();
        assert!(Manifest::load(&dir).is_err());
    }
}
