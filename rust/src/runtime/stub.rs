//! API-identical stand-ins for the PJRT engine and model driver, compiled
//! when the `pjrt` feature is off (the offline default — see the header
//! note in Cargo.toml and DESIGN.md §3).
//!
//! Everything here typechecks exactly like `runtime::engine` /
//! `runtime::model` but fails at the construction boundary
//! (`SharedEngine::load`, `Model::init`) with an actionable message, so
//! callers that gate on artifact availability — the integration tests,
//! `bench_runtime`, the `mlp` CLI backend — degrade to a clean skip or
//! error instead of a link failure. `Model` holds an uninhabited field,
//! so its post-construction methods are statically unreachable.

use std::convert::Infallible;
use std::marker::PhantomData;
use std::path::Path;

use anyhow::{bail, Result};

use crate::runtime::batch::Batch;

const NO_PJRT: &str = "hyppo was built without the `pjrt` feature; \
    rebuild with `--features pjrt` (and the `xla` crate, see Cargo.toml) \
    to run AOT artifacts";

/// Stub of the single-threaded engine core (never constructible).
pub struct Engine {
    #[allow(dead_code)] // uninhabited marker; nothing can read it
    void: Infallible,
}

impl Engine {
    /// Always fails: the PJRT runtime is not compiled in.
    pub fn load<P: AsRef<Path>>(_artifact_dir: P) -> Result<Engine> {
        bail!(NO_PJRT)
    }
}

/// Stub of the process-wide engine handle (never constructible).
pub struct SharedEngine {
    void: Infallible,
}

impl SharedEngine {
    /// Always fails: the PJRT runtime is not compiled in.
    pub fn load<P: AsRef<Path>>(_artifact_dir: P) -> Result<Self> {
        bail!(NO_PJRT)
    }

    /// Statically unreachable (no `SharedEngine` value can exist).
    pub fn manifest_archs(&self, _family: &str) -> Vec<String> {
        match self.void {}
    }
}

/// Stub of the live-model driver (never constructible).
pub struct Model<'e> {
    void: Infallible,
    _engine: PhantomData<&'e SharedEngine>,
}

impl<'e> Model<'e> {
    /// Always fails: the PJRT runtime is not compiled in.
    pub fn init(
        _engine: &'e SharedEngine,
        _arch: &str,
        _seed: i32,
    ) -> Result<Self> {
        bail!(NO_PJRT)
    }

    /// Always fails: the PJRT runtime is not compiled in.
    pub fn init_host(
        _engine: &'e SharedEngine,
        _arch: &str,
        _seed: u64,
    ) -> Result<Self> {
        bail!(NO_PJRT)
    }

    /// Statically unreachable (no `Model` value can exist).
    pub fn arch(&self) -> &str {
        match self.void {}
    }

    /// Statically unreachable (no `Model` value can exist).
    pub fn x_elems(&self) -> usize {
        match self.void {}
    }

    /// Statically unreachable (no `Model` value can exist).
    pub fn y_elems(&self) -> usize {
        match self.void {}
    }

    /// Statically unreachable (no `Model` value can exist).
    pub fn train_step(
        &mut self,
        _batch: &Batch,
        _lr: f32,
        _dropout_p: f32,
        _seed: i32,
    ) -> Result<f32> {
        match self.void {}
    }

    /// Statically unreachable (no `Model` value can exist).
    pub fn train_step_data_parallel(
        &mut self,
        _shards: &[Batch],
        _lr: f32,
        _dropout_p: f32,
        _seed: i32,
    ) -> Result<f32> {
        match self.void {}
    }

    /// Statically unreachable (no `Model` value can exist).
    pub fn predict(&self, _x: &[f32]) -> Result<Vec<f32>> {
        match self.void {}
    }

    /// Statically unreachable (no `Model` value can exist).
    pub fn predict_dropout(
        &self,
        _x: &[f32],
        _p: f32,
        _seed: i32,
    ) -> Result<Vec<f32>> {
        match self.void {}
    }

    /// Statically unreachable (no `Model` value can exist).
    pub fn eval_loss(&self, _batch: &Batch) -> Result<f32> {
        match self.void {}
    }

    /// Statically unreachable (no `Model` value can exist).
    pub fn n_params(&self) -> usize {
        match self.void {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_load_fails_with_actionable_message() {
        let err = SharedEngine::load("/tmp").unwrap_err();
        assert!(format!("{err:#}").contains("pjrt"));
        assert!(Engine::load("/tmp").is_err());
    }
}
