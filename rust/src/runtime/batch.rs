//! Padded-batch construction shared by the real PJRT model driver and the
//! feature-gated stub. Pure host-side code: no `xla` dependency, so the
//! batching contract (zero-padding + weight masking, see
//! `python/compile/kernels/reductions.py`) is always compiled and tested.

use anyhow::{bail, Result};

/// A dataset batch already shaped for the compiled batch dimension: rows
/// beyond the logical batch are zero-padded and masked out by the weight
/// vector (see kernels/reductions.py for the masking contract).
#[derive(Debug, Clone)]
pub struct Batch {
    /// Row-major input features, `batch * x_dim` elements.
    pub x: Vec<f32>,
    /// Row-major targets, `batch * y_dim` elements.
    pub y: Vec<f32>,
    /// Per-row mask: 1.0 for live rows, 0.0 for padding.
    pub weights: Vec<f32>,
}

/// Build a padded batch from row-major samples.
pub fn make_batch(
    xs: &[&[f32]],
    ys: &[&[f32]],
    batch: usize,
) -> Result<Batch> {
    if xs.len() != ys.len() {
        bail!("x/y row mismatch");
    }
    if xs.len() > batch {
        bail!("too many rows ({}) for compiled batch {batch}", xs.len());
    }
    if xs.is_empty() {
        bail!("empty batch");
    }
    let xd = xs[0].len();
    let yd = ys[0].len();
    let mut x = vec![0.0f32; batch * xd];
    let mut y = vec![0.0f32; batch * yd];
    let mut weights = vec![0.0f32; batch];
    for (i, (xr, yr)) in xs.iter().zip(ys).enumerate() {
        if xr.len() != xd || yr.len() != yd {
            bail!("ragged batch rows");
        }
        x[i * xd..(i + 1) * xd].copy_from_slice(xr);
        y[i * yd..(i + 1) * yd].copy_from_slice(yr);
        weights[i] = 1.0;
    }
    Ok(Batch { x, y, weights })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pads_and_masks() {
        let xs: Vec<&[f32]> = vec![&[1.0, 2.0], &[3.0, 4.0]];
        let ys: Vec<&[f32]> = vec![&[0.5], &[0.25]];
        let b = make_batch(&xs, &ys, 4).unwrap();
        assert_eq!(b.x.len(), 8);
        assert_eq!(b.y.len(), 4);
        assert_eq!(b.weights, vec![1.0, 1.0, 0.0, 0.0]);
        assert_eq!(&b.x[..4], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(&b.x[4..], &[0.0; 4]);
    }

    #[test]
    fn rejects_bad_shapes() {
        let xs: Vec<&[f32]> = vec![&[1.0, 2.0]];
        let ys: Vec<&[f32]> = vec![&[0.5], &[0.25]];
        assert!(make_batch(&xs, &ys, 4).is_err()); // row mismatch
        let ys1: Vec<&[f32]> = vec![&[0.5]];
        assert!(make_batch(&xs, &ys1, 0).is_err()); // too many rows
        let none: Vec<&[f32]> = vec![];
        assert!(make_batch(&none, &none, 4).is_err()); // empty
        let ragged_x: Vec<&[f32]> = vec![&[1.0, 2.0], &[3.0]];
        let ys2: Vec<&[f32]> = vec![&[0.5], &[0.25]];
        assert!(make_batch(&ragged_x, &ys2, 4).is_err());
    }
}
