//! Model training driver: owns a model's parameter literals and drives the
//! `init` / `train_step` / `predict` / `predict_dropout` / `eval_loss`
//! role executables of one architecture. This is the Rust side of the
//! lower-level problem (paper Eq. 3): the whole SGD loop runs here, with
//! Python long gone.

use anyhow::{bail, Context, Result};
use xla::Literal;

use crate::runtime::batch::Batch;
use crate::runtime::engine::{
    f32_scalar, f32_tensor, i32_scalar, to_f32_scalar, to_f32_vec,
    SharedEngine,
};
use crate::runtime::registry::TensorSpec;

/// A live model: architecture name + current parameter literals.
pub struct Model<'e> {
    engine: &'e SharedEngine,
    arch: String,
    params: Vec<Literal>,
    /// Compiled batch size and data shapes (from the manifest).
    pub batch: usize,
    x_spec: TensorSpec,
    y_spec: TensorSpec,
}

impl<'e> Model<'e> {
    /// Initialize parameters with the `init` executable.
    pub fn init(engine: &'e SharedEngine, arch: &str, seed: i32) -> Result<Self> {
        let train_spec = engine.with(|e| {
            e.prepare(arch, "train_step")
        })?;
        let n = train_spec.n_param_arrays;
        // train_step inputs: params.. x y w lr p seed
        let x_spec = train_spec.inputs[n].clone();
        let y_spec = train_spec.inputs[n + 1].clone();
        let batch = x_spec.shape[0];
        let params = engine
            .exec(arch, "init", &[i32_scalar(seed)])
            .context("init")?;
        if params.len() != n {
            bail!(
                "init returned {} arrays, manifest says {n}",
                params.len()
            );
        }
        Ok(Model {
            engine,
            arch: arch.to_string(),
            params,
            batch,
            x_spec,
            y_spec,
        })
    }

    /// Initialize parameters host-side instead of running the `init`
    /// executable. Matches the Python initializers' *distribution family*
    /// (He-normal for conv kernels, Glorot-uniform for dense matrices,
    /// zeros for biases) without bit-exactness. Motivation (§Perf): XLA
    /// CPU takes minutes to compile the threefry `init` graph of the
    /// 600k-parameter U-Net, while the training/predict artifacts compile
    /// in seconds — host init removes that one-time stall entirely.
    pub fn init_host(
        engine: &'e SharedEngine,
        arch: &str,
        seed: u64,
    ) -> Result<Self> {
        let train_spec =
            engine.with(|e| e.prepare(arch, "train_step"))?;
        let n = train_spec.n_param_arrays;
        let x_spec = train_spec.inputs[n].clone();
        let y_spec = train_spec.inputs[n + 1].clone();
        let batch = x_spec.shape[0];

        let mut rng = crate::sampling::Rng::new(seed ^ 0x1217);
        let params: Result<Vec<Literal>> = train_spec.inputs[..n]
            .iter()
            .map(|spec| {
                let count = spec.element_count();
                let data: Vec<f32> = match spec.shape.len() {
                    1 => vec![0.0; count], // bias
                    2 => {
                        // Glorot uniform over (fan_in, fan_out).
                        let limit = (6.0
                            / (spec.shape[0] + spec.shape[1]) as f64)
                            .sqrt();
                        (0..count)
                            .map(|_| {
                                ((rng.f64() * 2.0 - 1.0) * limit) as f32
                            })
                            .collect()
                    }
                    4 => {
                        // He normal over (kh, kw, cin, cout).
                        let fan_in = (spec.shape[0]
                            * spec.shape[1]
                            * spec.shape[2])
                            as f64;
                        let std = (2.0 / fan_in).sqrt();
                        (0..count)
                            .map(|_| (rng.normal() * std) as f32)
                            .collect()
                    }
                    _ => bail!(
                        "unsupported param rank {:?}",
                        spec.shape
                    ),
                };
                f32_tensor(&data, &spec.shape)
            })
            .collect();
        Ok(Model {
            engine,
            arch: arch.to_string(),
            params: params?,
            batch,
            x_spec,
            y_spec,
        })
    }

    pub fn arch(&self) -> &str {
        &self.arch
    }

    pub fn x_elems(&self) -> usize {
        self.x_spec.element_count() / self.batch
    }

    pub fn y_elems(&self) -> usize {
        self.y_spec.element_count() / self.batch
    }

    fn batch_literals(&self, b: &Batch) -> Result<(Literal, Literal, Literal)> {
        Ok((
            f32_tensor(&b.x, &self.x_spec.shape)?,
            f32_tensor(&b.y, &self.y_spec.shape)?,
            f32_tensor(&b.weights, &[self.batch])?,
        ))
    }

    /// One SGD step; consumes and replaces the parameter state, returns
    /// the pre-update batch loss.
    pub fn train_step(
        &mut self,
        batch: &Batch,
        lr: f32,
        dropout_p: f32,
        seed: i32,
    ) -> Result<f32> {
        let (x, y, w) = self.batch_literals(batch)?;
        let mut inputs: Vec<Literal> = std::mem::take(&mut self.params);
        inputs.extend([x, y, w, f32_scalar(lr), f32_scalar(dropout_p), i32_scalar(seed)]);
        let mut out = self.engine.exec(&self.arch, "train_step", &inputs)?;
        let loss = out
            .pop()
            .context("train_step output missing loss")
            .and_then(|l| to_f32_scalar(&l))?;
        self.params = out;
        Ok(loss)
    }

    /// One *data-parallel* SGD step (paper §IV-2, "train in parallel"):
    /// the logical batch is sharded into `shards` sub-batches; each shard
    /// applies `train_step` from the same starting parameters, and the
    /// resulting parameter sets are averaged — algebraically identical to
    /// averaging gradients (all-reduce) for plain SGD:
    ///   mean_k(w − lr·g_k) = w − lr·mean_k(g_k).
    /// Returns the weighted mean of the shard losses.
    pub fn train_step_data_parallel(
        &mut self,
        shards: &[Batch],
        lr: f32,
        dropout_p: f32,
        seed: i32,
    ) -> Result<f32> {
        assert!(!shards.is_empty());
        if shards.len() == 1 {
            return self.train_step(&shards[0], lr, dropout_p, seed);
        }
        let start_params = self.clone_params()?;
        let mut acc: Vec<Vec<f32>> = Vec::new();
        let mut shapes: Vec<Vec<usize>> = Vec::new();
        let mut loss_acc = 0.0f64;
        let mut weight_acc = 0.0f64;
        for (k, shard) in shards.iter().enumerate() {
            // Restore the pre-step parameters for every shard.
            self.params = start_params
                .iter()
                .map(|p| {
                    let shape: Vec<usize> = p
                        .array_shape()
                        .map_err(|e| anyhow::anyhow!("shape: {e:?}"))?
                        .dims()
                        .iter()
                        .map(|d| *d as usize)
                        .collect();
                    f32_tensor(&to_f32_vec(p)?, &shape)
                })
                .collect::<Result<Vec<_>>>()?;
            let w_k: f64 =
                shard.weights.iter().map(|w| *w as f64).sum();
            let loss = self.train_step(
                shard,
                lr,
                dropout_p,
                seed.wrapping_add(k as i32),
            )?;
            loss_acc += loss as f64 * w_k;
            weight_acc += w_k;
            for (i, p) in self.params.iter().enumerate() {
                let v = to_f32_vec(p)?;
                if k == 0 {
                    shapes.push(
                        p.array_shape()
                            .map_err(|e| anyhow::anyhow!("{e:?}"))?
                            .dims()
                            .iter()
                            .map(|d| *d as usize)
                            .collect(),
                    );
                    acc.push(v);
                } else {
                    for (a, b) in acc[i].iter_mut().zip(v) {
                        *a += b;
                    }
                }
            }
        }
        let n = shards.len() as f32;
        self.params = acc
            .into_iter()
            .zip(&shapes)
            .map(|(mut v, shape)| {
                for x in v.iter_mut() {
                    *x /= n;
                }
                f32_tensor(&v, shape)
            })
            .collect::<Result<Vec<_>>>()?;
        Ok((loss_acc / weight_acc.max(1e-12)) as f32)
    }

    /// Deterministic forward pass (batch-shaped x).
    pub fn predict(&self, x: &[f32]) -> Result<Vec<f32>> {
        let mut inputs: Vec<Literal> = self.clone_params()?;
        inputs.push(f32_tensor(x, &self.x_spec.shape)?);
        let out = self.engine.exec(&self.arch, "predict", &inputs)?;
        to_f32_vec(&out[0])
    }

    /// One MC-dropout pass.
    pub fn predict_dropout(
        &self,
        x: &[f32],
        p: f32,
        seed: i32,
    ) -> Result<Vec<f32>> {
        let mut inputs: Vec<Literal> = self.clone_params()?;
        inputs.extend([
            f32_tensor(x, &self.x_spec.shape)?,
            f32_scalar(p),
            i32_scalar(seed),
        ]);
        let out =
            self.engine.exec(&self.arch, "predict_dropout", &inputs)?;
        to_f32_vec(&out[0])
    }

    /// Deterministic weighted validation loss.
    pub fn eval_loss(&self, batch: &Batch) -> Result<f32> {
        let (x, y, w) = self.batch_literals(batch)?;
        let mut inputs: Vec<Literal> = self.clone_params()?;
        inputs.extend([x, y, w]);
        let out = self.engine.exec(&self.arch, "eval_loss", &inputs)?;
        to_f32_scalar(&out[0])
    }

    /// Total parameter count of the live state.
    pub fn n_params(&self) -> usize {
        self.params.iter().map(|p| p.element_count()).sum()
    }

    fn clone_params(&self) -> Result<Vec<Literal>> {
        // Literal has no Clone; rebuild via raw vecs (params are small).
        self.params
            .iter()
            .map(|p| {
                let shape: Vec<usize> = p
                    .array_shape()
                    .map_err(|e| anyhow::anyhow!("shape: {e:?}"))?
                    .dims()
                    .iter()
                    .map(|d| *d as usize)
                    .collect();
                let data = to_f32_vec(p)?;
                f32_tensor(&data, &shape)
            })
            .collect()
    }
}
