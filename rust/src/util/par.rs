//! Deterministic fork-join for the proposal hot path (DESIGN.md §11).
//!
//! The rule that keeps parallel candidate scoring bit-identical to the
//! sequential path: work is split into **contiguous chunks in input
//! order**, every item's result is computed independently of its
//! chunk-mates, and results are concatenated back in chunk order. Under
//! that contract the output is the same `Vec` — bit for bit — for every
//! thread count, so `scoring_threads` is a pure throughput knob that can
//! never change a proposal.

/// Map `f` over contiguous chunks of `items` using up to `threads`
/// scoped threads, concatenating the per-chunk outputs in input order.
///
/// `f` receives one chunk and must return exactly one result per item,
/// each computed independently of the chunk split (no cross-item state).
/// With `threads <= 1` (or a single item) `f` runs inline on the full
/// slice — the sequential path is literally the same code.
pub fn par_chunks_stable<T, R, F>(
    items: &[T],
    threads: usize,
    f: F,
) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&[T]) -> Vec<R> + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads == 1 {
        let out = f(items);
        assert_eq!(
            out.len(),
            items.len(),
            "chunk fn must return one result per item"
        );
        return out;
    }
    let chunk = items.len().div_ceil(threads);
    let mut out = Vec::with_capacity(items.len());
    let fref = &f;
    std::thread::scope(|s| {
        let handles: Vec<_> = items
            .chunks(chunk)
            .map(|c| s.spawn(move || fref(c)))
            .collect();
        for (h, c) in handles.into_iter().zip(items.chunks(chunk)) {
            let part = h.join().expect("scoring thread panicked");
            assert_eq!(
                part.len(),
                c.len(),
                "chunk fn must return one result per item"
            );
            out.extend(part);
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_is_identical_for_any_thread_count() {
        let items: Vec<f64> = (0..257).map(|i| i as f64 * 0.37).collect();
        let work = |chunk: &[f64]| -> Vec<f64> {
            chunk.iter().map(|v| (v * 1.7).sin() + v).collect()
        };
        let seq = par_chunks_stable(&items, 1, work);
        for threads in [2usize, 3, 8, 64, 1000] {
            let par = par_chunks_stable(&items, threads, work);
            assert_eq!(par.len(), seq.len());
            for (a, b) in par.iter().zip(&seq) {
                assert_eq!(a.to_bits(), b.to_bits(), "{threads} threads");
            }
        }
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let empty: Vec<u32> = Vec::new();
        let out = par_chunks_stable(&empty, 8, |c| c.to_vec());
        assert!(out.is_empty());
        let one = [42u32];
        assert_eq!(par_chunks_stable(&one, 8, |c| c.to_vec()), vec![42]);
    }

    #[test]
    fn chunks_are_contiguous_and_ordered() {
        let items: Vec<usize> = (0..100).collect();
        let out = par_chunks_stable(&items, 7, |c| c.to_vec());
        assert_eq!(out, items);
    }
}
