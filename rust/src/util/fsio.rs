//! Crash-durable filesystem primitives shared by `exec::checkpoint` and
//! the `serve::wal` write-ahead log.
//!
//! The classic atomic-replace recipe (write `<path>.tmp`, rename over
//! `path`) has two holes on real filesystems:
//!
//! 1. the tmp file's *contents* may still sit in the page cache when the
//!    rename lands, so a crash can leave `path` pointing at an empty or
//!    truncated inode — fixed by `fsync`ing the file before the rename;
//! 2. the rename itself is a directory-entry update, and a crash between
//!    the rename and the directory sync can lose the entry — fixed by
//!    opening the parent directory and `fsync`ing *it* after the rename
//!    (POSIX filesystems persist directory updates through the directory
//!    fd; on platforms where directories cannot be opened this step is a
//!    no-op, which is no worse than the previous behaviour).
//!
//! [`append_sync`] is the WAL half: append bytes and flush them to
//! stable storage before acknowledging, so a record that was reported
//! durable survives a crash immediately after.

use std::fs::{File, OpenOptions};
use std::io::{ErrorKind, Write};
use std::path::Path;

use anyhow::{Context, Result};

/// Retry `op` while it fails with [`ErrorKind::Interrupted`] (EINTR).
///
/// A signal landing mid-syscall is not a filesystem failure: `open`,
/// `fsync`, and friends may all surface EINTR on POSIX, and treating it
/// as fatal turns an innocuous `SIGCHLD` into a spurious WAL failure
/// (which under `wal_failure = wedge` takes a whole shard down). Any
/// other error is returned unchanged.
pub fn retry_interrupted<T>(
    mut op: impl FnMut() -> std::io::Result<T>,
) -> std::io::Result<T> {
    loop {
        match op() {
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            done => return done,
        }
    }
}

/// Write all of `buf` to `w`, retrying interrupted and short writes.
///
/// Equivalent to `Write::write_all` but with the EINTR handling spelled
/// out and the writer injectable, so the retry behaviour is unit-tested
/// against a deliberately interrupting writer rather than trusted.
pub fn write_all_retrying(w: &mut dyn Write, buf: &[u8]) -> std::io::Result<()> {
    let mut rest = buf;
    while !rest.is_empty() {
        match w.write(rest) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    ErrorKind::WriteZero,
                    "writer accepted 0 bytes",
                ))
            }
            Ok(n) => rest = rest.get(n..).unwrap_or(&[]),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// `fsync` the parent directory of `path`, persisting directory-entry
/// updates (renames, creations). No-op when `path` has no parent or on
/// platforms where directories cannot be opened as files.
pub fn sync_parent_dir(path: &Path) -> Result<()> {
    let dir = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => d,
        _ => return Ok(()),
    };
    #[cfg(unix)]
    {
        let d = retry_interrupted(|| File::open(dir))
            .with_context(|| format!("opening dir {}", dir.display()))?;
        retry_interrupted(|| d.sync_all())
            .with_context(|| format!("fsync dir {}", dir.display()))?;
    }
    #[cfg(not(unix))]
    {
        let _ = dir;
    }
    Ok(())
}

/// Atomically and durably replace `path` with `contents`: create the
/// parent directory, write `<path>.tmp`, `fsync` it, rename it over
/// `path`, then `fsync` the parent directory (see module docs for why
/// each step exists). A crash at any point leaves either the old
/// complete file or the new complete file.
pub fn atomic_write_sync(path: &Path, contents: &[u8]) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("mkdir {}", dir.display()))?;
        }
    }
    let tmp = path.with_extension("tmp");
    {
        let mut f = retry_interrupted(|| File::create(&tmp))
            .with_context(|| format!("creating {}", tmp.display()))?;
        write_all_retrying(&mut f, contents)
            .with_context(|| format!("writing {}", tmp.display()))?;
        retry_interrupted(|| f.sync_all())
            .with_context(|| format!("fsync {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming into {}", path.display()))?;
    sync_parent_dir(path)
}

/// Durably append `bytes` to `path` (creating it if absent): the bytes
/// are `fsync`ed before this returns, so a caller that acknowledges a
/// write-ahead-log record after `append_sync` never acknowledges
/// something a crash can take back.
pub fn append_sync(path: &Path, bytes: &[u8]) -> Result<()> {
    let mut f = retry_interrupted(|| {
        OpenOptions::new().create(true).append(true).open(path)
    })
    .with_context(|| format!("opening {} for append", path.display()))?;
    write_all_retrying(&mut f, bytes)
        .with_context(|| format!("appending to {}", path.display()))?;
    retry_interrupted(|| f.sync_all())
        .with_context(|| format!("fsync {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("hyppo_fsio_{name}"))
    }

    #[test]
    fn atomic_write_replaces_and_cleans_tmp() {
        let p = tmp_path("atomic.json");
        atomic_write_sync(&p, b"one").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"one");
        atomic_write_sync(&p, b"two").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"two");
        assert!(!p.with_extension("tmp").exists(), "tmp left behind");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn atomic_write_creates_missing_parent() {
        let dir = tmp_path("nested_dir");
        let p = dir.join("deep").join("ckpt.json");
        atomic_write_sync(&p, b"x").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"x");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Writer that fails with EINTR on every other call and otherwise
    /// accepts a single byte — the worst-case interrupting short writer.
    struct InterruptingWriter {
        sink: Vec<u8>,
        calls: usize,
    }

    impl Write for InterruptingWriter {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.calls += 1;
            if self.calls % 2 == 1 {
                return Err(std::io::Error::new(
                    ErrorKind::Interrupted,
                    "injected EINTR",
                ));
            }
            match buf.first() {
                Some(b) => {
                    self.sink.push(*b);
                    Ok(1)
                }
                None => Ok(0),
            }
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn write_all_retrying_survives_interrupts_and_short_writes() {
        let mut w = InterruptingWriter { sink: Vec::new(), calls: 0 };
        write_all_retrying(&mut w, b"durable").unwrap();
        assert_eq!(w.sink, b"durable");
        // one EINTR before each accepted byte
        assert_eq!(w.calls, 2 * b"durable".len());
    }

    #[test]
    fn write_all_retrying_propagates_real_errors() {
        struct Broken;
        impl Write for Broken {
            fn write(&mut self, _: &[u8]) -> std::io::Result<usize> {
                Err(std::io::Error::new(ErrorKind::Other, "disk on fire"))
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let err = write_all_retrying(&mut Broken, b"x").unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Other);
    }

    #[test]
    fn retry_interrupted_retries_eintr_only() {
        let mut left = 3usize;
        let out = retry_interrupted(|| {
            if left > 0 {
                left -= 1;
                Err(std::io::Error::new(ErrorKind::Interrupted, "EINTR"))
            } else {
                Ok(42)
            }
        })
        .unwrap();
        assert_eq!(out, 42);
        assert_eq!(left, 0);

        let err = retry_interrupted(|| -> std::io::Result<()> {
            Err(std::io::Error::new(ErrorKind::NotFound, "gone"))
        })
        .unwrap_err();
        assert_eq!(err.kind(), ErrorKind::NotFound);
    }

    #[test]
    fn append_sync_accumulates() {
        let p = tmp_path("append.log");
        std::fs::remove_file(&p).ok();
        append_sync(&p, b"a\n").unwrap();
        append_sync(&p, b"b\n").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"a\nb\n");
        std::fs::remove_file(&p).ok();
    }
}
