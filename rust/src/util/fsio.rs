//! Crash-durable filesystem primitives shared by `exec::checkpoint` and
//! the `serve::wal` write-ahead log.
//!
//! The classic atomic-replace recipe (write `<path>.tmp`, rename over
//! `path`) has two holes on real filesystems:
//!
//! 1. the tmp file's *contents* may still sit in the page cache when the
//!    rename lands, so a crash can leave `path` pointing at an empty or
//!    truncated inode — fixed by `fsync`ing the file before the rename;
//! 2. the rename itself is a directory-entry update, and a crash between
//!    the rename and the directory sync can lose the entry — fixed by
//!    opening the parent directory and `fsync`ing *it* after the rename
//!    (POSIX filesystems persist directory updates through the directory
//!    fd; on platforms where directories cannot be opened this step is a
//!    no-op, which is no worse than the previous behaviour).
//!
//! [`append_sync`] is the WAL half: append bytes and flush them to
//! stable storage before acknowledging, so a record that was reported
//! durable survives a crash immediately after.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// `fsync` the parent directory of `path`, persisting directory-entry
/// updates (renames, creations). No-op when `path` has no parent or on
/// platforms where directories cannot be opened as files.
pub fn sync_parent_dir(path: &Path) -> Result<()> {
    let dir = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => d,
        _ => return Ok(()),
    };
    #[cfg(unix)]
    {
        let d = File::open(dir)
            .with_context(|| format!("opening dir {}", dir.display()))?;
        d.sync_all()
            .with_context(|| format!("fsync dir {}", dir.display()))?;
    }
    #[cfg(not(unix))]
    {
        let _ = dir;
    }
    Ok(())
}

/// Atomically and durably replace `path` with `contents`: create the
/// parent directory, write `<path>.tmp`, `fsync` it, rename it over
/// `path`, then `fsync` the parent directory (see module docs for why
/// each step exists). A crash at any point leaves either the old
/// complete file or the new complete file.
pub fn atomic_write_sync(path: &Path, contents: &[u8]) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("mkdir {}", dir.display()))?;
        }
    }
    let tmp = path.with_extension("tmp");
    {
        let mut f = File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(contents)
            .with_context(|| format!("writing {}", tmp.display()))?;
        f.sync_all()
            .with_context(|| format!("fsync {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming into {}", path.display()))?;
    sync_parent_dir(path)
}

/// Durably append `bytes` to `path` (creating it if absent): the bytes
/// are `fsync`ed before this returns, so a caller that acknowledges a
/// write-ahead-log record after `append_sync` never acknowledges
/// something a crash can take back.
pub fn append_sync(path: &Path, bytes: &[u8]) -> Result<()> {
    let mut f = OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .with_context(|| format!("opening {} for append", path.display()))?;
    f.write_all(bytes)
        .with_context(|| format!("appending to {}", path.display()))?;
    f.sync_all()
        .with_context(|| format!("fsync {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("hyppo_fsio_{name}"))
    }

    #[test]
    fn atomic_write_replaces_and_cleans_tmp() {
        let p = tmp_path("atomic.json");
        atomic_write_sync(&p, b"one").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"one");
        atomic_write_sync(&p, b"two").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"two");
        assert!(!p.with_extension("tmp").exists(), "tmp left behind");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn atomic_write_creates_missing_parent() {
        let dir = tmp_path("nested_dir");
        let p = dir.join("deep").join("ckpt.json");
        atomic_write_sync(&p, b"x").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"x");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn append_sync_accumulates() {
        let p = tmp_path("append.log");
        std::fs::remove_file(&p).ok();
        append_sync(&p, b"a\n").unwrap();
        append_sync(&p, b"b\n").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"a\nb\n");
        std::fs::remove_file(&p).ok();
    }
}
