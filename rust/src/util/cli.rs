//! Tiny CLI argument parser substrate (no clap in the offline toolchain).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args,
//! with typed accessors and a usage/error path the `hyppo` binary and the
//! example drivers share.

use std::collections::BTreeMap;

/// Parsed command line: positionals plus `--key [value]` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else {
                    // Lookahead: `--key value` unless the next token is
                    // itself an option (then it's a bare flag).
                    let takes_value = iter
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false);
                    if takes_value {
                        let v = iter.next().unwrap();
                        out.opts.insert(rest.to_string(), v);
                    } else {
                        out.opts.insert(rest.to_string(), "true".into());
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.opts.get(key).map(String::as_str), Some("true") | Some("1"))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(String::as_str)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["run", "--iters", "50", "--fast", "--k=v"]);
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.usize_or("iters", 0), 50);
        assert!(a.flag("fast"));
        assert_eq!(a.get("k"), Some("v"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.usize_or("missing", 7), 7);
        assert_eq!(a.f64_or("missing", 0.5), 0.5);
        assert!(!a.flag("missing"));
    }

    #[test]
    fn flag_before_option() {
        let a = parse(&["--verbose", "--n", "3"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.usize_or("n", 0), 3);
    }
}
