//! CSV emitter for figure/table data (`examples/` write these; EXPERIMENTS.md
//! references them). Quoting rules cover the values we emit (numbers and
//! simple identifiers, occasionally containing commas).

use std::fmt::Display;
use std::fs::File;
use std::io::{BufWriter, Result, Write};
use std::path::Path;

pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    /// Create the file (and parent dirs) and write the header row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = CsvWriter {
            out: BufWriter::new(File::create(path)?),
            cols: header.len(),
        };
        w.write_raw_row(header)?;
        Ok(w)
    }

    fn write_raw_row<D: Display>(&mut self, row: &[D]) -> Result<()> {
        assert_eq!(row.len(), self.cols, "csv row arity mismatch");
        let mut line = String::new();
        for (i, cell) in row.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            let s = cell.to_string();
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                line.push('"');
                line.push_str(&s.replace('"', "\"\""));
                line.push('"');
            } else {
                line.push_str(&s);
            }
        }
        line.push('\n');
        self.out.write_all(line.as_bytes())
    }

    pub fn row<D: Display>(&mut self, row: &[D]) -> Result<()> {
        self.write_raw_row(row)
    }

    pub fn finish(mut self) -> Result<()> {
        self.out.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_quotes() {
        let dir = std::env::temp_dir().join("hyppo_csv_test");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row(&["1", "x,y"]).unwrap();
        w.row(&["2", "q\"q"]).unwrap();
        w.finish().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,\"x,y\"\n2,\"q\"\"q\"\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let dir = std::env::temp_dir().join("hyppo_csv_test2");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        let _ = w.row(&["only-one"]);
    }
}
