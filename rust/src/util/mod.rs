//! Shared substrates: JSON, CLI parsing, bench harness, property testing,
//! CSV emission, deterministic fork-join. All hand-rolled — the offline
//! toolchain ships no serde, clap, criterion, rayon, or proptest
//! (DESIGN.md §7).

pub mod bench;
pub mod cli;
pub mod csv;
pub mod fsio;
pub mod json;
pub mod par;
pub mod prop;
