//! Minimal JSON parser/writer substrate.
//!
//! The offline build environment provides no serde, so the artifact
//! manifest (`artifacts/manifest.json`) and the report emitters use this
//! hand-rolled implementation. It supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (sufficient for the ASCII manifest).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|v| v as i64)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; returns `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(format!("trailing bytes at offset {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at offset {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!(
                "unexpected {:?} at offset {}",
                other.map(|b| b as char),
                self.i
            )),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at offset {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
            {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|e| e.to_string())?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self
                        .peek()
                        .ok_or_else(|| "bad escape".to_string())?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )
                            .map_err(|e| e.to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|e| e.to_string())?;
                            self.i += 4;
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                        }
                        other => {
                            return Err(format!(
                                "bad escape \\{}",
                                other as char
                            ))
                        }
                    }
                }
                Some(_) => {
                    // Copy a run of plain bytes (UTF-8 passes through).
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("bad array at offset {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => {
                    return Err(format!("bad object at offset {}", self.i))
                }
            }
        }
    }
}

/// Serialize a value (compact form).
pub fn write(v: &Json) -> String {
    let mut s = String::new();
    write_into(v, &mut s);
    s
}

fn write_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if !n.is_finite() {
                // JSON has no inf/NaN tokens; emit null rather than an
                // unparseable document. Callers that must round-trip
                // non-finite values encode them themselves (see
                // analysis::persistence).
                out.push_str("null");
            } else if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => {
                        let _ = write!(out, "\\u{:04x}", c as u32);
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(&Json::Str(k.clone()), out);
                out.push(':');
                write_into(val, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a":[1,2,{"b":"x"}],"c":null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").as_arr().unwrap()[2].get("b").as_str(),
            Some("x")
        );
        assert_eq!(*v.get("c"), Json::Null);
        assert_eq!(*v.get("missing"), Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"n":-3,"o":{"t":true}}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&write(&v)).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn non_finite_numbers_emit_valid_json() {
        let doc = Json::Arr(vec![
            Json::Num(f64::INFINITY),
            Json::Num(f64::NEG_INFINITY),
            Json::Num(f64::NAN),
            Json::Num(1.5),
        ]);
        let text = write(&doc);
        assert_eq!(text, "[null,null,null,1.5]");
        assert!(parse(&text).is_ok(), "writer must never emit bad JSON");
    }
}
