//! Micro-benchmark harness substrate (criterion is unavailable offline).
//!
//! `cargo bench` targets are declared with `harness = false` and drive this
//! module: warmup, adaptive iteration count targeting a fixed measurement
//! window, and median/mean/p95 reporting. Good enough to rank hot-path
//! changes during the §Perf pass; absolute numbers land in EXPERIMENTS.md.
//!
//! Machine-readable output: every target drives a [`BenchRun`], which
//! understands two flags after the `cargo bench --bench <t> --` separator:
//!
//! * `--json PATH` — write all cases (plus derived ratios and the git
//!   revision) as a `hyppo-bench-v1` JSON document; the `BENCH_*.json`
//!   files at the repo root and the CI `bench-smoke` artifacts use this.
//! * `--budget-ms N` — override every case's measurement budget (the CI
//!   smoke job runs with ~5 ms so regressions surface per-PR without
//!   burning minutes).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::util::json::{write as write_json, Json};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    pub fn report(&self) {
        println!(
            "{:<44} {:>10} iters  mean {:>12}  median {:>12}  p95 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.min_ns),
        );
    }

    /// The `hyppo-bench-v1` record for this case.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("name".into(), Json::Str(self.name.clone()));
        o.insert("iters".into(), Json::Num(self.iters as f64));
        o.insert("mean_ns".into(), Json::Num(self.mean_ns));
        o.insert("median_ns".into(), Json::Num(self.median_ns));
        o.insert("p95_ns".into(), Json::Num(self.p95_ns));
        o.insert("min_ns".into(), Json::Num(self.min_ns));
        Json::Obj(o)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Benchmark `f`, automatically choosing the per-sample iteration count so
/// that total measurement time is ~`budget`.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchStats {
    // Warmup + calibration: run until we know the cost of one call. The
    // calibration window shrinks with tight budgets so a --budget-ms 5
    // smoke pass is actually fast.
    let cal_window = budget.min(Duration::from_millis(100));
    let cal_start = Instant::now();
    let mut cal_iters = 0u64;
    while cal_start.elapsed() < cal_window {
        f();
        cal_iters += 1;
        if cal_iters > 1_000_000 {
            break;
        }
    }
    let per_call =
        cal_start.elapsed().as_nanos() as f64 / cal_iters.max(1) as f64;

    const SAMPLES: usize = 20;
    let per_sample_budget =
        budget.as_nanos() as f64 / SAMPLES as f64;
    let iters_per_sample =
        ((per_sample_budget / per_call.max(1.0)) as u64).clamp(1, 10_000_000);

    let mut samples = Vec::with_capacity(SAMPLES);
    for _ in 0..SAMPLES {
        let t = Instant::now();
        for _ in 0..iters_per_sample {
            f();
        }
        samples.push(
            t.elapsed().as_nanos() as f64 / iters_per_sample as f64,
        );
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let stats = BenchStats {
        name: name.to_string(),
        iters: iters_per_sample * SAMPLES as u64,
        mean_ns: mean,
        median_ns: samples[samples.len() / 2],
        p95_ns: samples
            [((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
        min_ns: samples[0],
    };
    stats.report();
    stats
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One bench-target invocation: collects every case's [`BenchStats`]
/// (plus named derived ratios), honours the `--budget-ms` override, and
/// on [`BenchRun::finish`] writes the `--json PATH` document.
#[derive(Debug)]
pub struct BenchRun {
    target: String,
    budget_override: Option<Duration>,
    json_path: Option<PathBuf>,
    results: Vec<BenchStats>,
    derived: BTreeMap<String, f64>,
}

impl BenchRun {
    /// Parse `--json PATH` / `--budget-ms N` from the process arguments
    /// (everything after `cargo bench --bench <target> --` reaches the
    /// harness-free main unchanged). Unknown arguments are ignored so
    /// `cargo bench`'s own filter strings don't break the targets.
    pub fn from_args(target: &str) -> Self {
        let argv: Vec<String> = std::env::args().collect();
        Self::from_arg_slice(target, &argv[1..])
    }

    /// A run that writes straight to `path` without CLI parsing — for
    /// non-bench publishers of `hyppo-bench-v1` documents (the `hyppo
    /// simulate --json` subcommand emits its queueing metrics this way).
    pub fn to_path<P: Into<PathBuf>>(target: &str, path: Option<P>) -> Self {
        BenchRun {
            target: target.to_string(),
            budget_override: None,
            json_path: path.map(Into::into),
            results: Vec::new(),
            derived: BTreeMap::new(),
        }
    }

    /// Testable core of [`BenchRun::from_args`].
    pub fn from_arg_slice(target: &str, args: &[String]) -> Self {
        let mut run = BenchRun {
            target: target.to_string(),
            budget_override: None,
            json_path: None,
            results: Vec::new(),
            derived: BTreeMap::new(),
        };
        let mut it = args.iter();
        while let Some(a) = it.next() {
            match a.as_str() {
                "--json" => {
                    run.json_path =
                        it.next().map(PathBuf::from);
                }
                "--budget-ms" => {
                    run.budget_override = it
                        .next()
                        .and_then(|v| v.parse::<u64>().ok())
                        .map(|ms| Duration::from_millis(ms.max(1)));
                }
                _ => {}
            }
        }
        run
    }

    /// The effective measurement budget: the CLI override, else the
    /// case's own `budget`.
    fn effective(&self, budget: Duration) -> Duration {
        self.budget_override.unwrap_or(budget)
    }

    /// Benchmark with the default 1 s budget (or the CLI override).
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> BenchStats {
        self.bench_with(name, Duration::from_secs(1), f)
    }

    /// Benchmark with an explicit budget (still subject to the CLI
    /// override — the smoke job clamps *every* case).
    pub fn bench_with<F: FnMut()>(
        &mut self,
        name: &str,
        budget: Duration,
        f: F,
    ) -> BenchStats {
        let stats = bench(name, self.effective(budget), f);
        self.results.push(stats.clone());
        stats
    }

    /// Record a derived metric (e.g. a batch-vs-scalar speedup ratio)
    /// into the JSON document and echo it on stdout.
    pub fn ratio(&mut self, name: &str, value: f64) {
        println!("   {name}: {value:.1}x");
        self.derived.insert(name.to_string(), value);
    }

    /// Record a plain derived metric (no "x" suffix — queueing metrics,
    /// counts, fractions) into the JSON document and echo it on stdout.
    pub fn metric(&mut self, name: &str, value: f64) {
        println!("   {name} = {value:.4}");
        self.derived.insert(name.to_string(), value);
    }

    /// The `hyppo-bench-v1` document for this run.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert(
            "schema".into(),
            Json::Str("hyppo-bench-v1".into()),
        );
        o.insert("target".into(), Json::Str(self.target.clone()));
        o.insert("git_rev".into(), Json::Str(git_rev()));
        if let Some(b) = self.budget_override {
            o.insert(
                "budget_override_ms".into(),
                Json::Num(b.as_millis() as f64),
            );
        }
        o.insert(
            "results".into(),
            Json::Arr(self.results.iter().map(BenchStats::to_json).collect()),
        );
        o.insert(
            "derived".into(),
            Json::Obj(
                self.derived
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Num(*v)))
                    .collect(),
            ),
        );
        Json::Obj(o)
    }

    /// Write the JSON document when `--json PATH` was given. Call once,
    /// at the end of the target's `main` (including early-skip paths, so
    /// CI always has an artifact to upload).
    pub fn finish(&self) -> std::io::Result<()> {
        if let Some(path) = &self.json_path {
            let mut text = write_json(&self.to_json());
            text.push('\n');
            std::fs::write(path, text)?;
            println!("bench json -> {}", path.display());
        }
        Ok(())
    }
}

/// Short git revision for bench provenance; "unknown" outside a work
/// tree (or without a git binary, e.g. a bare CI container).
fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short=12", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let s = bench("noop-ish", Duration::from_millis(50), || {
            black_box(1u64 + 1);
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.median_ns);
        assert!(s.median_ns <= s.p95_ns * 1.0001);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }

    #[test]
    fn bench_run_parses_flags_and_writes_json() {
        let path = std::env::temp_dir().join("hyppo_bench_run_test.json");
        let args: Vec<String> = [
            "--budget-ms",
            "5",
            "--json",
            path.to_str().unwrap(),
            "somefilter",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let mut run = BenchRun::from_arg_slice("bench_test", &args);
        assert_eq!(run.budget_override, Some(Duration::from_millis(5)));
        run.bench_with("tiny", Duration::from_secs(10), || {
            black_box(3u64 * 7);
        });
        run.ratio("speedup_demo", 6.5);
        run.finish().unwrap();

        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::parse(&text).unwrap();
        assert_eq!(doc.get("schema").as_str(), Some("hyppo-bench-v1"));
        assert_eq!(doc.get("target").as_str(), Some("bench_test"));
        assert!(doc.get("git_rev").as_str().is_some());
        assert_eq!(doc.get("budget_override_ms").as_f64(), Some(5.0));
        let results = doc.get("results").as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("name").as_str(), Some("tiny"));
        assert!(results[0].get("mean_ns").as_f64().unwrap() > 0.0);
        assert_eq!(
            doc.get("derived").get("speedup_demo").as_f64(),
            Some(6.5)
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn to_path_and_metric_publish_derived_values() {
        let path = std::env::temp_dir().join("hyppo_bench_to_path_test.json");
        let mut run = BenchRun::to_path("simulate", Some(&path));
        run.metric("wasted_work_fraction", 0.25);
        run.metric("crashes", 18.0);
        run.finish().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::parse(&text).unwrap();
        assert_eq!(doc.get("schema").as_str(), Some("hyppo-bench-v1"));
        assert_eq!(doc.get("target").as_str(), Some("simulate"));
        assert_eq!(
            doc.get("derived").get("wasted_work_fraction").as_f64(),
            Some(0.25)
        );
        assert_eq!(doc.get("derived").get("crashes").as_f64(), Some(18.0));
        std::fs::remove_file(&path).ok();
        // No path: nothing written, still no error.
        BenchRun::to_path::<PathBuf>("simulate", None).finish().unwrap();
    }

    #[test]
    fn bench_run_without_json_is_quiet() {
        let run = BenchRun::from_arg_slice("t", &[]);
        assert!(run.json_path.is_none());
        assert!(run.budget_override.is_none());
        run.finish().unwrap(); // no path: nothing written, no error
    }

    #[test]
    fn bench_stats_to_json_roundtrips() {
        let s = BenchStats {
            name: "case".into(),
            iters: 10,
            mean_ns: 1.5,
            median_ns: 1.25,
            p95_ns: 2.5,
            min_ns: 1.0,
        };
        let doc =
            crate::util::json::parse(&crate::util::json::write(&s.to_json()))
                .unwrap();
        assert_eq!(doc.get("name").as_str(), Some("case"));
        assert_eq!(doc.get("iters").as_i64(), Some(10));
        assert_eq!(doc.get("median_ns").as_f64(), Some(1.25));
    }
}
