//! Micro-benchmark harness substrate (criterion is unavailable offline).
//!
//! `cargo bench` targets are declared with `harness = false` and drive this
//! module: warmup, adaptive iteration count targeting a fixed measurement
//! window, and median/mean/p95 reporting. Good enough to rank hot-path
//! changes during the §Perf pass; absolute numbers land in EXPERIMENTS.md.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    pub fn report(&self) {
        println!(
            "{:<44} {:>10} iters  mean {:>12}  median {:>12}  p95 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.min_ns),
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Benchmark `f`, automatically choosing the per-sample iteration count so
/// that total measurement time is ~`budget`.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchStats {
    // Warmup + calibration: run until we know the cost of one call.
    let cal_start = Instant::now();
    let mut cal_iters = 0u64;
    while cal_start.elapsed() < Duration::from_millis(100) {
        f();
        cal_iters += 1;
        if cal_iters > 1_000_000 {
            break;
        }
    }
    let per_call =
        cal_start.elapsed().as_nanos() as f64 / cal_iters.max(1) as f64;

    const SAMPLES: usize = 20;
    let per_sample_budget =
        budget.as_nanos() as f64 / SAMPLES as f64;
    let iters_per_sample =
        ((per_sample_budget / per_call.max(1.0)) as u64).clamp(1, 10_000_000);

    let mut samples = Vec::with_capacity(SAMPLES);
    for _ in 0..SAMPLES {
        let t = Instant::now();
        for _ in 0..iters_per_sample {
            f();
        }
        samples.push(
            t.elapsed().as_nanos() as f64 / iters_per_sample as f64,
        );
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let stats = BenchStats {
        name: name.to_string(),
        iters: iters_per_sample * SAMPLES as u64,
        mean_ns: mean,
        median_ns: samples[samples.len() / 2],
        p95_ns: samples
            [((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
        min_ns: samples[0],
    };
    stats.report();
    stats
}

/// Convenience: benchmark with the default 1s budget.
pub fn bench1<F: FnMut()>(name: &str, f: F) -> BenchStats {
    bench(name, Duration::from_secs(1), f)
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let s = bench("noop-ish", Duration::from_millis(50), || {
            black_box(1u64 + 1);
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.median_ns);
        assert!(s.median_ns <= s.p95_ns * 1.0001);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }
}
