//! Minimal property-based testing substrate (proptest is unavailable
//! offline). Seeded generation, many cases per property, and failure
//! reports that include the reproducing seed. No shrinking — failures
//! print the generated case instead.

use crate::sampling::rng::Rng;

/// Run `cases` random trials of `prop`, feeding each a fresh seeded RNG.
/// Panics with the failing case index + seed on the first failure.
pub fn forall<F: FnMut(&mut Rng) -> Result<(), String>>(
    name: &str,
    cases: usize,
    mut prop: F,
) {
    for case in 0..cases {
        let seed = 0x9e3779b97f4a7c15u64
            .wrapping_mul(case as u64 + 1)
            ^ 0xdeadbeef;
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Assert helper returning `Err` with context instead of panicking, so
/// `forall` can attach the seed.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("u64 parity", 100, |rng| {
            let v = rng.next_u64();
            prop_assert!(v % 2 == 0 || v % 2 == 1, "impossible {v}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always false'")]
    fn forall_reports_failure() {
        forall("always false", 10, |_| Err("nope".into()));
    }
}
