//! Integer hyperparameter lattice Ω (paper Eq. 2).
//!
//! Every tunable hyperparameter is an inclusive integer range; continuous
//! quantities (learning rate, dropout probability, multipliers) are encoded
//! as scaled integers by their `Evaluator` (e.g. `lr = 10^(-idx/2)`), which
//! is exactly how the paper handles its "integer lattice" formulation.

use crate::sampling::rng::Rng;

/// One hyperparameter: an inclusive integer range.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamSpec {
    pub name: String,
    pub lo: i64,
    pub hi: i64,
}

impl ParamSpec {
    pub fn new(name: &str, lo: i64, hi: i64) -> Self {
        assert!(lo <= hi, "empty range for {name}: [{lo}, {hi}]");
        ParamSpec { name: name.to_string(), lo, hi }
    }

    pub fn size(&self) -> u64 {
        (self.hi - self.lo) as u64 + 1
    }
}

/// A point on the lattice, one value per `ParamSpec` in order.
pub type Point = Vec<i64>;

/// The search space Ω.
#[derive(Debug, Clone)]
pub struct Space {
    params: Vec<ParamSpec>,
}

impl Space {
    pub fn new(params: Vec<ParamSpec>) -> Self {
        assert!(!params.is_empty(), "empty search space");
        Space { params }
    }

    pub fn dim(&self) -> usize {
        self.params.len()
    }

    pub fn params(&self) -> &[ParamSpec] {
        &self.params
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|p| p.name == name)
    }

    /// Total lattice cardinality (saturating).
    pub fn cardinality(&self) -> u64 {
        self.params
            .iter()
            .fold(1u64, |acc, p| acc.saturating_mul(p.size()))
    }

    pub fn contains(&self, x: &[i64]) -> bool {
        x.len() == self.dim()
            && x.iter()
                .zip(&self.params)
                .all(|(v, p)| *v >= p.lo && *v <= p.hi)
    }

    /// Clamp each coordinate into bounds.
    pub fn clamp(&self, x: &mut [i64]) {
        for (v, p) in x.iter_mut().zip(&self.params) {
            *v = (*v).clamp(p.lo, p.hi);
        }
    }

    /// Map a unit-cube sample to lattice cells via equal-width buckets
    /// (the integer adaptation of Sec. VI; see `sampling::lowdisc`).
    pub fn from_unit(&self, u: &[f64]) -> Point {
        assert_eq!(u.len(), self.dim());
        u.iter()
            .zip(&self.params)
            .map(|(ui, p)| {
                let cell = (ui * p.size() as f64).floor() as i64;
                (p.lo + cell).min(p.hi)
            })
            .collect()
    }

    /// Normalize a lattice point to [0,1]^d (surrogates operate here so
    /// ranges of very different magnitude contribute comparably to
    /// distances — same trick as [2]'s scaled RBF).
    pub fn to_unit(&self, x: &[i64]) -> Vec<f64> {
        x.iter()
            .zip(&self.params)
            .map(|(v, p)| {
                if p.size() == 1 {
                    0.5
                } else {
                    (v - p.lo) as f64 / (p.hi - p.lo) as f64
                }
            })
            .collect()
    }

    /// Uniform random lattice point.
    pub fn random_point(&self, rng: &mut Rng) -> Point {
        self.params
            .iter()
            .map(|p| rng.i64_in(p.lo, p.hi))
            .collect()
    }

    /// Perturb `x`: each coordinate mutates with probability `p_mut` by a
    /// discretized Gaussian step of relative scale `sigma` (at least ±1).
    /// This is the local candidate generator of the Regis-Shoemaker
    /// strategy (paper Feature 2).
    pub fn perturb(
        &self,
        x: &[i64],
        p_mut: f64,
        sigma: f64,
        rng: &mut Rng,
    ) -> Point {
        let mut out = x.to_vec();
        for (i, p) in self.params.iter().enumerate() {
            if rng.f64() < p_mut {
                let scale = (p.size() as f64 * sigma).max(1.0);
                let step = (rng.normal() * scale).round() as i64;
                let step = if step == 0 {
                    if rng.f64() < 0.5 {
                        -1
                    } else {
                        1
                    }
                } else {
                    step
                };
                out[i] = (x[i] + step).clamp(p.lo, p.hi);
            }
        }
        if out == x {
            // Mutations may have been clamped back at a boundary (or none
            // fired); guarantee at least one coordinate moves if the space
            // is not a single point.
            let movable: Vec<usize> = (0..self.dim())
                .filter(|&i| self.params[i].size() > 1)
                .collect();
            if let Some(&i) = movable
                .get(rng.usize_below(movable.len().max(1)))
                .filter(|_| !movable.is_empty())
            {
                let p = &self.params[i];
                let mut v = out[i];
                while v == out[i] {
                    v = rng.i64_in(p.lo, p.hi);
                }
                out[i] = v;
            }
        }
        out
    }

    /// Squared Euclidean distance in normalized coordinates.
    pub fn dist2(&self, a: &[i64], b: &[i64]) -> f64 {
        let ua = self.to_unit(a);
        let ub = self.to_unit(b);
        ua.iter()
            .zip(&ub)
            .map(|(x, y)| (x - y) * (x - y))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::forall;

    fn space() -> Space {
        Space::new(vec![
            ParamSpec::new("layers", 1, 3),
            ParamSpec::new("width", 0, 2),
            ParamSpec::new("lr_idx", 0, 11),
        ])
    }

    #[test]
    fn cardinality_and_contains() {
        let sp = space();
        assert_eq!(sp.cardinality(), 3 * 3 * 12);
        assert!(sp.contains(&[1, 0, 0]));
        assert!(!sp.contains(&[0, 0, 0]));
        assert!(!sp.contains(&[1, 0]));
    }

    #[test]
    fn unit_roundtrip_centers() {
        let sp = space();
        forall("to_unit/from_unit roundtrip", 200, |rng| {
            let p = sp.random_point(rng);
            let u = sp.to_unit(&p);
            // Re-quantizing the normalized point must recover a valid point
            // within one cell of the original.
            let q = sp.from_unit(&u);
            prop_assert!(sp.contains(&q), "{q:?} out of bounds");
            for ((a, b), spec) in p.iter().zip(&q).zip(sp.params()) {
                prop_assert!(
                    (a - b).abs() <= 1,
                    "{a} vs {b} in {}",
                    spec.name
                );
            }
            Ok(())
        });
    }

    #[test]
    fn perturb_stays_in_bounds_and_moves() {
        let sp = space();
        forall("perturb in-bounds", 300, |rng| {
            let p = sp.random_point(rng);
            let q = sp.perturb(&p, 0.5, 0.2, rng);
            prop_assert!(sp.contains(&q), "{q:?}");
            prop_assert!(p != q, "perturb must move: {p:?}");
            Ok(())
        });
    }

    #[test]
    fn dist2_is_metric_like() {
        let sp = space();
        forall("dist2 symmetry/identity", 200, |rng| {
            let a = sp.random_point(rng);
            let b = sp.random_point(rng);
            let dab = sp.dist2(&a, &b);
            let dba = sp.dist2(&b, &a);
            prop_assert!((dab - dba).abs() < 1e-12, "asymmetric");
            prop_assert!(sp.dist2(&a, &a) == 0.0, "nonzero self-distance");
            prop_assert!(dab >= 0.0, "negative");
            Ok(())
        });
    }

    #[test]
    fn degenerate_single_value_param() {
        let sp = Space::new(vec![
            ParamSpec::new("fixed", 5, 5),
            ParamSpec::new("free", 0, 10),
        ]);
        let mut rng = Rng::new(0);
        let p = sp.random_point(&mut rng);
        assert_eq!(p[0], 5);
        let q = sp.perturb(&p, 1.0, 0.3, &mut rng);
        assert_eq!(q[0], 5); // clamped back
        assert_eq!(sp.to_unit(&p)[0], 0.5);
    }
}
