//! Typed hyperparameter search space Ω (search-space v2).
//!
//! The paper's Eq. 2 formulates Ω as an integer lattice, which forced
//! continuous quantities (learning rate, dropout) to be smuggled in as
//! evaluator-specific scaled integers and left categoricals (optimizer
//! choice, activation) inexpressible. Search-space v2 makes the space
//! typed — [`ParamKind::Int`] keeps the exact lattice semantics (and the
//! exact RNG streams) of the v1 space, while [`ParamKind::Continuous`]
//! (optionally log-warped), [`ParamKind::Categorical`], and
//! [`ParamKind::Ordinal`] are first-class.
//!
//! All representation changes go through one place: the [`Encoding`]
//! layer (`space::encoding`, DESIGN.md §2) owns every mapping between
//! typed points, the per-parameter unit cube used by the low-discrepancy
//! samplers, and the surrogate feature space (log-warped continuous
//! coordinates, one-hot categorical blocks). `Space` re-exports thin
//! delegating methods so call sites keep reading naturally.

pub mod encoding;

pub use encoding::Encoding;

use crate::sampling::rng::Rng;

/// How many rejection draws `perturb`'s resample fallback attempts for
/// an `Int` coordinate before stepping deterministically. Bounding the
/// loop makes termination explicit; 64 misses at ≥ 1/2 success
/// probability per draw is a ≤ 2⁻⁶⁴ event, so the RNG stream is
/// unchanged versus the historical unbounded loop in practice.
const RESAMPLE_ATTEMPTS: usize = 64;

/// The type (and domain) of one hyperparameter.
#[derive(Debug, Clone, PartialEq)]
pub enum ParamKind {
    /// Inclusive integer range — the paper's Eq. 2 lattice axis.
    /// Bit-compatible with the v1 `ParamSpec {lo, hi}`.
    Int { lo: i64, hi: i64 },
    /// Real interval `[lo, hi]`. With `log = true` the parameter lives
    /// on a log scale (`lo > 0` required): sampling, perturbation, and
    /// the surrogate all see the log-warped coordinate, so e.g. a
    /// learning rate spans decades uniformly.
    Continuous { lo: f64, hi: f64, log: bool },
    /// Unordered finite choice set. Values are [`Value::Cat`] indices
    /// into `choices`; surrogates see a one-hot block (see `encoding`).
    Categorical { choices: Vec<String> },
    /// Ordered numeric levels (e.g. batch sizes `[16, 32, 64, 128]`).
    /// Values are [`Value::Int`] *indices* into `levels`; the order of
    /// the levels is meaningful to perturbation and to the surrogate.
    Ordinal { levels: Vec<f64> },
}

impl ParamKind {
    /// Number of distinct values, when finite (`None` for continuous).
    pub fn cardinality(&self) -> Option<u64> {
        match self {
            ParamKind::Int { lo, hi } => Some((hi - lo) as u64 + 1),
            ParamKind::Continuous { .. } => None,
            ParamKind::Categorical { choices } => Some(choices.len() as u64),
            ParamKind::Ordinal { levels } => Some(levels.len() as u64),
        }
    }

    /// True when only a single value is possible.
    pub fn is_fixed(&self) -> bool {
        match self {
            ParamKind::Continuous { lo, hi, .. } => lo == hi,
            other => other.cardinality() == Some(1),
        }
    }
}

/// One hyperparameter: a name plus its typed domain.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub kind: ParamKind,
}

impl ParamSpec {
    /// Integer-range parameter — the v1 constructor, kept as sugar so
    /// `ParamSpec::new("layers", 1, 3)` still means what it always did.
    pub fn new(name: &str, lo: i64, hi: i64) -> Self {
        ParamSpec::int(name, lo, hi)
    }

    /// Integer-range parameter (explicit name for the `Int` kind).
    pub fn int(name: &str, lo: i64, hi: i64) -> Self {
        assert!(lo <= hi, "empty range for {name}: [{lo}, {hi}]");
        ParamSpec { name: name.to_string(), kind: ParamKind::Int { lo, hi } }
    }

    /// Linear continuous parameter on `[lo, hi]`.
    pub fn continuous(name: &str, lo: f64, hi: f64) -> Self {
        assert!(
            lo.is_finite() && hi.is_finite() && lo <= hi,
            "bad continuous range for {name}: [{lo}, {hi}]"
        );
        ParamSpec {
            name: name.to_string(),
            kind: ParamKind::Continuous { lo, hi, log: false },
        }
    }

    /// Log-scale continuous parameter on `[lo, hi]`, `lo > 0`.
    pub fn log_continuous(name: &str, lo: f64, hi: f64) -> Self {
        assert!(
            lo.is_finite() && hi.is_finite() && 0.0 < lo && lo <= hi,
            "bad log-continuous range for {name}: [{lo}, {hi}]"
        );
        ParamSpec {
            name: name.to_string(),
            kind: ParamKind::Continuous { lo, hi, log: true },
        }
    }

    /// Categorical parameter over named choices.
    pub fn categorical(name: &str, choices: &[&str]) -> Self {
        assert!(!choices.is_empty(), "no choices for {name}");
        let choices: Vec<String> =
            choices.iter().map(|c| c.to_string()).collect();
        let mut dedup = choices.clone();
        dedup.sort();
        dedup.dedup();
        assert!(
            dedup.len() == choices.len(),
            "duplicate choices for {name}: {choices:?}"
        );
        ParamSpec {
            name: name.to_string(),
            kind: ParamKind::Categorical { choices },
        }
    }

    /// Ordinal parameter over strictly increasing numeric levels.
    pub fn ordinal(name: &str, levels: &[f64]) -> Self {
        assert!(!levels.is_empty(), "no levels for {name}");
        assert!(
            levels.windows(2).all(|w| w[0] < w[1]),
            "ordinal levels for {name} must be strictly increasing: \
             {levels:?}"
        );
        ParamSpec {
            name: name.to_string(),
            kind: ParamKind::Ordinal { levels: levels.to_vec() },
        }
    }

    /// Number of distinct values, when finite (`None` for continuous).
    pub fn cardinality(&self) -> Option<u64> {
        self.kind.cardinality()
    }

    /// True when only a single value is possible.
    pub fn is_fixed(&self) -> bool {
        self.kind.is_fixed()
    }

    /// True when `v` is a well-typed, in-bounds value for this spec.
    pub fn accepts(&self, v: &Value) -> bool {
        match (&self.kind, v) {
            (ParamKind::Int { lo, hi }, Value::Int(x)) => {
                (*lo..=*hi).contains(x)
            }
            (ParamKind::Continuous { lo, hi, .. }, Value::Float(x)) => {
                x.is_finite() && (*lo..=*hi).contains(x)
            }
            (ParamKind::Categorical { choices }, Value::Cat(i)) => {
                *i < choices.len()
            }
            (ParamKind::Ordinal { levels }, Value::Int(i)) => {
                (0..levels.len() as i64).contains(i)
            }
            _ => false,
        }
    }

    /// The natural numeric reading of `v` under this spec: the integer
    /// itself, the continuous value, the ordinal *level* (not index), or
    /// the categorical index as a float.
    pub fn numeric(&self, v: &Value) -> f64 {
        match (&self.kind, v) {
            (ParamKind::Ordinal { levels }, Value::Int(i)) => {
                levels[*i as usize]
            }
            (_, v) => v.as_f64(),
        }
    }

    /// Human-readable rendering of `v` under this spec (categorical
    /// values print their choice name, ordinals their level).
    pub fn format(&self, v: &Value) -> String {
        match (&self.kind, v) {
            (ParamKind::Categorical { choices }, Value::Cat(i)) => {
                choices[*i].clone()
            }
            (ParamKind::Ordinal { levels }, Value::Int(i)) => {
                format!("{}", levels[*i as usize])
            }
            (_, v) => format!("{v}"),
        }
    }
}

/// One typed hyperparameter value. The variant must match the parameter
/// kind at the same position of the owning [`Space`]:
///
/// * `Int` kind → `Value::Int(value)`
/// * `Continuous` kind → `Value::Float(value)`
/// * `Categorical` kind → `Value::Cat(choice_index)`
/// * `Ordinal` kind → `Value::Int(level_index)`
///
/// Equality, ordering, and hashing are total (floats compare by
/// `total_cmp` / hash by bit pattern), so points can be deduplicated and
/// sorted exactly — the optimizer's "never evaluate θ twice" logic
/// relies on this.
#[derive(Debug, Clone, Copy)]
pub enum Value {
    Int(i64),
    Float(f64),
    Cat(usize),
}

impl Value {
    /// The integer payload (`Int` value or `Ordinal` level index).
    /// Panics on other variants — use where the kind is known.
    pub fn as_i64(&self) -> i64 {
        match self {
            Value::Int(v) => *v,
            other => panic!("expected an Int value, got {other:?}"),
        }
    }

    /// The categorical choice index. Panics on other variants.
    pub fn as_index(&self) -> usize {
        match self {
            Value::Cat(i) => *i,
            other => panic!("expected a Cat value, got {other:?}"),
        }
    }

    /// A numeric reading of any variant (categoricals read as their
    /// index; ordinals as their index — see [`ParamSpec::numeric`] for
    /// the level value).
    pub fn as_f64(&self) -> f64 {
        match self {
            Value::Int(v) => *v as f64,
            Value::Float(v) => *v,
            Value::Cat(i) => *i as f64,
        }
    }

    fn rank(&self) -> u8 {
        match self {
            Value::Int(_) => 0,
            Value::Float(_) => 1,
            Value::Cat(_) => 2,
        }
    }
}

impl PartialEq for Value {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (Value::Int(a), Value::Int(b)) => a == b,
            (Value::Float(a), Value::Float(b)) => {
                a.to_bits() == b.to_bits()
            }
            (Value::Cat(a), Value::Cat(b)) => a == b,
            _ => false,
        }
    }
}

impl Eq for Value {}

impl PartialOrd for Value {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Value {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        match (self, other) {
            (Value::Int(a), Value::Int(b)) => a.cmp(b),
            (Value::Float(a), Value::Float(b)) => a.total_cmp(b),
            (Value::Cat(a), Value::Cat(b)) => a.cmp(b),
            (a, b) => a.rank().cmp(&b.rank()),
        }
    }
}

impl std::hash::Hash for Value {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        state.write_u8(self.rank());
        match self {
            Value::Int(v) => state.write_i64(*v),
            Value::Float(v) => state.write_u64(v.to_bits()),
            Value::Cat(i) => state.write_usize(*i),
        }
    }
}

impl std::fmt::Display for Value {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Value::Int(v) => write!(f, "{v}"),
            Value::Float(v) => write!(f, "{v}"),
            Value::Cat(i) => write!(f, "#{i}"),
        }
    }
}

/// A point in the search space: one typed [`Value`] per [`ParamSpec`],
/// in parameter order.
pub type Point = Vec<Value>;

/// Build an all-integer [`Point`] — handy for tests and for `Int`-only
/// (v1-style) spaces.
pub fn ints(vals: &[i64]) -> Point {
    vals.iter().map(|v| Value::Int(*v)).collect()
}

/// Render a point compactly (`[3, 0.01, #1]`); use
/// [`Space::format_point`] when choice names should appear.
pub fn format_values(p: &[Value]) -> String {
    let inner: Vec<String> = p.iter().map(|v| v.to_string()).collect();
    format!("[{}]", inner.join(", "))
}

/// The search space Ω.
#[derive(Debug, Clone)]
pub struct Space {
    params: Vec<ParamSpec>,
    encoding: Encoding,
}

impl Space {
    pub fn new(params: Vec<ParamSpec>) -> Self {
        assert!(!params.is_empty(), "empty search space");
        let encoding = Encoding::new(&params);
        Space { params, encoding }
    }

    pub fn dim(&self) -> usize {
        self.params.len()
    }

    pub fn params(&self) -> &[ParamSpec] {
        &self.params
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|p| p.name == name)
    }

    /// The encoding layer owning all representation mappings.
    pub fn encoding(&self) -> &Encoding {
        &self.encoding
    }

    /// Total number of distinct points, when finite (saturating; `None`
    /// as soon as one parameter is continuous).
    pub fn cardinality(&self) -> Option<u64> {
        self.params.iter().try_fold(1u64, |acc, p| {
            p.cardinality().map(|c| acc.saturating_mul(c))
        })
    }

    pub fn contains(&self, x: &[Value]) -> bool {
        x.len() == self.dim()
            && x.iter().zip(&self.params).all(|(v, p)| p.accepts(v))
    }

    /// Clamp each coordinate into its domain. Values must already be
    /// the right variant for their parameter kind (like the typed
    /// accessors, a mismatch is a programmer error and panics); NaN
    /// continuous coordinates clamp to the lower bound.
    pub fn clamp(&self, x: &mut [Value]) {
        for (v, p) in x.iter_mut().zip(&self.params) {
            *v = match (&p.kind, &*v) {
                (ParamKind::Int { lo, hi }, Value::Int(a)) => {
                    Value::Int((*a).clamp(*lo, *hi))
                }
                (ParamKind::Continuous { lo, hi, .. }, Value::Float(a)) => {
                    Value::Float(if a.is_nan() {
                        *lo
                    } else {
                        a.clamp(*lo, *hi)
                    })
                }
                (ParamKind::Categorical { choices }, Value::Cat(i)) => {
                    Value::Cat((*i).min(choices.len() - 1))
                }
                (ParamKind::Ordinal { levels }, Value::Int(i)) => {
                    Value::Int((*i).clamp(0, levels.len() as i64 - 1))
                }
                (kind, v) => panic!(
                    "type mismatch: {v:?} for {kind:?} parameter {}",
                    p.name
                ),
            };
        }
    }

    /// Map a unit-cube sample (one coordinate per *parameter*) to a
    /// typed point. Integer/ordinal/categorical coordinates use
    /// equal-width buckets (the integer adaptation of Sec. VI, exactly
    /// the v1 arithmetic for `Int`); continuous coordinates apply the
    /// (possibly log) warp. Delegates to [`Encoding::point_from_unit`].
    pub fn from_unit(&self, u: &[f64]) -> Point {
        self.encoding.point_from_unit(u)
    }

    /// Per-parameter unit coordinates in `[0,1]^d` (one per parameter;
    /// categorical indices are scaled nominally). Used by sampling and
    /// the synthetic landscape; surrogates use [`Space::encode`].
    /// Delegates to [`Encoding::unit`].
    pub fn to_unit(&self, x: &[Value]) -> Vec<f64> {
        self.encoding.unit(x)
    }

    /// Surrogate feature vector: unit/log-warped scalars plus one-hot
    /// categorical blocks. Delegates to [`Encoding::encode`].
    pub fn encode(&self, x: &[Value]) -> Vec<f64> {
        self.encoding.encode(x)
    }

    /// Inverse of [`Space::encode`] up to lattice rounding. Delegates to
    /// [`Encoding::decode`].
    pub fn decode(&self, feats: &[f64]) -> Point {
        self.encoding.decode(feats)
    }

    /// Uniform random point (one RNG draw per parameter, in order; the
    /// `Int` path consumes the RNG exactly as the v1 lattice did).
    pub fn random_point(&self, rng: &mut Rng) -> Point {
        self.params
            .iter()
            .map(|p| match &p.kind {
                ParamKind::Int { lo, hi } => Value::Int(rng.i64_in(*lo, *hi)),
                ParamKind::Continuous { .. } => {
                    self.encoding.value_from_unit(&p.kind, rng.f64())
                }
                ParamKind::Categorical { choices } => {
                    Value::Cat(rng.usize_below(choices.len()))
                }
                ParamKind::Ordinal { levels } => {
                    Value::Int(rng.usize_below(levels.len()) as i64)
                }
            })
            .collect()
    }

    /// Perturb `x`: each coordinate mutates with probability `p_mut` by
    /// a kind-appropriate local move of relative scale `sigma` — the
    /// local candidate generator of the Regis-Shoemaker strategy (paper
    /// Feature 2):
    ///
    /// * `Int` / `Ordinal`: discretized Gaussian step of at least ±1
    ///   cell (bit-identical to the v1 lattice for `Int`).
    /// * `Continuous`: Gaussian step of scale `sigma` in (warped) unit
    ///   coordinates.
    /// * `Categorical`: resample to a uniformly chosen *different*
    ///   choice.
    ///
    /// If no coordinate moved (nothing fired, or every step clamped
    /// back at a boundary), one uniformly chosen movable coordinate is
    /// resampled to a guaranteed-different value; if the space has no
    /// movable coordinate at all, the input is returned unchanged.
    /// Termination is explicit: every resample path is bounded.
    pub fn perturb(
        &self,
        x: &[Value],
        p_mut: f64,
        sigma: f64,
        rng: &mut Rng,
    ) -> Point {
        let mut out = x.to_vec();
        for (i, p) in self.params.iter().enumerate() {
            if rng.f64() < p_mut {
                out[i] = self.step_coord(p, &x[i], sigma, rng);
            }
        }
        if out == x {
            let movable: Vec<usize> =
                (0..self.dim()).filter(|&i| !self.params[i].is_fixed()).collect();
            if movable.is_empty() {
                // Degenerate single-point space: nothing can move.
                return out;
            }
            let i = movable[rng.usize_below(movable.len())];
            out[i] = self.resample_different(&self.params[i], &out[i], rng);
        }
        out
    }

    /// One local move of a single coordinate (the `p_mut`-gated body of
    /// [`Space::perturb`]).
    fn step_coord(
        &self,
        p: &ParamSpec,
        cur: &Value,
        sigma: f64,
        rng: &mut Rng,
    ) -> Value {
        match &p.kind {
            ParamKind::Int { lo, hi } => {
                let size = (hi - lo) as u64 + 1;
                let v = cur.as_i64();
                Value::Int(lattice_step(v, *lo, *hi, size, sigma, rng))
            }
            ParamKind::Ordinal { levels } => {
                let k = levels.len() as i64;
                let v = cur.as_i64();
                Value::Int(lattice_step(v, 0, k - 1, k as u64, sigma, rng))
            }
            ParamKind::Continuous { lo, hi, .. } => {
                if lo == hi {
                    return *cur;
                }
                let u = encoding::unit_of_loose(&p.kind, cur);
                let u2 = (u + sigma * rng.normal()).clamp(0.0, 1.0);
                self.encoding.value_from_unit(&p.kind, u2)
            }
            ParamKind::Categorical { choices } => {
                let k = choices.len();
                if k == 1 {
                    return *cur;
                }
                Value::Cat(different_index(k, cur.as_index(), rng))
            }
        }
    }

    /// Resample a coordinate to a value guaranteed different from
    /// `cur`, with bounded RNG consumption. `p` must not be fixed.
    fn resample_different(
        &self,
        p: &ParamSpec,
        cur: &Value,
        rng: &mut Rng,
    ) -> Value {
        match &p.kind {
            ParamKind::Int { lo, hi } => {
                let c = cur.as_i64();
                // Bounded rejection keeps the historical RNG stream
                // (the v1 loop was unbounded); the deterministic nudge
                // guarantees termination.
                for _ in 0..RESAMPLE_ATTEMPTS {
                    let v = rng.i64_in(*lo, *hi);
                    if v != c {
                        return Value::Int(v);
                    }
                }
                Value::Int(if c < *hi { c + 1 } else { c - 1 })
            }
            ParamKind::Ordinal { levels } => {
                let j = different_index(
                    levels.len(),
                    cur.as_i64() as usize,
                    rng,
                );
                Value::Int(j as i64)
            }
            ParamKind::Categorical { choices } => {
                Value::Cat(different_index(
                    choices.len(),
                    cur.as_index(),
                    rng,
                ))
            }
            ParamKind::Continuous { lo, hi, .. } => {
                let v = self.encoding.value_from_unit(&p.kind, rng.f64());
                if &v != cur {
                    return v;
                }
                // One-in-2⁵³ collision (or a pathological warp):
                // deterministic fallback to a bound.
                let c = match cur {
                    Value::Float(c) => *c,
                    _ => *lo,
                };
                Value::Float(if c != *lo { *lo } else { *hi })
            }
        }
    }

    /// Squared Euclidean distance in the surrogate feature space
    /// (distinct categorical choices contribute exactly 1.0; see
    /// [`Encoding`]).
    pub fn dist2(&self, a: &[Value], b: &[Value]) -> f64 {
        self.encoding.dist2(a, b)
    }

    /// Human-readable rendering with categorical choice names and
    /// ordinal levels resolved: `{layers=3, lr=0.01, opt=adam}`.
    pub fn format_point(&self, p: &[Value]) -> String {
        let inner: Vec<String> = self
            .params
            .iter()
            .zip(p)
            .map(|(spec, v)| format!("{}={}", spec.name, spec.format(v)))
            .collect();
        format!("{{{}}}", inner.join(", "))
    }
}

/// Uniform index in `[0, k)` different from `cur`, in exactly one RNG
/// draw (draw over the `k - 1` other indices, then skip past `cur`).
/// Requires `k >= 2`.
fn different_index(k: usize, cur: usize, rng: &mut Rng) -> usize {
    debug_assert!(k >= 2);
    let mut j = rng.usize_below(k - 1);
    if j >= cur {
        j += 1;
    }
    j
}

/// The v1 integer-lattice Gaussian step: scale from the cell count,
/// rounded normal step of at least ±1, clamped. Kept verbatim so `Int`
/// parameters consume the RNG exactly as the pre-v2 lattice did.
fn lattice_step(
    v: i64,
    lo: i64,
    hi: i64,
    size: u64,
    sigma: f64,
    rng: &mut Rng,
) -> i64 {
    let scale = (size as f64 * sigma).max(1.0);
    let step = (rng.normal() * scale).round() as i64;
    let step = if step == 0 {
        if rng.f64() < 0.5 {
            -1
        } else {
            1
        }
    } else {
        step
    };
    (v + step).clamp(lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::forall;

    fn space() -> Space {
        Space::new(vec![
            ParamSpec::new("layers", 1, 3),
            ParamSpec::new("width", 0, 2),
            ParamSpec::new("lr_idx", 0, 11),
        ])
    }

    fn mixed_space() -> Space {
        Space::new(vec![
            ParamSpec::int("layers", 1, 4),
            ParamSpec::log_continuous("lr", 1e-5, 1e-1),
            ParamSpec::continuous("dropout", 0.0, 0.5),
            ParamSpec::categorical("opt", &["sgd", "adam", "rmsprop"]),
            ParamSpec::ordinal("batch", &[16.0, 32.0, 64.0, 128.0]),
        ])
    }

    #[test]
    fn cardinality_and_contains() {
        let sp = space();
        assert_eq!(sp.cardinality(), Some(3 * 3 * 12));
        assert!(sp.contains(&ints(&[1, 0, 0])));
        assert!(!sp.contains(&ints(&[0, 0, 0])));
        assert!(!sp.contains(&ints(&[1, 0])));
        // Mixed spaces have no finite cardinality.
        assert_eq!(mixed_space().cardinality(), None);
    }

    #[test]
    fn contains_is_type_checked() {
        let sp = mixed_space();
        let mut rng = Rng::new(0);
        let p = sp.random_point(&mut rng);
        assert!(sp.contains(&p));
        // A float where an int belongs is rejected even if "in range".
        let mut bad = p.clone();
        bad[0] = Value::Float(2.0);
        assert!(!sp.contains(&bad));
        // A categorical index out of range is rejected.
        let mut bad = p;
        bad[3] = Value::Cat(3);
        assert!(!sp.contains(&bad));
    }

    #[test]
    fn unit_roundtrip_centers() {
        let sp = space();
        forall("to_unit/from_unit roundtrip", 200, |rng| {
            let p = sp.random_point(rng);
            let u = sp.to_unit(&p);
            // Re-quantizing the normalized point must recover a valid
            // point within one cell of the original.
            let q = sp.from_unit(&u);
            prop_assert!(sp.contains(&q), "{q:?} out of bounds");
            for ((a, b), spec) in p.iter().zip(&q).zip(sp.params()) {
                prop_assert!(
                    (a.as_i64() - b.as_i64()).abs() <= 1,
                    "{a} vs {b} in {}",
                    spec.name
                );
            }
            Ok(())
        });
    }

    #[test]
    fn perturb_stays_in_bounds_and_moves() {
        for sp in [space(), mixed_space()] {
            forall("perturb in-bounds", 300, |rng| {
                let p = sp.random_point(rng);
                let q = sp.perturb(&p, 0.5, 0.2, rng);
                prop_assert!(sp.contains(&q), "{q:?}");
                prop_assert!(p != q, "perturb must move: {p:?}");
                Ok(())
            });
        }
    }

    #[test]
    fn dist2_is_metric_like() {
        for sp in [space(), mixed_space()] {
            forall("dist2 symmetry/identity", 200, |rng| {
                let a = sp.random_point(rng);
                let b = sp.random_point(rng);
                let dab = sp.dist2(&a, &b);
                let dba = sp.dist2(&b, &a);
                prop_assert!((dab - dba).abs() < 1e-12, "asymmetric");
                prop_assert!(
                    sp.dist2(&a, &a) == 0.0,
                    "nonzero self-distance"
                );
                prop_assert!(dab >= 0.0, "negative");
                Ok(())
            });
        }
    }

    #[test]
    fn degenerate_single_value_param() {
        let sp = Space::new(vec![
            ParamSpec::new("fixed", 5, 5),
            ParamSpec::new("free", 0, 10),
        ]);
        let mut rng = Rng::new(0);
        let p = sp.random_point(&mut rng);
        assert_eq!(p[0], Value::Int(5));
        let q = sp.perturb(&p, 1.0, 0.3, &mut rng);
        assert_eq!(q[0], Value::Int(5)); // clamped back
        assert_eq!(sp.to_unit(&p)[0], 0.5);
    }

    #[test]
    fn clamp_pulls_every_kind_into_domain() {
        let sp = mixed_space();
        let mut p = vec![
            Value::Int(99),        // above hi
            Value::Float(5.0),     // above hi
            Value::Float(f64::NAN),
            Value::Cat(7),         // index past the choices
            Value::Int(-2),        // below the first level
        ];
        sp.clamp(&mut p);
        assert!(sp.contains(&p), "{p:?}");
        assert_eq!(p[0], Value::Int(4));
        assert_eq!(p[2], Value::Float(0.0)); // NaN -> lower bound
        assert_eq!(p[3], Value::Cat(2));
    }

    #[test]
    fn fully_fixed_space_perturb_returns_input() {
        // Satellite fix: no movable coordinate → early return, no
        // unbounded resample loop, no RNG panic.
        let sp = Space::new(vec![
            ParamSpec::new("a", 3, 3),
            ParamSpec::categorical("b", &["only"]),
        ]);
        let mut rng = Rng::new(1);
        let p = sp.random_point(&mut rng);
        let q = sp.perturb(&p, 1.0, 0.5, &mut rng);
        assert_eq!(p, q);
    }

    #[test]
    fn resample_fallback_always_moves_every_kind() {
        // p_mut = 0 forces the fallback path on every call.
        let sp = mixed_space();
        forall("fallback moves", 300, |rng| {
            let p = sp.random_point(rng);
            let q = sp.perturb(&p, 0.0, 0.2, rng);
            prop_assert!(p != q, "fallback did not move {p:?}");
            prop_assert!(sp.contains(&q), "{q:?}");
            // Exactly one coordinate differs.
            let moved =
                p.iter().zip(&q).filter(|(a, b)| a != b).count();
            prop_assert!(moved == 1, "moved {moved} coords");
            Ok(())
        });
    }

    #[test]
    fn value_order_and_hash_are_total() {
        use std::collections::HashSet;
        let mut vals = vec![
            Value::Float(f64::NAN),
            Value::Float(0.5),
            Value::Int(2),
            Value::Cat(1),
            Value::Float(-0.0),
            Value::Float(0.0),
        ];
        vals.sort(); // must not panic
        let set: HashSet<Value> = vals.iter().copied().collect();
        // -0.0 and 0.0 are distinct bit patterns, NaN equals itself.
        assert_eq!(set.len(), 6);
        assert_eq!(Value::Float(f64::NAN), Value::Float(f64::NAN));
    }

    #[test]
    fn format_point_resolves_names() {
        let sp = mixed_space();
        let p = vec![
            Value::Int(2),
            Value::Float(1e-3),
            Value::Float(0.25),
            Value::Cat(1),
            Value::Int(2),
        ];
        let s = sp.format_point(&p);
        assert_eq!(
            s,
            "{layers=2, lr=0.001, dropout=0.25, opt=adam, batch=64}"
        );
        assert_eq!(format_values(&ints(&[1, 2])), "[1, 2]");
    }
}
