//! The encoding layer: every representation mapping of the typed search
//! space lives here (DESIGN.md §2).
//!
//! Three representations exist, and before search-space v2 each consumer
//! re-derived its own conversions. Now they are owned in one place:
//!
//! 1. **Typed points** (`Vec<Value>`) — the API surface: what
//!    evaluators receive, what histories/checkpoints record.
//! 2. **Per-parameter unit cube** (`unit` / `point_from_unit`) — one
//!    coordinate per parameter in `[0,1]`, consumed by the
//!    low-discrepancy samplers (`sampling::lowdisc`, `sampling::sobol`)
//!    and the sensitivity analyses. Integer/ordinal/categorical
//!    parameters map through equal-width buckets — for `Int`, exactly
//!    the v1 lattice arithmetic, preserving bit-identical designs.
//! 3. **Surrogate feature space** (`encode` / `decode` / `dist2`) —
//!    what `Surrogate::fit`/`predict`, the candidate-distance scoring,
//!    and `Space::dist2` consume. Scalar kinds contribute one feature
//!    (continuous coordinates are warped, so log-scale parameters are
//!    *linear in the feature*); categoricals contribute a one-hot block
//!    scaled by `1/√2` so any two distinct choices are at squared
//!    distance exactly 1 — the same weight a full-range scalar move
//!    carries. For all-`Int` spaces the feature vector equals the unit
//!    vector, which is what keeps v2 bit-compatible with the v1
//!    surrogate stack.

use crate::space::{ParamKind, ParamSpec, Point, Value};

/// One-hot entries are scaled so two distinct categories sit at squared
/// feature distance `2 · (1/√2)² = 1`.
pub const ONE_HOT_SCALE: f64 = std::f64::consts::FRAC_1_SQRT_2;

/// The representation mapper for one [`Space`](crate::space::Space).
/// Holds only the parameter *kinds* (the domains); names and the spec
/// list itself stay in the owning `Space`.
#[derive(Debug, Clone, PartialEq)]
pub struct Encoding {
    kinds: Vec<ParamKind>,
    n_features: usize,
}

/// Unit coordinate of a value under a kind, accepting loosely-typed
/// values (used by `Space::clamp` coercion and the continuous perturb
/// path). For well-typed values this equals [`Encoding::unit`]'s entry.
pub(crate) fn unit_of_loose(kind: &ParamKind, v: &Value) -> f64 {
    match kind {
        ParamKind::Int { lo, hi } => {
            if lo == hi {
                0.5
            } else {
                (v.as_f64() - *lo as f64) / (*hi - *lo) as f64
            }
        }
        ParamKind::Continuous { lo, hi, log } => {
            if lo == hi {
                0.5
            } else if *log {
                (v.as_f64().max(*lo).ln() - lo.ln()) / (hi.ln() - lo.ln())
            } else {
                (v.as_f64() - lo) / (hi - lo)
            }
        }
        ParamKind::Categorical { choices } => {
            let k = choices.len();
            if k == 1 {
                0.5
            } else {
                v.as_f64() / (k - 1) as f64
            }
        }
        ParamKind::Ordinal { levels } => {
            let k = levels.len();
            if k == 1 {
                0.5
            } else {
                v.as_f64() / (k - 1) as f64
            }
        }
    }
}

fn feature_width(kind: &ParamKind) -> usize {
    match kind {
        ParamKind::Categorical { choices } => choices.len(),
        _ => 1,
    }
}

impl Encoding {
    pub fn new(specs: &[ParamSpec]) -> Self {
        let kinds: Vec<ParamKind> =
            specs.iter().map(|p| p.kind.clone()).collect();
        let n_features = kinds.iter().map(feature_width).sum();
        Encoding { kinds, n_features }
    }

    /// Dimension of the surrogate feature space (≥ the parameter count;
    /// equal when no parameter is categorical).
    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Per-parameter unit coordinates in `[0,1]^d` (representation 2).
    /// Degenerate single-value parameters map to `0.5` (the v1 rule),
    /// so they contribute zero to any distance.
    pub fn unit(&self, x: &[Value]) -> Vec<f64> {
        assert_eq!(x.len(), self.kinds.len());
        x.iter()
            .zip(&self.kinds)
            .map(|(v, k)| unit_of_loose(k, v))
            .collect()
    }

    /// Map per-parameter unit coordinates back to a typed point:
    /// equal-width buckets for the finite kinds (v1 arithmetic for
    /// `Int`), the (possibly log) warp for continuous.
    pub fn point_from_unit(&self, u: &[f64]) -> Point {
        assert_eq!(u.len(), self.kinds.len());
        u.iter()
            .zip(&self.kinds)
            .map(|(ui, k)| self.value_from_unit(k, *ui))
            .collect()
    }

    /// One coordinate of [`Encoding::point_from_unit`].
    pub fn value_from_unit(&self, kind: &ParamKind, u: f64) -> Value {
        match kind {
            ParamKind::Int { lo, hi } => {
                let size = (*hi - *lo) as u64 + 1;
                let cell = (u * size as f64).floor() as i64;
                Value::Int((*lo + cell).min(*hi).max(*lo))
            }
            ParamKind::Continuous { lo, hi, log } => {
                let u = u.clamp(0.0, 1.0);
                let v = if lo == hi {
                    *lo
                } else if *log {
                    (lo.ln() + u * (hi.ln() - lo.ln())).exp()
                } else {
                    lo + u * (hi - lo)
                };
                Value::Float(v.clamp(*lo, *hi))
            }
            ParamKind::Categorical { choices } => {
                let k = choices.len();
                let cell = (u * k as f64).floor().max(0.0) as usize;
                Value::Cat(cell.min(k - 1))
            }
            ParamKind::Ordinal { levels } => {
                let k = levels.len();
                let cell = (u * k as f64).floor().max(0.0) as i64;
                Value::Int(cell.min(k as i64 - 1).max(0))
            }
        }
    }

    /// Surrogate features (representation 3): scalar unit coordinates
    /// for Int/Continuous/Ordinal, a scaled one-hot block per
    /// categorical. For all-`Int` spaces this equals [`Encoding::unit`].
    pub fn encode(&self, x: &[Value]) -> Vec<f64> {
        assert_eq!(x.len(), self.kinds.len());
        let mut out = Vec::with_capacity(self.n_features);
        for (v, kind) in x.iter().zip(&self.kinds) {
            match kind {
                ParamKind::Categorical { choices } => {
                    let hot = v.as_index();
                    for i in 0..choices.len() {
                        out.push(if i == hot { ONE_HOT_SCALE } else { 0.0 });
                    }
                }
                kind => out.push(unit_of_loose(kind, v)),
            }
        }
        out
    }

    /// Inverse of [`Encoding::encode`]: scalar features round to the
    /// nearest lattice cell / clamp into the continuous range, one-hot
    /// blocks take their argmax (ties resolve to the lowest index).
    /// Exact round-trip for the finite kinds; continuous values return
    /// to within floating-point round-off of the warp.
    pub fn decode(&self, feats: &[f64]) -> Point {
        assert_eq!(feats.len(), self.n_features, "feature dim mismatch");
        let mut out = Vec::with_capacity(self.kinds.len());
        let mut i = 0;
        for kind in &self.kinds {
            match kind {
                ParamKind::Categorical { choices } => {
                    let block = &feats[i..i + choices.len()];
                    i += choices.len();
                    let best = block
                        .iter()
                        .copied()
                        .fold(f64::NEG_INFINITY, f64::max);
                    let hot =
                        block.iter().position(|v| *v == best).unwrap_or(0);
                    out.push(Value::Cat(hot));
                }
                ParamKind::Int { lo, hi } => {
                    let u = feats[i];
                    i += 1;
                    let v = *lo + (u * (*hi - *lo) as f64).round() as i64;
                    out.push(Value::Int(v.clamp(*lo, *hi)));
                }
                ParamKind::Ordinal { levels } => {
                    let u = feats[i];
                    i += 1;
                    let k = levels.len() as i64;
                    let v = (u * (k - 1) as f64).round() as i64;
                    out.push(Value::Int(v.clamp(0, k - 1)));
                }
                ParamKind::Continuous { .. } => {
                    let u = feats[i];
                    i += 1;
                    out.push(self.value_from_unit(kind, u));
                }
            }
        }
        out
    }

    /// Squared Euclidean distance in feature space. Distinct
    /// categorical choices contribute exactly `1.0` per parameter;
    /// identical choices contribute `0`.
    pub fn dist2(&self, a: &[Value], b: &[Value]) -> f64 {
        let ea = self.encode(a);
        let eb = self.encode(b);
        ea.iter().zip(&eb).map(|(x, y)| (x - y) * (x - y)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::sampling::rng::Rng;
    use crate::space::{ints, Space};
    use crate::util::prop::forall;

    fn mixed() -> Space {
        Space::new(vec![
            ParamSpec::int("layers", 1, 4),
            ParamSpec::log_continuous("lr", 1e-5, 1e-1),
            ParamSpec::continuous("dropout", 0.0, 0.5),
            ParamSpec::categorical("opt", &["sgd", "adam", "rmsprop"]),
            ParamSpec::ordinal("batch", &[16.0, 32.0, 64.0, 128.0]),
        ])
    }

    #[test]
    fn feature_dim_counts_one_hot_blocks() {
        let sp = mixed();
        assert_eq!(sp.encoding().n_features(), 4 + 3);
        assert_eq!(sp.encode(&sp.from_unit(&[0.0; 5])).len(), 7);
    }

    /// Satellite: `decode(encode(p)) == p` for all kinds. Exact for the
    /// finite kinds; continuous coordinates return to within round-off
    /// of the (possibly log) warp, which the typed equality check makes
    /// explicit via an ulp-scale tolerance.
    #[test]
    fn decode_encode_roundtrip_all_kinds() {
        let sp = mixed();
        forall("decode∘encode == id", 500, |rng| {
            let p = sp.random_point(rng);
            let q = sp.decode(&sp.encode(&p));
            for ((a, b), spec) in p.iter().zip(&q).zip(sp.params()) {
                match (a, b) {
                    (Value::Float(x), Value::Float(y)) => prop_assert!(
                        (x - y).abs()
                            <= 1e-12 * x.abs().max(y.abs()).max(1e-300),
                        "{} drifted: {x} -> {y}",
                        spec.name
                    ),
                    (a, b) => prop_assert!(
                        a == b,
                        "{} changed: {a} -> {b}",
                        spec.name
                    ),
                }
            }
            Ok(())
        });
    }

    #[test]
    fn decode_roundtrip_exact_for_finite_kinds() {
        let sp = Space::new(vec![
            ParamSpec::int("a", -3, 9),
            ParamSpec::categorical("c", &["x", "y", "z", "w"]),
            ParamSpec::ordinal("o", &[1.0, 2.0, 4.0]),
        ]);
        forall("finite kinds exact", 300, |rng| {
            let p = sp.random_point(rng);
            prop_assert!(
                sp.decode(&sp.encode(&p)) == p,
                "{p:?} not exact"
            );
            Ok(())
        });
    }

    /// Satellite: log-scale monotonicity — the feature is linear in the
    /// *exponent*, so consecutive decades are equidistant.
    #[test]
    fn log_scale_is_monotone_and_decade_uniform() {
        let spec = ParamSpec::log_continuous("lr", 1e-5, 1e-1);
        let sp = Space::new(vec![spec]);
        let f = |v: f64| sp.encode(&[Value::Float(v)])[0];
        let mut prev = f(1e-5);
        for v in [3e-5, 1e-4, 1e-3, 1e-2, 1e-1] {
            let cur = f(v);
            assert!(cur > prev, "not monotone at {v}");
            prev = cur;
        }
        let d1 = f(1e-4) - f(1e-5);
        let d2 = f(1e-3) - f(1e-4);
        let d3 = f(1e-2) - f(1e-3);
        assert!((d1 - d2).abs() < 1e-12 && (d2 - d3).abs() < 1e-12);
        assert_eq!(f(1e-5), 0.0);
        assert!((f(1e-1) - 1.0).abs() < 1e-12);
    }

    /// Satellite: one-hot block distances match `dist2` — distinct
    /// choices are at squared distance exactly 1, like a full-range
    /// scalar move.
    #[test]
    fn one_hot_distance_matches_dist2() {
        let sp = Space::new(vec![
            ParamSpec::categorical("opt", &["a", "b", "c"]),
            ParamSpec::int("w", 0, 10),
        ]);
        let p = |c: usize, w: i64| vec![Value::Cat(c), Value::Int(w)];
        assert_eq!(sp.dist2(&p(0, 5), &p(0, 5)), 0.0);
        assert!((sp.dist2(&p(0, 5), &p(1, 5)) - 1.0).abs() < 1e-12);
        assert!((sp.dist2(&p(2, 5), &p(1, 5)) - 1.0).abs() < 1e-12);
        // Full-range scalar move carries the same weight.
        assert!((sp.dist2(&p(0, 0), &p(0, 10)) - 1.0).abs() < 1e-12);
        // And the feature-space distance is what dist2 reports.
        let (a, b) = (p(0, 3), p(2, 7));
        let (ea, eb) = (sp.encode(&a), sp.encode(&b));
        let manual: f64 = ea
            .iter()
            .zip(&eb)
            .map(|(x, y)| (x - y) * (x - y))
            .sum();
        assert!((sp.dist2(&a, &b) - manual).abs() < 1e-15);
    }

    #[test]
    fn int_spaces_encode_exactly_like_v1_to_unit() {
        // For all-Int spaces the feature vector IS the unit vector —
        // the invariant that keeps the v2 surrogate stack bit-identical
        // to the v1 lattice.
        let sp = Space::new(vec![
            ParamSpec::new("a", 0, 9),
            ParamSpec::new("b", -5, 5),
            ParamSpec::new("fixed", 2, 2),
        ]);
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let p = sp.random_point(&mut rng);
            assert_eq!(sp.encode(&p), sp.to_unit(&p));
        }
        assert_eq!(sp.to_unit(&ints(&[0, -5, 2])), vec![0.0, 0.0, 0.5]);
        assert_eq!(sp.to_unit(&ints(&[9, 5, 2])), vec![1.0, 1.0, 0.5]);
    }

    #[test]
    fn one_hot_decode_takes_first_argmax() {
        let sp =
            Space::new(vec![ParamSpec::categorical("c", &["x", "y", "z"])]);
        assert_eq!(
            sp.decode(&[0.3, 0.9, 0.1]),
            vec![Value::Cat(1)]
        );
        // Ties resolve to the lowest index, deterministically.
        assert_eq!(sp.decode(&[0.5, 0.5, 0.5]), vec![Value::Cat(0)]);
    }

    #[test]
    fn unit_bucket_mapping_is_exact_for_categorical_and_ordinal() {
        let sp = Space::new(vec![
            ParamSpec::categorical("c", &["x", "y", "z"]),
            ParamSpec::ordinal("o", &[1.0, 10.0, 100.0, 1000.0]),
        ]);
        for c in 0..3usize {
            for o in 0..4i64 {
                let p = vec![Value::Cat(c), Value::Int(o)];
                assert_eq!(sp.from_unit(&sp.to_unit(&p)), p);
            }
        }
    }
}
