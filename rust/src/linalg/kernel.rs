//! Packed, register-blocked micro-kernels behind the public `linalg`
//! entry points (DESIGN.md §14).
//!
//! Every kernel here preserves the *per-element* floating-point
//! operation sequence of the scalar reference forms: each output
//! element accumulates its products in ascending-k order starting from
//! 0.0 (or subtracts them in ascending-k order from the source value,
//! for the factorizations), exactly as the naive loops do. Tiling and
//! packing only change *which* elements are in flight concurrently —
//! never the order of operations landing on any one element — so the
//! results are bit-identical to the pre-kernel implementations while
//! the independent accumulator lanes give the autovectorizer packed
//! `f64x4`-style work. Everything is stable safe Rust on plain slices:
//! no `unsafe`, no intrinsics, and (deliberately) no panic-capable
//! indexing — the whole module is written against iterators and
//! checked access so it rides under the `palint` panic-surface
//! baseline at zero.

/// Register-block rows: independent accumulator chains per micro-tile.
pub(super) const MR: usize = 4;
/// Register-block columns: contiguous lanes per accumulator row.
pub(super) const NR: usize = 8;
/// k-panel depth: one packed panel of A/B stays L1/L2-resident.
pub(super) const KC: usize = 256;
/// Interleaved right-hand sides per substitution sweep.
pub(super) const LANE: usize = 4;
/// Panel width of the blocked right-looking Cholesky.
pub(super) const CHOL_NB: usize = 64;

/// Pack `nrows` rows of row-major `src` (leading dimension `ld`),
/// columns `col0..col0+kc`, into a k-major panel: packed position
/// `kk * stride + r` holds `src[row0 + r][col0 + kk]`. `out` must be
/// zero-filled on entry; short rows/columns stay zero-padded.
fn pack_kmajor(
    src: &[f64],
    ld: usize,
    row0: usize,
    nrows: usize,
    col0: usize,
    kc: usize,
    stride: usize,
    out: &mut [f64],
) {
    for (r, row) in src
        .chunks_exact(ld)
        .skip(row0)
        .take(nrows)
        .enumerate()
    {
        for (dst, v) in out
            .iter_mut()
            .skip(r)
            .step_by(stride)
            .zip(row.iter().skip(col0).take(kc))
        {
            *dst = *v;
        }
    }
}

/// Pack `nrows` rows of row-major `src` (leading dimension `ld`),
/// columns `col0..col0+width`, into a contiguous `nrows × width` strip.
/// `out` must be zero-filled on entry; short columns stay zero-padded.
fn pack_rows(
    src: &[f64],
    ld: usize,
    row0: usize,
    nrows: usize,
    col0: usize,
    width: usize,
    out: &mut [f64],
) {
    for (dst, row) in out
        .chunks_exact_mut(width)
        .zip(src.chunks_exact(ld).skip(row0).take(nrows))
    {
        for (d, s) in dst.iter_mut().zip(row.iter().skip(col0).take(width))
        {
            *d = *s;
        }
    }
}

/// Load the valid `mr × nr` corner of the C tile at `(i0, j0)` into the
/// accumulator; padded lanes are zeroed (their values are never stored
/// back, so they only need to be finite).
fn load_tile(
    c: &[f64],
    ldc: usize,
    i0: usize,
    mr: usize,
    j0: usize,
    nr: usize,
    acc: &mut [f64; MR * NR],
) {
    acc.fill(0.0);
    for (arow, crow) in acc
        .chunks_exact_mut(NR)
        .zip(c.chunks_exact(ldc).skip(i0).take(mr))
    {
        for (d, s) in arow.iter_mut().zip(crow.iter().skip(j0).take(nr)) {
            *d = *s;
        }
    }
}

/// Store the valid `mr × nr` corner of the accumulator back to C.
fn store_tile(
    acc: &[f64; MR * NR],
    c: &mut [f64],
    ldc: usize,
    i0: usize,
    mr: usize,
    j0: usize,
    nr: usize,
) {
    for (arow, crow) in acc
        .chunks_exact(NR)
        .zip(c.chunks_exact_mut(ldc).skip(i0).take(mr))
    {
        for (d, s) in crow.iter_mut().skip(j0).take(nr).zip(arow) {
            *d = *s;
        }
    }
}

/// The register-resident inner kernel: `acc += pa · pb` where `pa` is a
/// k-major `kc × MR` panel and `pb` a row-major `kc × NR` panel. The
/// accumulator holds MR×NR independent chains, each advancing in
/// ascending-k order — the per-element sequence of the naive product.
#[inline]
fn microkernel(pa: &[f64], pb: &[f64], acc: &mut [f64; MR * NR]) {
    for (avals, bvals) in pa.chunks_exact(MR).zip(pb.chunks_exact(NR)) {
        for (arow, a) in acc.chunks_exact_mut(NR).zip(avals) {
            let av = *a;
            for (cv, b) in arow.iter_mut().zip(bvals) {
                *cv += av * *b;
            }
        }
    }
}

/// Cache-tiled `C += A · B` over zero-initialized `c` — the packed GEBP
/// drive loop. `pa`/`pb` are reusable packing buffers (any capacity).
/// Per output element the accumulation runs in ascending-k order from
/// the zero-initialized C, bit-identical to the naive triple loop.
pub(super) fn matmul_into(
    m: usize,
    k: usize,
    n: usize,
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    pa: &mut Vec<f64>,
    pb: &mut Vec<f64>,
) {
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let nstrips = (n + NR - 1) / NR;
    for k0 in (0..k).step_by(KC) {
        let kc = KC.min(k - k0);
        pb.clear();
        pb.resize(nstrips * kc * NR, 0.0);
        for (s, buf) in pb.chunks_exact_mut(kc * NR).enumerate() {
            pack_rows(b, n, k0, kc, s * NR, NR, buf);
        }
        for i0 in (0..m).step_by(MR) {
            let mr = MR.min(m - i0);
            pa.clear();
            pa.resize(kc * MR, 0.0);
            pack_kmajor(a, k, i0, mr, k0, kc, MR, pa);
            let mut acc = [0.0f64; MR * NR];
            for (s, bbuf) in pb.chunks_exact(kc * NR).enumerate() {
                let j0 = s * NR;
                let nr = NR.min(n - j0);
                load_tile(c, n, i0, mr, j0, nr, &mut acc);
                microkernel(pa, bbuf, &mut acc);
                store_tile(&acc, c, n, i0, mr, j0, nr);
            }
        }
    }
}

/// Row-blocked matrix-vector product: MR independent accumulator
/// chains share one streaming pass over `x`. Each row's chain is the
/// scalar `fold(0.0, +)` in ascending-column order — bit-identical to
/// the per-row `iter().zip().map().sum()` form.
pub(super) fn matvec_into(n: usize, a: &[f64], x: &[f64], out: &mut [f64]) {
    if n == 0 {
        for o in out.iter_mut() {
            *o = 0.0;
        }
        return;
    }
    let mfull = (out.len() / MR) * MR;
    let (amain, atail) = a.split_at(mfull * n);
    let (omain, otail) = out.split_at_mut(mfull);
    for (rows, outs) in amain
        .chunks_exact(MR * n)
        .zip(omain.chunks_exact_mut(MR))
    {
        let (r0, rest) = rows.split_at(n);
        let (r1, rest) = rest.split_at(n);
        let (r2, r3) = rest.split_at(n);
        let mut s0 = 0.0;
        let mut s1 = 0.0;
        let mut s2 = 0.0;
        let mut s3 = 0.0;
        for ((((xv, a0), a1), a2), a3) in
            x.iter().zip(r0).zip(r1).zip(r2).zip(r3)
        {
            s0 += *a0 * *xv;
            s1 += *a1 * *xv;
            s2 += *a2 * *xv;
            s3 += *a3 * *xv;
        }
        for (o, s) in outs.iter_mut().zip([s0, s1, s2, s3]) {
            *o = s;
        }
    }
    for (row, o) in atail.chunks_exact(n).zip(otail.iter_mut()) {
        *o = row.iter().zip(x).map(|(av, xv)| *av * *xv).sum();
    }
}

/// In-place forward substitution on `LANE` interleaved right-hand
/// sides: `xl` holds `n` rows of `LANE` lanes; lane `l` follows exactly
/// the scalar sequence `x[i] -= L[i][j]·x[j]` (ascending j), then — for
/// non-unit triangles — `x[i] /= L[i][i]`.
pub(super) fn forward_lanes(l: &[f64], n: usize, unit: bool, xl: &mut [f64]) {
    for (i, lrow) in l.chunks_exact(n).enumerate() {
        let (prev, rest) = xl.split_at_mut(i * LANE);
        let (xi, _) = rest.split_at_mut(LANE);
        for (c, xj) in lrow.iter().take(i).zip(prev.chunks_exact(LANE)) {
            let cv = *c;
            for (a, b) in xi.iter_mut().zip(xj) {
                *a -= cv * *b;
            }
        }
        if !unit {
            if let Some(d) = lrow.get(i) {
                let dv = *d;
                for v in xi.iter_mut() {
                    *v /= dv;
                }
            }
        }
    }
}

/// In-place backward substitution against the rows of an upper triangle
/// (the U factor of LU): lane-for-lane the scalar sequence
/// `x[i] -= U[i][j]·x[j]` (ascending j > i), then `x[i] /= U[i][i]`.
pub(super) fn backward_lanes_row(u: &[f64], n: usize, xl: &mut [f64]) {
    for (i, urow) in u.chunks_exact(n).enumerate().rev() {
        let (head, rest) = xl.split_at_mut((i + 1) * LANE);
        let (_, xi) = head.split_at_mut(i * LANE);
        for (c, xj) in urow.iter().skip(i + 1).zip(rest.chunks_exact(LANE))
        {
            let cv = *c;
            for (a, b) in xi.iter_mut().zip(xj) {
                *a -= cv * *b;
            }
        }
        if let Some(d) = urow.get(i) {
            let dv = *d;
            for v in xi.iter_mut() {
                *v /= dv;
            }
        }
    }
}

/// In-place backward substitution against the *columns* of a lower
/// triangle (`x ← L⁻ᵀ x`): lane-for-lane the scalar sequence
/// `x[i] -= L[k][i]·x[k]` (ascending k > i), then `x[i] /= L[i][i]`.
pub(super) fn backward_lanes_col(l: &[f64], n: usize, xl: &mut [f64]) {
    for i in (0..n).rev() {
        let (head, rest) = xl.split_at_mut((i + 1) * LANE);
        let (_, xi) = head.split_at_mut(i * LANE);
        for (krow, xk) in
            l.chunks_exact(n).skip(i + 1).zip(rest.chunks_exact(LANE))
        {
            if let Some(c) = krow.get(i) {
                let cv = *c;
                for (a, b) in xi.iter_mut().zip(xk) {
                    *a -= cv * *b;
                }
            }
        }
        if let Some(drow) = l.chunks_exact(n).nth(i) {
            if let Some(d) = drow.get(i) {
                let dv = *d;
                for v in xi.iter_mut() {
                    *v /= dv;
                }
            }
        }
    }
}

/// Factor the `kb × kb` diagonal block at `(k0, k0)` of the in-place
/// lower factor, using the classic unblocked recurrence restricted to
/// panel columns: subtractions for columns `< k0` were already applied
/// by earlier trailing updates, so the per-element total order of
/// subtractions is the full ascending-k sequence of the unblocked
/// algorithm. Returns `false` (not positive definite) on the same
/// diagonal values the unblocked form rejects.
fn factor_diag(n: usize, l: &mut [f64], k0: usize, kb: usize) -> bool {
    for i in k0..k0 + kb {
        let (head, tail) = l.split_at_mut(i * n);
        let (irow, _) = tail.split_at_mut(n);
        for (j, jrow) in head.chunks_exact(n).enumerate().skip(k0) {
            let dot = j - k0;
            let Some(&start) = irow.get(j) else {
                return false;
            };
            let mut v = start;
            for (a, b) in irow
                .iter()
                .skip(k0)
                .take(dot)
                .zip(jrow.iter().skip(k0).take(dot))
            {
                v -= *a * *b;
            }
            let Some(&dj) = jrow.get(j) else {
                return false;
            };
            v /= dj;
            if let Some(slot) = irow.get_mut(j) {
                *slot = v;
            }
        }
        let dot = i - k0;
        let Some(&start) = irow.get(i) else {
            return false;
        };
        let mut v = start;
        for a in irow.iter().skip(k0).take(dot) {
            v -= *a * *a;
        }
        if v <= 0.0 {
            return false;
        }
        let root = v.sqrt();
        if let Some(slot) = irow.get_mut(i) {
            *slot = root;
        }
    }
    true
}

/// Solve the panel below the diagonal block: for every row `i ≥ k0+kb`
/// and panel column `j`, apply the scalar recurrence
/// `v = L[i][j] - Σ L[i][kk]·L[j][kk]` (kk ascending in the panel) and
/// divide by the freshly factored `L[j][j]`.
fn panel_solve(n: usize, l: &mut [f64], k0: usize, kb: usize) {
    let (top, bottom) = l.split_at_mut((k0 + kb) * n);
    for irow in bottom.chunks_exact_mut(n) {
        for (j, jrow) in top.chunks_exact(n).enumerate().skip(k0) {
            let dot = j - k0;
            let Some(&start) = irow.get(j) else {
                continue;
            };
            let mut v = start;
            for (a, b) in irow
                .iter()
                .skip(k0)
                .take(dot)
                .zip(jrow.iter().skip(k0).take(dot))
            {
                v -= *a * *b;
            }
            if let Some(&dj) = jrow.get(j) {
                v /= dj;
            }
            if let Some(slot) = irow.get_mut(j) {
                *slot = v;
            }
        }
    }
}

/// Rank-`kb` trailing update `C -= P·Pᵀ` over the lower triangle, run
/// through the packed micro-kernel with a negated A panel: per element
/// `x + (-a)·b` is bit-identical to `x - a·b` in IEEE-754, and the kk
/// order within the panel is ascending, so the total subtraction order
/// matches the unblocked recurrence. Tiles strictly above the diagonal
/// are skipped; the straddling tiles' upper lanes hold scratch that the
/// factorization never reads and `cholesky_in_place` zeroes at the end.
fn trailing_update(
    n: usize,
    l: &mut [f64],
    k0: usize,
    kb: usize,
    pa: &mut Vec<f64>,
    pb: &mut Vec<f64>,
) {
    let r0 = k0 + kb;
    if r0 >= n {
        return;
    }
    let t = n - r0;
    let nstrips = (t + NR - 1) / NR;
    pb.clear();
    pb.resize(nstrips * kb * NR, 0.0);
    for (s, buf) in pb.chunks_exact_mut(kb * NR).enumerate() {
        let nr = NR.min(t - s * NR);
        pack_kmajor(l, n, r0 + s * NR, nr, k0, kb, NR, buf);
    }
    for i0 in (0..t).step_by(MR) {
        let mr = MR.min(t - i0);
        pa.clear();
        pa.resize(kb * MR, 0.0);
        pack_kmajor(l, n, r0 + i0, mr, k0, kb, MR, pa);
        for v in pa.iter_mut() {
            *v = -*v;
        }
        let mut acc = [0.0f64; MR * NR];
        for (s, bbuf) in pb.chunks_exact(kb * NR).enumerate() {
            let j0 = s * NR;
            if j0 > i0 + MR - 1 {
                break;
            }
            let nr = NR.min(t - j0);
            load_tile(l, n, r0 + i0, mr, r0 + j0, nr, &mut acc);
            microkernel(pa, bbuf, &mut acc);
            store_tile(&acc, l, n, r0 + i0, mr, r0 + j0, nr);
        }
    }
}

/// Blocked right-looking Cholesky on the row-major `n × n` buffer `l`
/// (entered holding A): factor a `CHOL_NB`-wide diagonal block, solve
/// the panel below it, then down-date the trailing submatrix through
/// the packed micro-kernel, and repeat. Returns `false` when A is not
/// positive definite — on the same diagonal value as the unblocked
/// form, since every intermediate is bit-identical. On success the
/// strict upper triangle is zeroed.
pub(super) fn cholesky_in_place(
    n: usize,
    l: &mut [f64],
    pa: &mut Vec<f64>,
    pb: &mut Vec<f64>,
) -> bool {
    let mut k0 = 0;
    while k0 < n {
        let kb = CHOL_NB.min(n - k0);
        if !factor_diag(n, l, k0, kb) {
            return false;
        }
        panel_solve(n, l, k0, kb);
        trailing_update(n, l, k0, kb, pa, pb);
        k0 += kb;
    }
    for (i, row) in l.chunks_exact_mut(n).enumerate() {
        for v in row.iter_mut().skip(i + 1) {
            *v = 0.0;
        }
    }
    true
}
