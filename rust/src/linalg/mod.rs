//! Dense linear-algebra substrate (no external crates offline).
//!
//! Provides exactly what the surrogates need: row-major `Mat`, LU with
//! partial pivoting (the RBF saddle system of Eq. 10 is symmetric but
//! *indefinite*, so Cholesky does not apply), and Cholesky for the SPD
//! Gaussian-process covariances.

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows)
            .map(|i| {
                self.row(i)
                    .iter()
                    .zip(x)
                    .map(|(a, b)| a * b)
                    .sum()
            })
            .collect()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Reusable LU factorization (partial pivoting) of a square matrix.
///
/// Factor once with [`lu_factor`], then [`LuFactors::solve`] any number
/// of right-hand sides in O(n²) each — this is what makes [`invert`]
/// O(n³) overall instead of O(n⁴).
#[derive(Debug, Clone)]
pub struct LuFactors {
    n: usize,
    /// Packed L (unit diagonal, below) and U (on/above diagonal) of PA.
    lu: Vec<f64>,
    /// Row permutation: `perm[k]` is the original row now at position k.
    perm: Vec<usize>,
}

/// LU-factor `A` with partial pivoting. Returns `None` when `A` is
/// numerically singular (pivot below 1e-13).
pub fn lu_factor(a: &Mat) -> Option<LuFactors> {
    assert_eq!(a.rows, a.cols, "lu_factor needs a square matrix");
    let n = a.rows;
    let mut lu = a.data.clone();
    let mut perm: Vec<usize> = (0..n).collect();

    for k in 0..n {
        // Pivot search.
        let mut p = k;
        let mut max = lu[k * n + k].abs();
        for i in (k + 1)..n {
            let v = lu[i * n + k].abs();
            if v > max {
                max = v;
                p = i;
            }
        }
        if max < 1e-13 {
            return None;
        }
        if p != k {
            for j in 0..n {
                lu.swap(k * n + j, p * n + j);
            }
            perm.swap(k, p);
        }
        let pivot = lu[k * n + k];
        for i in (k + 1)..n {
            let f = lu[i * n + k] / pivot;
            lu[i * n + k] = f;
            for j in (k + 1)..n {
                lu[i * n + j] -= f * lu[k * n + j];
            }
        }
    }
    Some(LuFactors { n, lu, perm })
}

impl LuFactors {
    /// Solve `A x = b` using the stored factors (O(n²)).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n;
        assert_eq!(b.len(), n);
        // Apply the row permutation, then forward/back substitution.
        let mut x: Vec<f64> = self.perm.iter().map(|&p| b[p]).collect();
        for i in 0..n {
            for j in 0..i {
                x[i] -= self.lu[i * n + j] * x[j];
            }
        }
        for i in (0..n).rev() {
            for j in (i + 1)..n {
                x[i] -= self.lu[i * n + j] * x[j];
            }
            x[i] /= self.lu[i * n + i];
        }
        x
    }
}

/// LU decomposition with partial pivoting; solves `A x = b`.
/// Returns `None` when `A` is numerically singular.
pub fn lu_solve(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    assert_eq!(b.len(), a.rows);
    Some(lu_factor(a)?.solve(b))
}

/// Dense inverse via LU: one factorization plus n unit-vector solves.
/// Returns `None` when `A` is numerically singular.
pub fn invert(a: &Mat) -> Option<Mat> {
    let n = a.rows;
    let f = lu_factor(a)?;
    let mut inv = Mat::zeros(n, n);
    let mut e = vec![0.0; n];
    for j in 0..n {
        e[j] = 1.0;
        let col = f.solve(&e);
        e[j] = 0.0;
        for i in 0..n {
            inv[(i, j)] = col[i];
        }
    }
    Some(inv)
}

/// Cholesky factorization of an SPD matrix: returns lower-triangular `L`
/// with `A = L L^T`, or `None` if not positive definite.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[(i, j)];
            for k in 0..j {
                sum -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[(i, j)] = sum.sqrt();
            } else {
                l[(i, j)] = sum / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Solve `L y = b` (forward) then `L^T x = y` (backward).
pub fn cholesky_solve(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut y = b.to_vec();
    for i in 0..n {
        for k in 0..i {
            y[i] -= l[(i, k)] * y[k];
        }
        y[i] /= l[(i, i)];
    }
    for i in (0..n).rev() {
        for k in (i + 1)..n {
            y[i] -= l[(k, i)] * y[k];
        }
        y[i] /= l[(i, i)];
    }
    y
}

/// Solve only the forward half `L y = b` (used for GP variance terms).
pub fn forward_solve(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut y = b.to_vec();
    for i in 0..n {
        for k in 0..i {
            y[i] -= l[(i, k)] * y[k];
        }
        y[i] /= l[(i, i)];
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::sampling::rng::Rng;
    use crate::util::prop::forall;

    fn random_mat(n: usize, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(n, n);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    #[test]
    fn lu_solves_random_systems() {
        forall("LU residual small", 50, |rng| {
            let n = 2 + rng.usize_below(14);
            let a = random_mat(n, rng);
            let xtrue: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.matvec(&xtrue);
            let x = lu_solve(&a, &b)
                .ok_or_else(|| "singular".to_string())?;
            for (xi, ti) in x.iter().zip(&xtrue) {
                prop_assert!(
                    (xi - ti).abs() < 1e-7 * (1.0 + ti.abs()),
                    "{xi} vs {ti}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn lu_detects_singular() {
        let a = Mat::from_rows(&[
            vec![1.0, 2.0],
            vec![2.0, 4.0],
        ]);
        assert!(lu_solve(&a, &[1.0, 2.0]).is_none());
    }

    #[test]
    fn lu_handles_permutation_matrix() {
        // Zero diagonal forces pivoting.
        let a = Mat::from_rows(&[
            vec![0.0, 1.0],
            vec![1.0, 0.0],
        ]);
        let x = lu_solve(&a, &[3.0, 4.0]).unwrap();
        assert_eq!(x, vec![4.0, 3.0]);
    }

    #[test]
    fn cholesky_roundtrip_spd() {
        forall("cholesky reconstructs SPD", 40, |rng| {
            let n = 2 + rng.usize_below(10);
            let g = random_mat(n, rng);
            // A = G G^T + n I is SPD.
            let mut a = Mat::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0;
                    for k in 0..n {
                        s += g[(i, k)] * g[(j, k)];
                    }
                    a[(i, j)] = s + if i == j { n as f64 } else { 0.0 };
                }
            }
            let l = cholesky(&a).ok_or("not SPD?".to_string())?;
            let xtrue: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.matvec(&xtrue);
            let x = cholesky_solve(&l, &b);
            for (xi, ti) in x.iter().zip(&xtrue) {
                prop_assert!((xi - ti).abs() < 1e-7, "{xi} vs {ti}");
            }
            Ok(())
        });
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(&[
            vec![1.0, 2.0],
            vec![2.0, 1.0],
        ]);
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn matvec_identity() {
        let i3 = Mat::eye(3);
        assert_eq!(i3.matvec(&[1.0, 2.0, 3.0]), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn lu_factors_reusable_across_rhs() {
        forall("LU factors solve many rhs", 20, |rng| {
            let n = 2 + rng.usize_below(10);
            let a = random_mat(n, rng);
            let Some(f) = lu_factor(&a) else {
                return Ok(()); // singular by chance
            };
            for _ in 0..3 {
                let xtrue: Vec<f64> =
                    (0..n).map(|_| rng.normal()).collect();
                let b = a.matvec(&xtrue);
                let x = f.solve(&b);
                for (xi, ti) in x.iter().zip(&xtrue) {
                    prop_assert!(
                        (xi - ti).abs() < 1e-7 * (1.0 + ti.abs()),
                        "{xi} vs {ti}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn invert_times_matrix_is_identity() {
        forall("A * inv(A) = I", 20, |rng| {
            let n = 2 + rng.usize_below(8);
            let a = random_mat(n, rng);
            let Some(inv) = invert(&a) else {
                return Ok(());
            };
            // Check A·inv column-wise: A * inv[:,j] = e_j.
            for j in 0..n {
                let col: Vec<f64> = (0..n).map(|i| inv[(i, j)]).collect();
                let e = a.matvec(&col);
                for (i, v) in e.iter().enumerate() {
                    let want = if i == j { 1.0 } else { 0.0 };
                    prop_assert!(
                        (v - want).abs() < 1e-7,
                        "({i},{j}): {v}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn invert_rejects_singular() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(invert(&a).is_none());
        assert!(lu_factor(&a).is_none());
    }
}
