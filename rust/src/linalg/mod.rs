//! Dense linear-algebra substrate (no external crates offline).
//!
//! Provides exactly what the surrogates need: row-major `Mat`, LU with
//! partial pivoting (the RBF saddle system of Eq. 10 is symmetric but
//! *indefinite*, so Cholesky does not apply), and Cholesky for the SPD
//! Gaussian-process covariances.
//!
//! The batched proposal path (DESIGN.md §11) additionally relies on the
//! allocation-free variants: every solver has an `_into` form writing
//! into caller-owned buffers, factorizations expose multi-RHS
//! `solve_many`, and [`Workspace`] pools scratch buffers so a whole
//! candidate batch is scored without per-point heap traffic. All `_into`
//! and `_many` forms perform the identical floating-point operation
//! sequence as their scalar counterparts — callers may mix them freely
//! without perturbing results by a single ULP.
//!
//! The heavy loops (`matmul`, `matvec_into`, the `_many` substitution
//! sweeps, and the blocked Cholesky) execute inside the packed
//! micro-kernel layer of [`kernel`] (DESIGN.md §14), which preserves
//! the per-element operation order of the scalar forms exactly — the
//! tiling is a throughput change, never a numerical one.

mod kernel;

/// Pool of reusable `Vec<f64>` scratch buffers for the batched hot path.
///
/// `take` hands out a zeroed buffer of the requested length, reusing a
/// previously `give`n allocation when one is available: a whole
/// candidate batch is scored with O(1) buffer allocations (amortized to
/// zero while a workspace is kept alive across calls) instead of the
/// per-candidate heap traffic of the scalar path. The pool is
/// deliberately type-dumb (plain `Vec<f64>`s) so one workspace serves
/// correlation rows, solve buffers, score vectors, and — via
/// [`Workspace::take_mat`] — whole factor/RHS matrices alike.
///
/// The pool also meters itself: every byte of *capacity growth* that a
/// `take` forces (a fresh allocation, or a reused buffer resized past
/// its capacity) accumulates in [`Workspace::alloc_bytes`], so callers
/// like `RefitStats` can prove a steady-state refit loop stopped
/// touching the heap instead of assuming it.
#[derive(Debug, Default, Clone)]
pub struct Workspace {
    pool: Vec<Vec<f64>>,
    alloc_bytes: u64,
}

impl Workspace {
    /// An empty pool; buffers are created on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// Borrow a zero-filled buffer of length `len`.
    pub fn take(&mut self, len: usize) -> Vec<f64> {
        let mut b = self.pool.pop().unwrap_or_default();
        let cap0 = b.capacity();
        b.clear();
        b.resize(len, 0.0);
        if b.capacity() > cap0 {
            self.alloc_bytes += ((b.capacity() - cap0)
                * std::mem::size_of::<f64>()) as u64;
        }
        b
    }

    /// Return a buffer to the pool for later reuse.
    pub fn give(&mut self, buf: Vec<f64>) {
        self.pool.push(buf);
    }

    /// Borrow a zero-filled `rows × cols` matrix backed by the pool.
    pub fn take_mat(&mut self, rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: self.take(rows * cols) }
    }

    /// Return a matrix's backing buffer to the pool.
    pub fn give_mat(&mut self, m: Mat) {
        self.give(m.data);
    }

    /// Total bytes of capacity growth forced through this pool so far.
    pub fn alloc_bytes(&self) -> u64 {
        self.alloc_bytes
    }

    /// Read and reset the allocation meter (per-refit accounting).
    pub fn take_alloc_bytes(&mut self) -> u64 {
        std::mem::take(&mut self.alloc_bytes)
    }
}

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.rows];
        self.matvec_into(x, &mut out);
        out
    }

    /// Matrix-vector product into a caller-owned buffer (no allocation).
    /// Identical accumulation order to [`Mat::matvec`]: the row-blocked
    /// kernel keeps one sequential ascending-column chain per row.
    pub fn matvec_into(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        kernel::matvec_into(self.cols, &self.data, x, out);
    }

    /// Cache-tiled matrix-matrix product `self · other` through the
    /// packed register-blocked micro-kernel ([`kernel`], DESIGN.md §14).
    /// Per output element the products accumulate in ascending-k order
    /// from 0.0 — bit-identical to the naive triple loop and to the
    /// earlier blocked form this replaces.
    pub fn matmul(&self, other: &Mat) -> Mat {
        let mut ws = Workspace::new();
        self.matmul_ws(other, &mut ws)
    }

    /// [`Mat::matmul`] with packing buffers and the output drawn from a
    /// caller-owned [`Workspace`] (steady-state: zero heap traffic).
    /// Same operation sequence as `matmul`.
    pub fn matmul_ws(&self, other: &Mat, ws: &mut Workspace) -> Mat {
        assert_eq!(
            self.cols, other.rows,
            "matmul dimension mismatch: {}x{} · {}x{}",
            self.rows, self.cols, other.rows, other.cols
        );
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = ws.take_mat(m, n);
        let mut pa = ws.take(0);
        let mut pb = ws.take(0);
        kernel::matmul_into(
            m, k, n, &self.data, &other.data, &mut out.data, &mut pa,
            &mut pb,
        );
        ws.give(pa);
        ws.give(pb);
        out
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Reusable LU factorization (partial pivoting) of a square matrix.
///
/// Factor once with [`lu_factor`], then [`LuFactors::solve`] any number
/// of right-hand sides in O(n²) each — this is what makes [`invert`]
/// O(n³) overall instead of O(n⁴).
#[derive(Debug, Clone)]
pub struct LuFactors {
    n: usize,
    /// Packed L (unit diagonal, below) and U (on/above diagonal) of PA.
    lu: Vec<f64>,
    /// Row permutation: `perm[k]` is the original row now at position k.
    perm: Vec<usize>,
}

/// LU-factor `A` with partial pivoting. Returns `None` when `A` is
/// numerically singular (pivot below 1e-13).
pub fn lu_factor(a: &Mat) -> Option<LuFactors> {
    assert_eq!(a.rows, a.cols, "lu_factor needs a square matrix");
    let n = a.rows;
    let mut lu = a.data.clone();
    let mut perm: Vec<usize> = (0..n).collect();

    for k in 0..n {
        // Pivot search.
        let mut p = k;
        let mut max = lu[k * n + k].abs();
        for i in (k + 1)..n {
            let v = lu[i * n + k].abs();
            if v > max {
                max = v;
                p = i;
            }
        }
        if max < 1e-13 {
            return None;
        }
        if p != k {
            for j in 0..n {
                lu.swap(k * n + j, p * n + j);
            }
            perm.swap(k, p);
        }
        let pivot = lu[k * n + k];
        for i in (k + 1)..n {
            let f = lu[i * n + k] / pivot;
            lu[i * n + k] = f;
            for j in (k + 1)..n {
                lu[i * n + j] -= f * lu[k * n + j];
            }
        }
    }
    Some(LuFactors { n, lu, perm })
}

impl LuFactors {
    /// Solve `A x = b` using the stored factors (O(n²)).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = Vec::new();
        self.solve_into(b, &mut x);
        x
    }

    /// [`LuFactors::solve`] into a caller-owned buffer (no allocation
    /// when `x` has capacity). Same operation sequence as `solve`.
    pub fn solve_into(&self, b: &[f64], x: &mut Vec<f64>) {
        assert_eq!(b.len(), self.n);
        // Apply the row permutation, then forward/back substitution.
        x.clear();
        x.extend(self.perm.iter().map(|&p| b[p]));
        self.substitute(x);
    }

    /// Solve `A X = B` for every column of `B` over the one stored
    /// factorization (multi-RHS, O(n²) per column). Columns run through
    /// the lane-interleaved substitution kernel four at a time — the
    /// per-column operation sequence is exactly [`LuFactors::solve`]'s.
    pub fn solve_many(&self, b: &Mat) -> Mat {
        let mut ws = Workspace::new();
        self.solve_many_ws(b, &mut ws)
    }

    /// [`LuFactors::solve_many`] with all scratch (and the output
    /// matrix) drawn from a caller-owned [`Workspace`]. Same operation
    /// sequence.
    pub fn solve_many_ws(&self, b: &Mat, ws: &mut Workspace) -> Mat {
        let n = self.n;
        assert_eq!(b.rows, n, "solve_many needs n-row right-hand sides");
        let mut out = ws.take_mat(n, b.cols);
        let mut lanes = ws.take(n * kernel::LANE);
        for j0 in (0..b.cols).step_by(kernel::LANE) {
            for (row_lanes, &p) in
                lanes.chunks_exact_mut(kernel::LANE).zip(&self.perm)
            {
                let brow = b.row(p);
                for (l, slot) in row_lanes.iter_mut().enumerate() {
                    *slot =
                        brow.get(j0 + l).copied().unwrap_or(0.0);
                }
            }
            kernel::forward_lanes(&self.lu, n, true, &mut lanes);
            kernel::backward_lanes_row(&self.lu, n, &mut lanes);
            for (row_lanes, orow) in lanes
                .chunks_exact(kernel::LANE)
                .zip(out.data.chunks_exact_mut(b.cols))
            {
                for (dst, src) in orow
                    .iter_mut()
                    .skip(j0)
                    .take(kernel::LANE)
                    .zip(row_lanes)
                {
                    *dst = *src;
                }
            }
        }
        ws.give(lanes);
        out
    }

    /// Hand the factorization's backing buffer back to a workspace pool
    /// (the permutation vector is dropped; it is integer-typed and
    /// small). Lets steady-state refit loops factor → solve → recycle
    /// without net heap traffic.
    pub fn recycle(self, ws: &mut Workspace) {
        ws.give(self.lu);
    }

    /// Forward/back substitution on an already-permuted vector.
    fn substitute(&self, x: &mut [f64]) {
        let n = self.n;
        for i in 0..n {
            for j in 0..i {
                x[i] -= self.lu[i * n + j] * x[j];
            }
        }
        for i in (0..n).rev() {
            for j in (i + 1)..n {
                x[i] -= self.lu[i * n + j] * x[j];
            }
            x[i] /= self.lu[i * n + i];
        }
    }
}

/// LU decomposition with partial pivoting; solves `A x = b`.
/// Returns `None` when `A` is numerically singular.
pub fn lu_solve(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    assert_eq!(b.len(), a.rows);
    Some(lu_factor(a)?.solve(b))
}

/// Dense inverse via LU: one factorization plus an n-column multi-RHS
/// solve against the identity. Returns `None` when `A` is numerically
/// singular.
pub fn invert(a: &Mat) -> Option<Mat> {
    let mut ws = Workspace::new();
    invert_ws(a, &mut ws)
}

/// [`invert`] with the factorization scratch, identity RHS, and output
/// all drawn from a caller-owned [`Workspace`] — the steady-state
/// incremental-refit path allocates nothing here once the pool is warm.
/// Same operation sequence as `invert`.
pub fn invert_ws(a: &Mat, ws: &mut Workspace) -> Option<Mat> {
    let f = lu_factor(a)?;
    let n = a.rows;
    let mut eye = ws.take_mat(n, n);
    for (i, row) in eye.data.chunks_exact_mut(n).enumerate() {
        if let Some(d) = row.get_mut(i) {
            *d = 1.0;
        }
    }
    let out = f.solve_many_ws(&eye, ws);
    ws.give_mat(eye);
    f.recycle(ws);
    Some(out)
}

/// Cholesky factorization of an SPD matrix: returns lower-triangular `L`
/// with `A = L L^T`, or `None` if not positive definite. Runs the
/// blocked right-looking algorithm of [`kernel::cholesky_in_place`];
/// every intermediate — including the rejection point for indefinite
/// input — is bit-identical to the classic unblocked recurrence.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    let mut ws = Workspace::new();
    cholesky_ws(a, &mut ws)
}

/// [`cholesky`] with the factor and packing scratch drawn from a
/// caller-owned [`Workspace`]. Same operation sequence.
pub fn cholesky_ws(a: &Mat, ws: &mut Workspace) -> Option<Mat> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = ws.take_mat(n, n);
    l.data.copy_from_slice(&a.data);
    let mut pa = ws.take(0);
    let mut pb = ws.take(0);
    let ok = kernel::cholesky_in_place(n, &mut l.data, &mut pa, &mut pb);
    ws.give(pa);
    ws.give(pb);
    if ok {
        Some(l)
    } else {
        ws.give_mat(l);
        None
    }
}

/// Solve `L y = b` (forward) then `L^T x = y` (backward).
pub fn cholesky_solve(l: &Mat, b: &[f64]) -> Vec<f64> {
    let mut y = Vec::new();
    cholesky_solve_into(l, b, &mut y);
    y
}

/// [`cholesky_solve`] into a caller-owned buffer (no allocation when
/// `y` has capacity). Same operation sequence.
pub fn cholesky_solve_into(l: &Mat, b: &[f64], y: &mut Vec<f64>) {
    assert_eq!(b.len(), l.rows);
    y.clear();
    y.extend_from_slice(b);
    forward_substitute(l, y);
    backward_substitute(l, y);
}

/// Solve `L L^T X = B` for every column of `B` over one Cholesky factor
/// (multi-RHS). Columns run through the lane-interleaved substitution
/// kernel four at a time; the per-column operation sequence is exactly
/// [`cholesky_solve`]'s.
pub fn cholesky_solve_many(l: &Mat, b: &Mat) -> Mat {
    let mut ws = Workspace::new();
    cholesky_solve_many_ws(l, b, &mut ws)
}

/// [`cholesky_solve_many`] with scratch and output drawn from a
/// caller-owned [`Workspace`]. Same operation sequence.
pub fn cholesky_solve_many_ws(l: &Mat, b: &Mat, ws: &mut Workspace) -> Mat {
    let n = l.rows;
    assert_eq!(b.rows, n, "cholesky_solve_many needs n-row RHS");
    let mut out = ws.take_mat(n, b.cols);
    let mut lanes = ws.take(n * kernel::LANE);
    for j0 in (0..b.cols).step_by(kernel::LANE) {
        for (row_lanes, brow) in lanes
            .chunks_exact_mut(kernel::LANE)
            .zip(b.data.chunks_exact(b.cols))
        {
            for (lidx, slot) in row_lanes.iter_mut().enumerate() {
                *slot = brow.get(j0 + lidx).copied().unwrap_or(0.0);
            }
        }
        kernel::forward_lanes(&l.data, n, false, &mut lanes);
        kernel::backward_lanes_col(&l.data, n, &mut lanes);
        for (row_lanes, orow) in lanes
            .chunks_exact(kernel::LANE)
            .zip(out.data.chunks_exact_mut(b.cols))
        {
            for (dst, src) in orow
                .iter_mut()
                .skip(j0)
                .take(kernel::LANE)
                .zip(row_lanes)
            {
                *dst = *src;
            }
        }
    }
    ws.give(lanes);
    out
}

/// Solve only the forward half `L y = b` (used for GP variance terms).
pub fn forward_solve(l: &Mat, b: &[f64]) -> Vec<f64> {
    let mut y = Vec::new();
    forward_solve_into(l, b, &mut y);
    y
}

/// [`forward_solve`] into a caller-owned buffer — the per-candidate
/// variance solve of the batched GP path runs through this with one
/// [`Workspace`] buffer for the whole candidate set.
pub fn forward_solve_into(l: &Mat, b: &[f64], y: &mut Vec<f64>) {
    assert_eq!(b.len(), l.rows);
    y.clear();
    y.extend_from_slice(b);
    forward_substitute(l, y);
}

/// In-place forward substitution `y ← L⁻¹ y`.
fn forward_substitute(l: &Mat, y: &mut [f64]) {
    let n = l.rows;
    for i in 0..n {
        for k in 0..i {
            y[i] -= l[(i, k)] * y[k];
        }
        y[i] /= l[(i, i)];
    }
}

/// In-place backward substitution `y ← L⁻ᵀ y`.
fn backward_substitute(l: &Mat, y: &mut [f64]) {
    let n = l.rows;
    for i in (0..n).rev() {
        for k in (i + 1)..n {
            y[i] -= l[(k, i)] * y[k];
        }
        y[i] /= l[(i, i)];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::sampling::rng::Rng;
    use crate::util::prop::forall;

    fn random_mat(n: usize, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(n, n);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    #[test]
    fn lu_solves_random_systems() {
        forall("LU residual small", 50, |rng| {
            let n = 2 + rng.usize_below(14);
            let a = random_mat(n, rng);
            let xtrue: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.matvec(&xtrue);
            let x = lu_solve(&a, &b)
                .ok_or_else(|| "singular".to_string())?;
            for (xi, ti) in x.iter().zip(&xtrue) {
                prop_assert!(
                    (xi - ti).abs() < 1e-7 * (1.0 + ti.abs()),
                    "{xi} vs {ti}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn lu_detects_singular() {
        let a = Mat::from_rows(&[
            vec![1.0, 2.0],
            vec![2.0, 4.0],
        ]);
        assert!(lu_solve(&a, &[1.0, 2.0]).is_none());
    }

    #[test]
    fn lu_handles_permutation_matrix() {
        // Zero diagonal forces pivoting.
        let a = Mat::from_rows(&[
            vec![0.0, 1.0],
            vec![1.0, 0.0],
        ]);
        let x = lu_solve(&a, &[3.0, 4.0]).unwrap();
        assert_eq!(x, vec![4.0, 3.0]);
    }

    #[test]
    fn cholesky_roundtrip_spd() {
        forall("cholesky reconstructs SPD", 40, |rng| {
            let n = 2 + rng.usize_below(10);
            let g = random_mat(n, rng);
            // A = G G^T + n I is SPD.
            let mut a = Mat::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0;
                    for k in 0..n {
                        s += g[(i, k)] * g[(j, k)];
                    }
                    a[(i, j)] = s + if i == j { n as f64 } else { 0.0 };
                }
            }
            let l = cholesky(&a).ok_or("not SPD?".to_string())?;
            let xtrue: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.matvec(&xtrue);
            let x = cholesky_solve(&l, &b);
            for (xi, ti) in x.iter().zip(&xtrue) {
                prop_assert!((xi - ti).abs() < 1e-7, "{xi} vs {ti}");
            }
            Ok(())
        });
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(&[
            vec![1.0, 2.0],
            vec![2.0, 1.0],
        ]);
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn matvec_identity() {
        let i3 = Mat::eye(3);
        assert_eq!(i3.matvec(&[1.0, 2.0, 3.0]), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn lu_factors_reusable_across_rhs() {
        forall("LU factors solve many rhs", 20, |rng| {
            let n = 2 + rng.usize_below(10);
            let a = random_mat(n, rng);
            let Some(f) = lu_factor(&a) else {
                return Ok(()); // singular by chance
            };
            for _ in 0..3 {
                let xtrue: Vec<f64> =
                    (0..n).map(|_| rng.normal()).collect();
                let b = a.matvec(&xtrue);
                let x = f.solve(&b);
                for (xi, ti) in x.iter().zip(&xtrue) {
                    prop_assert!(
                        (xi - ti).abs() < 1e-7 * (1.0 + ti.abs()),
                        "{xi} vs {ti}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn invert_times_matrix_is_identity() {
        forall("A * inv(A) = I", 20, |rng| {
            let n = 2 + rng.usize_below(8);
            let a = random_mat(n, rng);
            let Some(inv) = invert(&a) else {
                return Ok(());
            };
            // Check A·inv column-wise: A * inv[:,j] = e_j.
            for j in 0..n {
                let col: Vec<f64> = (0..n).map(|i| inv[(i, j)]).collect();
                let e = a.matvec(&col);
                for (i, v) in e.iter().enumerate() {
                    let want = if i == j { 1.0 } else { 0.0 };
                    prop_assert!(
                        (v - want).abs() < 1e-7,
                        "({i},{j}): {v}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn invert_rejects_singular() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(invert(&a).is_none());
        assert!(lu_factor(&a).is_none());
    }

    #[test]
    fn matvec_into_is_bitwise_matvec() {
        forall("matvec_into == matvec", 30, |rng| {
            let n = 1 + rng.usize_below(20);
            let a = random_mat(n, rng);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut out = vec![f64::NAN; n];
            a.matvec_into(&x, &mut out);
            let want = a.matvec(&x);
            for (o, w) in out.iter().zip(&want) {
                prop_assert!(o.to_bits() == w.to_bits(), "{o} vs {w}");
            }
            Ok(())
        });
    }

    #[test]
    fn matmul_matches_naive_product_bitwise() {
        forall("matmul == naive", 25, |rng| {
            let (m, k, n) = (
                1 + rng.usize_below(70),
                1 + rng.usize_below(70),
                1 + rng.usize_below(70),
            );
            let mut a = Mat::zeros(m, k);
            let mut b = Mat::zeros(k, n);
            for v in a.data.iter_mut() {
                *v = rng.normal();
            }
            for v in b.data.iter_mut() {
                *v = rng.normal();
            }
            let c = a.matmul(&b);
            // Naive triple loop in the same ascending-k accumulation
            // order the blocked kernel guarantees per output element.
            for i in 0..m {
                for j in 0..n {
                    let want: f64 = (0..k)
                        .map(|kk| a[(i, kk)] * b[(kk, j)])
                        .sum();
                    prop_assert!(
                        c[(i, j)].to_bits() == want.to_bits(),
                        "({i},{j}): {} vs {want}",
                        c[(i, j)]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn solve_many_is_bitwise_columnwise_solve() {
        forall("solve_many == per-column solve", 25, |rng| {
            let n = 2 + rng.usize_below(12);
            let a = random_mat(n, rng);
            let Some(f) = lu_factor(&a) else {
                return Ok(());
            };
            let ncols = 1 + rng.usize_below(5);
            let mut b = Mat::zeros(n, ncols);
            for v in b.data.iter_mut() {
                *v = rng.normal();
            }
            let many = f.solve_many(&b);
            for j in 0..ncols {
                let col: Vec<f64> = (0..n).map(|i| b[(i, j)]).collect();
                let x = f.solve(&col);
                for (i, xi) in x.iter().enumerate() {
                    prop_assert!(
                        many[(i, j)].to_bits() == xi.to_bits(),
                        "({i},{j}): {} vs {xi}",
                        many[(i, j)]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cholesky_solve_many_is_bitwise_columnwise() {
        forall("cholesky_solve_many == per-column", 25, |rng| {
            let n = 2 + rng.usize_below(10);
            let g = random_mat(n, rng);
            let mut a = Mat::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0;
                    for k in 0..n {
                        s += g[(i, k)] * g[(j, k)];
                    }
                    a[(i, j)] = s + if i == j { n as f64 } else { 0.0 };
                }
            }
            let l = cholesky(&a).ok_or("not SPD?".to_string())?;
            let ncols = 1 + rng.usize_below(4);
            let mut b = Mat::zeros(n, ncols);
            for v in b.data.iter_mut() {
                *v = rng.normal();
            }
            let many = cholesky_solve_many(&l, &b);
            for j in 0..ncols {
                let col: Vec<f64> = (0..n).map(|i| b[(i, j)]).collect();
                let x = cholesky_solve(&l, &col);
                let mut fwd = Vec::new();
                forward_solve_into(&l, &col, &mut fwd);
                let fwd_ref = forward_solve(&l, &col);
                for (i, xi) in x.iter().enumerate() {
                    prop_assert!(
                        many[(i, j)].to_bits() == xi.to_bits(),
                        "({i},{j}): {} vs {xi}",
                        many[(i, j)]
                    );
                    prop_assert!(
                        fwd[i].to_bits() == fwd_ref[i].to_bits(),
                        "forward_solve_into diverged at {i}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn workspace_reuses_allocations_and_zeroes() {
        let mut ws = Workspace::new();
        let mut a = ws.take(8);
        a.iter_mut().for_each(|v| *v = 7.0);
        let cap = a.capacity();
        let ptr = a.as_ptr();
        ws.give(a);
        let b = ws.take(4);
        assert_eq!(b.len(), 4);
        assert!(b.iter().all(|v| *v == 0.0), "stale data leaked");
        assert_eq!(b.as_ptr(), ptr, "allocation was not reused");
        assert!(b.capacity() >= cap.min(8));
        // A second take while the first is out must still work.
        let c = ws.take(16);
        assert_eq!(c.len(), 16);
        ws.give(b);
        ws.give(c);
    }
}
