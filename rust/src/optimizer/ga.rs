//! Integer-aware genetic algorithm (paper Feature 2: "we maximize the
//! expected improvement auxiliary function using a genetic algorithm that
//! can handle the integer constraints").
//!
//! Plain generational GA: tournament selection, uniform crossover,
//! `Space::perturb` mutation (which respects the lattice by construction),
//! elitism of 1. Generic over the fitness function so the same machinery
//! maximizes EI for the GP surrogate and is reused by tests.
//!
//! Fitness is evaluated **a generation at a time** (`&[Point] ->
//! Vec<f64>`): the EI consumer scores the whole population through the
//! batched surrogate API (one cross-correlation block per generation,
//! optionally fanned over threads) instead of point-at-a-time calls.
//! Fitness evaluation consumes no RNG, so the batch rewrite leaves the
//! evolution stream — and therefore every proposal — bit-identical.

use crate::sampling::rng::Rng;
use crate::space::{Point, Space, Value};

/// Genetic-algorithm knobs (defaults reproduce the paper's setting).
#[derive(Debug, Clone)]
pub struct GaConfig {
    /// Individuals per generation.
    pub population: usize,
    /// Generations to evolve.
    pub generations: usize,
    /// Tournament size for parent selection.
    pub tournament: usize,
    /// Probability of uniform crossover (vs cloning a parent).
    pub p_crossover: f64,
    /// Per-coordinate mutation probability.
    pub p_mutate_coord: f64,
    /// Mutation scale as a fraction of each coordinate's range.
    pub sigma: f64,
}

impl Default for GaConfig {
    fn default() -> Self {
        GaConfig {
            population: 40,
            generations: 30,
            tournament: 3,
            p_crossover: 0.9,
            p_mutate_coord: 0.3,
            sigma: 0.15,
        }
    }
}

/// Maximize a **batch** fitness over the space; returns (best point,
/// best fitness). The closure scores one whole generation per call and
/// must return one value per point, each independent of the batch
/// composition (the determinism contract of DESIGN.md §11).
pub fn maximize<F: FnMut(&[Point]) -> Vec<f64>>(
    space: &Space,
    cfg: &GaConfig,
    rng: &mut Rng,
    mut fitness: F,
) -> (Point, f64) {
    assert!(cfg.population >= 2);
    let mut pop: Vec<Point> = (0..cfg.population)
        .map(|_| space.random_point(rng))
        .collect();
    let mut fit = fitness(&pop);
    assert_eq!(
        fit.len(),
        pop.len(),
        "batch fitness must score every individual"
    );

    let best_idx = |fit: &[f64]| {
        (0..fit.len())
            .max_by(|&a, &b| fit[a].total_cmp(&fit[b]))
            .unwrap()
    };

    for _gen in 0..cfg.generations {
        let elite = best_idx(&fit);
        let mut next: Vec<Point> = vec![pop[elite].clone()];
        while next.len() < cfg.population {
            let a = tournament(&fit, cfg.tournament, rng);
            let b = tournament(&fit, cfg.tournament, rng);
            let mut child = if rng.f64() < cfg.p_crossover {
                crossover(&pop[a], &pop[b], rng)
            } else {
                pop[a].clone()
            };
            if rng.f64() < 0.9 {
                child =
                    space.perturb(&child, cfg.p_mutate_coord, cfg.sigma, rng);
            }
            next.push(child);
        }
        pop = next;
        fit = fitness(&pop);
        assert_eq!(
            fit.len(),
            pop.len(),
            "batch fitness must score every individual"
        );
    }
    let i = best_idx(&fit);
    (pop[i].clone(), fit[i])
}

/// Scalar-fitness convenience over [`maximize`] (tests, simple
/// acquisition functions): wraps the per-point closure in a mapped
/// batch, which is exactly what the pre-batch GA computed.
pub fn maximize_scalar<F: FnMut(&[Value]) -> f64>(
    space: &Space,
    cfg: &GaConfig,
    rng: &mut Rng,
    mut fitness: F,
) -> (Point, f64) {
    maximize(space, cfg, rng, |pop| {
        pop.iter().map(|p| fitness(p)).collect()
    })
}

fn tournament(fit: &[f64], k: usize, rng: &mut Rng) -> usize {
    let mut best = rng.usize_below(fit.len());
    for _ in 1..k {
        let c = rng.usize_below(fit.len());
        if fit[c] > fit[best] {
            best = c;
        }
    }
    best
}

fn crossover(a: &[Value], b: &[Value], rng: &mut Rng) -> Point {
    a.iter()
        .zip(b)
        .map(|(x, y)| if rng.f64() < 0.5 { *x } else { *y })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::space::ParamSpec;
    use crate::util::prop::forall;

    fn space() -> Space {
        Space::new(vec![
            ParamSpec::new("a", 0, 31),
            ParamSpec::new("b", 0, 31),
            ParamSpec::new("c", 0, 31),
        ])
    }

    #[test]
    fn finds_unique_global_maximum() {
        use crate::space::ints;
        let sp = space();
        let target = [7i64, 21, 13];
        let mut rng = Rng::new(1);
        let (best, f) = maximize_scalar(&sp, &GaConfig::default(), &mut rng, |p| {
            -p.iter()
                .zip(&target)
                .map(|(x, t)| {
                    let d = x.as_i64() - t;
                    (d * d) as f64
                })
                .sum::<f64>()
        });
        assert_eq!(f, 0.0, "best {best:?}");
        assert_eq!(best, ints(&target));
    }

    #[test]
    fn results_stay_on_lattice() {
        let sp = space();
        forall("GA in-bounds", 10, |rng| {
            let (best, _) =
                maximize_scalar(&sp, &GaConfig { generations: 5, ..Default::default() }, rng, |p| {
                    p[0].as_f64()
                });
            prop_assert!(sp.contains(&best), "{best:?}");
            Ok(())
        });
    }

    #[test]
    fn monotone_fitness_pushes_to_boundary() {
        let sp = space();
        let mut rng = Rng::new(3);
        let (best, _) = maximize_scalar(&sp, &GaConfig::default(), &mut rng, |p| {
            p[0].as_f64() + p[1].as_f64() + p[2].as_f64()
        });
        assert_eq!(best, crate::space::ints(&[31, 31, 31]));
    }

    #[test]
    fn batch_and_scalar_fitness_evolve_identically() {
        // Same seed, same fitness function expressed both ways: the GA
        // consumes the RNG identically, so the full outcome matches.
        let sp = space();
        let f = |p: &[Value]| -(p[0].as_f64() - 11.0).powi(2)
            + 0.3 * p[1].as_f64();
        let (a_pt, a_fit) =
            maximize_scalar(&sp, &GaConfig::default(), &mut Rng::new(42), f);
        let (b_pt, b_fit) =
            maximize(&sp, &GaConfig::default(), &mut Rng::new(42), |pop| {
                pop.iter().map(|p| f(p)).collect()
            });
        assert_eq!(a_pt, b_pt);
        assert_eq!(a_fit.to_bits(), b_fit.to_bits());
    }

    #[test]
    fn elitism_never_regresses() {
        let sp = space();
        let mut rng = Rng::new(4);
        // Track the best fitness after every generation by re-running with
        // increasing generation counts (deterministic RNG per run).
        let fit_at = |gens: usize| {
            let mut r = Rng::new(99);
            let (_, f) = maximize_scalar(
                &sp,
                &GaConfig { generations: gens, ..Default::default() },
                &mut r,
                |p| {
                    let d = p[0].as_i64() - 13;
                    -((d * d) as f64)
                },
            );
            f
        };
        let _ = &mut rng;
        assert!(fit_at(8) >= fit_at(2) - 1e-12);
    }
}
