//! Persistent surrogate state for the asynchronous executor.
//!
//! The seed implementation refit its surrogate **from scratch** after
//! every completion — an O(n³) stall on the coordinator that serializes
//! exactly the path the paper parallelizes (Fig. 6). `OnlineProposer`
//! keeps one surrogate alive across the whole experiment and absorbs each
//! completion with `Surrogate::fit_incremental` (O(n²)), falling back to
//! a full refit only when the incremental update declines (singular
//! extension, drifted inverse) or when the GP is due for a length-scale
//! retune. `propose_next` routes the one-shot sequential path through the
//! same code, so the candidate-search and acquisition logic exists once.

use std::collections::HashSet;

use crate::baselines::forest::{Forest, ForestConfig};
use crate::linalg::Workspace;
use crate::optimizer::candidates::{self, WEIGHT_CYCLE};
use crate::optimizer::ga::{maximize, GaConfig};
use crate::optimizer::{EvalRecord, History, HpoConfig, SurrogateKind};
use crate::sampling::rng::Rng;
use crate::space::{Point, Space};
use crate::surrogate::ensemble::RbfEnsemble;
use crate::surrogate::gp::{expected_improvement, GpSurrogate};
use crate::surrogate::rbf::RbfSurrogate;
use crate::surrogate::scaling::{self, ScalingConfig, ScalingMode};
use crate::surrogate::Surrogate;
use crate::uq::LossInterval;
use crate::util::par::par_chunks_stable;

/// Retune the GP length-scale (full profile-likelihood refit) after this
/// many incremental insertions.
const GP_RETUNE_EVERY: usize = 25;

/// Counters distinguishing cheap incremental refits from full refits —
/// surfaced by `hyppo run` and asserted on in the executor tests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RefitStats {
    /// O(n²) rank-1 / bordered updates absorbed.
    pub incremental: u64,
    /// O(n³) from-scratch fits (initial fit, fallbacks, GP retunes).
    pub full: u64,
    /// Proposals served.
    pub proposals: u64,
    /// Candidate sets that came back short after exhausting their
    /// attempt budget (small / nearly-explored spaces; surfaced by
    /// `hyppo run` instead of warning to stderr per occurrence).
    pub exhausted_candidate_sets: u64,
    /// Bytes of *new* scratch capacity the refit workspace had to grow
    /// by, cumulative. After warm-up this should stay flat — growth per
    /// refit means an allocation leaked past the `Workspace` pool
    /// (the PR 8 asymmetry bug made visible; see DESIGN.md §14).
    pub refit_alloc_bytes: u64,
    /// Exact→scaled regime transitions (0 or 1 per study: the handoff
    /// latch is one-way).
    pub handoffs: u64,
    /// Observations evicted from the surrogate training mirror after
    /// the handoff (the executor `History` is never evicted).
    pub evicted: u64,
    /// Proposals served by the scaled regime (subset-GP or forest).
    pub scaled_fits: u64,
}

/// A surrogate that lives across completions, plus the acquisition logic
/// that turns it into the next point to evaluate.
#[derive(Debug, Clone)]
pub struct OnlineProposer {
    kind: SurrogateKind,
    gamma: f64,
    candidates: candidates::CandidateConfig,
    rbf: RbfSurrogate,
    gp: GpSurrogate,
    /// Encoded feature vectors / objectives mirroring the history, in
    /// the order `observe` saw them (the surrogate's training set; see
    /// `space::Encoding` for the feature layout).
    xs: Vec<Vec<f64>>,
    ys: Vec<f64>,
    /// Model must be fully refitted before the next proposal.
    dirty: bool,
    inserts_since_tune: usize,
    stats: RefitStats,
    /// Observation budgets; inert until the mirror outgrows
    /// `scaling.max_exact_n` (see `surrogate::scaling`).
    scaling: ScalingConfig,
    /// One-way latch: once the mirror exceeds the exact budget the
    /// study stays in the scaled regime (re-entering the exact path
    /// after evictions would silently change its training set).
    handed_off: bool,
    /// Study seed, used to derive deterministic seeds for the scaled
    /// regime (forest refits).
    seed: u64,
    /// Pooled linear-algebra scratch threaded through every refit so
    /// steady-state updates do no heap traffic (DESIGN.md §14).
    ws: Workspace,
}

impl OnlineProposer {
    /// Fresh proposer for a run configured by `cfg`.
    pub fn new(cfg: &HpoConfig) -> Self {
        OnlineProposer {
            kind: cfg.surrogate.clone(),
            gamma: cfg.gamma,
            candidates: cfg.candidates.clone(),
            rbf: RbfSurrogate::new(),
            gp: GpSurrogate::new(),
            xs: Vec::new(),
            ys: Vec::new(),
            dirty: true,
            inserts_since_tune: 0,
            stats: RefitStats::default(),
            scaling: cfg.scaling,
            handed_off: false,
            seed: cfg.seed,
            ws: Workspace::new(),
        }
    }

    /// Rebuild the training mirror from an existing history (bulk load:
    /// one full refit at the next proposal instead of n incremental
    /// updates). Used by `propose_next` and by checkpoint resume.
    pub fn preload(&mut self, space: &Space, history: &History) {
        self.xs.clear();
        self.ys.clear();
        for r in &history.records {
            self.xs.push(space.encode(&r.theta));
            self.ys.push(r.objective(self.gamma));
        }
        self.dirty = true;
        // A resumed study past the exact budget re-enters the scaled
        // regime immediately (the latch is part of derived state, not
        // the checkpoint); `stats.handoffs` only counts live
        // transitions, so it stays 0 here.
        if self.xs.len() > self.scaling.max_exact_n {
            self.handed_off = true;
            self.enforce_history_cap();
        }
    }

    /// Evict the surrogate mirror down to the configured history cap
    /// (scaled regime only; the exact regime never evicts).
    fn enforce_history_cap(&mut self) {
        let dropped = scaling::evict_mirror(
            &mut self.xs,
            &mut self.ys,
            self.scaling.effective_max_history(),
        );
        if dropped > 0 {
            self.stats.evicted += dropped as u64;
            self.dirty = true;
        }
    }

    /// Absorb one completed evaluation. Incremental (O(n²)) when the
    /// active surrogate supports it, otherwise the model is marked dirty
    /// and the next `propose` pays one full refit.
    pub fn observe(&mut self, space: &Space, record: &EvalRecord) {
        let x = space.encode(&record.theta);
        let y = record.objective(self.gamma);
        self.xs.push(x.clone());
        self.ys.push(y);
        if !self.handed_off && self.xs.len() > self.scaling.max_exact_n {
            // One-way handoff: the exact incremental state is abandoned
            // and every subsequent proposal is served by the scaled
            // regime (`propose_scaled`).
            self.handed_off = true;
            self.stats.handoffs += 1;
            self.dirty = true;
        }
        if self.handed_off {
            self.enforce_history_cap();
            // Scaled regimes refit per proposal; per-completion O(n²)
            // updates against an evicted mirror would drift.
            self.dirty = true;
            return;
        }
        match self.kind {
            SurrogateKind::Rbf => {
                if !self.dirty
                    && self.rbf.is_fitted()
                    && self.rbf.fit_incremental_ws(&x, y, &mut self.ws)
                {
                    self.stats.incremental += 1;
                } else {
                    self.dirty = true;
                }
            }
            SurrogateKind::Gp => {
                self.inserts_since_tune += 1;
                if !self.dirty
                    && self.gp.is_fitted()
                    && self.inserts_since_tune < GP_RETUNE_EVERY
                    && self.gp.fit_incremental_ws(&x, y, &mut self.ws)
                {
                    self.stats.incremental += 1;
                } else {
                    self.dirty = true;
                }
            }
            // The CI-extreme ensemble resamples its members around fresh
            // confidence intervals at every proposal; there is no
            // persistent model to update.
            SurrogateKind::RbfEnsemble { .. } => {}
        }
        self.stats.refit_alloc_bytes += self.ws.take_alloc_bytes();
    }

    /// Refit counters accumulated so far.
    pub fn stats(&self) -> RefitStats {
        self.stats
    }

    /// Propose the next point to evaluate. `iter` indexes the adaptive
    /// phase (for the exploitation/exploration weight cycle).
    pub fn propose(
        &mut self,
        space: &Space,
        history: &History,
        iter: usize,
        rng: &mut Rng,
    ) -> Point {
        self.stats.proposals += 1;
        if self.handed_off {
            return self.propose_scaled(space, history, iter, rng);
        }
        let evaluated = history.points();
        let fallback = |rng: &mut Rng| {
            let mut p = space.random_point(rng);
            let mut guard = 0;
            while evaluated.contains(&p) && guard < 1000 {
                p = space.random_point(rng);
                guard += 1;
            }
            p
        };

        match &self.kind {
            SurrogateKind::Rbf => {
                if self.dirty || !self.rbf.is_fitted() {
                    self.stats.full += 1;
                    let ok =
                        self.rbf.fit_ws(&self.xs, &self.ys, &mut self.ws);
                    self.stats.refit_alloc_bytes +=
                        self.ws.take_alloc_bytes();
                    if !ok {
                        return fallback(rng);
                    }
                    self.dirty = false;
                }
                let best = &history.best(self.gamma).unwrap().theta;
                let gen = candidates::generate(
                    space,
                    best,
                    &evaluated,
                    &self.candidates,
                    rng,
                );
                if gen.exhausted {
                    self.stats.exhausted_candidate_sets += 1;
                }
                let cands = gen.points;
                if cands.is_empty() {
                    return fallback(rng);
                }
                // Batched scoring: encode once (fanned out too), then
                // score deterministic candidate chunks — each chunk
                // pays one kernel block instead of per-point rebuilds.
                let threads = self.candidates.scoring_threads;
                let encoded: Vec<Vec<f64>> =
                    par_chunks_stable(&cands, threads, |chunk| {
                        chunk.iter().map(|c| space.encode(c)).collect()
                    });
                let rbf = &self.rbf;
                let values: Vec<f64> =
                    par_chunks_stable(&encoded, threads, |chunk| {
                        let mut ws = Workspace::new();
                        let mut out = Vec::new();
                        rbf.predict_batch(chunk, &mut ws, &mut out);
                        out
                    });
                let w = WEIGHT_CYCLE[iter % WEIGHT_CYCLE.len()];
                match candidates::select_encoded(
                    space, &encoded, &values, &evaluated, w, threads,
                ) {
                    Some(i) => cands[i].clone(),
                    None => fallback(rng),
                }
            }
            SurrogateKind::Gp => {
                if self.dirty || !self.gp.is_fitted() {
                    self.stats.full += 1;
                    self.inserts_since_tune = 0;
                    let ok =
                        self.gp.fit_ws(&self.xs, &self.ys, &mut self.ws);
                    self.stats.refit_alloc_bytes +=
                        self.ws.take_alloc_bytes();
                    if !ok {
                        return fallback(rng);
                    }
                    self.dirty = false;
                }
                let best_y = self
                    .ys
                    .iter()
                    .cloned()
                    .fold(f64::INFINITY, f64::min);
                let gp = &self.gp;
                let threads = self.candidates.scoring_threads;
                let evaluated_set: HashSet<&Point> =
                    evaluated.iter().collect();
                // Batched EI over each GA generation: one
                // cross-correlation block per chunk amortizes mean, std,
                // and EI; already-evaluated points are excluded exactly
                // as before (their mean/std is computed but unused, so
                // the surviving scores are bit-identical).
                let (point, _fit) = maximize(
                    space,
                    &GaConfig::default(),
                    rng,
                    |pop| {
                        par_chunks_stable(pop, threads, |chunk| {
                            let mut ws = Workspace::new();
                            let encoded: Vec<Vec<f64>> = chunk
                                .iter()
                                .map(|p| space.encode(p))
                                .collect();
                            let mut mu = Vec::new();
                            let mut sd = Vec::new();
                            gp.predict_mean_std_batch(
                                &encoded, &mut ws, &mut mu, &mut sd,
                            );
                            chunk
                                .iter()
                                .enumerate()
                                .map(|(i, p)| {
                                    if evaluated_set.contains(p) {
                                        f64::NEG_INFINITY
                                    } else {
                                        expected_improvement(
                                            mu[i], sd[i], best_y,
                                        )
                                    }
                                })
                                .collect()
                        })
                    },
                );
                if evaluated_set.contains(&point) {
                    fallback(rng)
                } else {
                    point
                }
            }
            SurrogateKind::RbfEnsemble { alpha, members } => {
                let intervals: Vec<LossInterval> = history
                    .records
                    .iter()
                    .map(|r| LossInterval {
                        center: r.objective(self.gamma),
                        radius: r.summary.interval.radius,
                    })
                    .collect();
                let mut ens = RbfEnsemble::new(*members, *alpha);
                self.stats.full += 1;
                if !ens.fit(&self.xs, &intervals, rng) {
                    return fallback(rng);
                }
                let best = &history.best(self.gamma).unwrap().theta;
                let gen = candidates::generate(
                    space,
                    best,
                    &evaluated,
                    &self.candidates,
                    rng,
                );
                if gen.exhausted {
                    self.stats.exhausted_candidate_sets += 1;
                }
                let cands = gen.points;
                if cands.is_empty() {
                    return fallback(rng);
                }
                // Eq. (8): score = μ + ασ, batched so every member
                // predicts the whole chunk once, then the distance
                // trade-off. Encoding fans out like the scoring does.
                let threads = self.candidates.scoring_threads;
                let encoded: Vec<Vec<f64>> =
                    par_chunks_stable(&cands, threads, |chunk| {
                        chunk.iter().map(|c| space.encode(c)).collect()
                    });
                let ens_ref = &ens;
                let values: Vec<f64> =
                    par_chunks_stable(&encoded, threads, |chunk| {
                        let mut ws = Workspace::new();
                        let mut out = Vec::new();
                        ens_ref.score_batch(chunk, &mut ws, &mut out);
                        out
                    });
                let w = WEIGHT_CYCLE[iter % WEIGHT_CYCLE.len()];
                match candidates::select_encoded(
                    space, &encoded, &values, &evaluated, w, threads,
                ) {
                    Some(i) => cands[i].clone(),
                    None => fallback(rng),
                }
            }
        }
    }

    /// Proposal service once the study has outgrown the exact budget
    /// (`surrogate::scaling`, DESIGN.md §14). `Subset` refits the GP on
    /// `max_exact_n` deterministic landmarks and maximizes EI with the
    /// integer GA; `Forest` fits the extra-trees surrogate on the whole
    /// (evicted) mirror and scores Regis–Shoemaker candidates by the
    /// forest mean. Seeded-deterministic, but *not* bit-compatible with
    /// the unbounded exact path — that guarantee stops at the handoff.
    fn propose_scaled(
        &mut self,
        space: &Space,
        history: &History,
        iter: usize,
        rng: &mut Rng,
    ) -> Point {
        self.stats.scaled_fits += 1;
        let evaluated = history.points();
        let fallback = |rng: &mut Rng| {
            let mut p = space.random_point(rng);
            let mut guard = 0;
            while evaluated.contains(&p) && guard < 1000 {
                p = space.random_point(rng);
                guard += 1;
            }
            p
        };
        match self.scaling.mode {
            ScalingMode::Subset => {
                // Subset-of-data sparse GP: landmark selection is
                // deterministic (greedy max–min from the incumbent), so
                // a resumed study refits the same model.
                if self.dirty {
                    let idx = scaling::select_landmarks(
                        &self.xs,
                        &self.ys,
                        self.scaling.max_exact_n,
                    );
                    let sub_xs: Vec<Vec<f64>> = idx
                        .iter()
                        .filter_map(|i| self.xs.get(*i).cloned())
                        .collect();
                    let sub_ys: Vec<f64> = idx
                        .iter()
                        .filter_map(|i| self.ys.get(*i).copied())
                        .collect();
                    let ok =
                        self.gp.fit_ws(&sub_xs, &sub_ys, &mut self.ws);
                    self.stats.refit_alloc_bytes +=
                        self.ws.take_alloc_bytes();
                    if !ok {
                        return fallback(rng);
                    }
                    self.dirty = false;
                }
                let best_y = self
                    .ys
                    .iter()
                    .cloned()
                    .fold(f64::INFINITY, f64::min);
                let gp = &self.gp;
                let threads = self.candidates.scoring_threads;
                let evaluated_set: HashSet<&Point> =
                    evaluated.iter().collect();
                let (point, _fit) = maximize(
                    space,
                    &GaConfig::default(),
                    rng,
                    |pop| {
                        par_chunks_stable(pop, threads, |chunk| {
                            let mut ws = Workspace::new();
                            let encoded: Vec<Vec<f64>> = chunk
                                .iter()
                                .map(|p| space.encode(p))
                                .collect();
                            let mut mu = Vec::new();
                            let mut sd = Vec::new();
                            gp.predict_mean_std_batch(
                                &encoded, &mut ws, &mut mu, &mut sd,
                            );
                            chunk
                                .iter()
                                .zip(mu.iter().zip(&sd))
                                .map(|(p, (m, s))| {
                                    if evaluated_set.contains(p) {
                                        f64::NEG_INFINITY
                                    } else {
                                        expected_improvement(
                                            *m, *s, best_y,
                                        )
                                    }
                                })
                                .collect()
                        })
                    },
                );
                if evaluated_set.contains(&point) {
                    fallback(rng)
                } else {
                    point
                }
            }
            ScalingMode::Forest => {
                // Forest refits are cheap enough to do per proposal;
                // the seed mixes the study seed with the mirror length
                // so each refit is deterministic yet distinct.
                let mut frng = Rng::new(
                    self.seed ^ 0xF0E5_u64 ^ (self.xs.len() as u64) << 16,
                );
                if self.xs.is_empty() {
                    return fallback(rng);
                }
                let forest = Forest::fit(
                    &self.xs,
                    &self.ys,
                    &ForestConfig::default(),
                    &mut frng,
                );
                let Some(best_rec) = history.best(self.gamma) else {
                    return fallback(rng);
                };
                let gen = candidates::generate(
                    space,
                    &best_rec.theta,
                    &evaluated,
                    &self.candidates,
                    rng,
                );
                if gen.exhausted {
                    self.stats.exhausted_candidate_sets += 1;
                }
                let cands = gen.points;
                if cands.is_empty() {
                    return fallback(rng);
                }
                let threads = self.candidates.scoring_threads;
                let encoded: Vec<Vec<f64>> =
                    par_chunks_stable(&cands, threads, |chunk| {
                        chunk.iter().map(|c| space.encode(c)).collect()
                    });
                let forest_ref = &forest;
                let values: Vec<f64> =
                    par_chunks_stable(&encoded, threads, |chunk| {
                        chunk
                            .iter()
                            .map(|x| forest_ref.predict(x).0)
                            .collect()
                    });
                let w = WEIGHT_CYCLE
                    .get(iter % WEIGHT_CYCLE.len())
                    .copied()
                    .unwrap_or(0.5);
                match candidates::select_encoded(
                    space, &encoded, &values, &evaluated, w, threads,
                ) {
                    Some(i) => cands
                        .get(i)
                        .cloned()
                        .unwrap_or_else(|| fallback(rng)),
                    None => fallback(rng),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::synthetic::SyntheticEvaluator;
    use crate::eval::Evaluator;
    use crate::optimizer::{evaluate_point, initial_design};
    use crate::space::ParamSpec;

    fn setup() -> (SyntheticEvaluator, HpoConfig) {
        let space = Space::new(vec![
            ParamSpec::new("a", 0, 24),
            ParamSpec::new("b", 0, 24),
        ]);
        let ev = SyntheticEvaluator::new(space, 5);
        let cfg = HpoConfig {
            max_evaluations: 24,
            n_init: 6,
            n_trials: 2,
            seed: 1,
            ..Default::default()
        };
        (ev, cfg)
    }

    /// Drive a sequential loop through the online proposer and count
    /// refits: after the initial full fit, completions must be absorbed
    /// incrementally (the RBF path never needs another O(n³) fit).
    #[test]
    fn rbf_loop_is_incremental_after_first_fit() {
        let (ev, cfg) = setup();
        let space = ev.space().clone();
        let mut rng = Rng::new(cfg.seed);
        let mut history = History::default();
        let mut prop = OnlineProposer::new(&cfg);
        for theta in initial_design(&space, &cfg, &mut rng) {
            let summary = evaluate_point(
                &ev,
                &theta,
                cfg.n_trials,
                cfg.weights,
                rng.next_u64(),
            );
            let id = history.len();
            let rec = EvalRecord {
                id,
                n_params: ev.n_params(&theta),
                theta,
                summary,
                provenance: vec![],
            };
            prop.observe(&space, &rec);
            history.records.push(rec);
        }
        let mut iter = 0;
        while history.len() < cfg.max_evaluations {
            let theta = prop.propose(&space, &history, iter, &mut rng);
            let summary = evaluate_point(
                &ev,
                &theta,
                cfg.n_trials,
                cfg.weights,
                rng.next_u64(),
            );
            let id = history.len();
            let rec = EvalRecord {
                id,
                n_params: ev.n_params(&theta),
                theta,
                summary,
                provenance: (0..id).collect(),
            };
            prop.observe(&space, &rec);
            history.records.push(rec);
            iter += 1;
        }
        assert_eq!(history.len(), 24);
        let s = prop.stats();
        assert_eq!(s.proposals, 18);
        assert!(
            s.incremental >= 12,
            "expected mostly incremental refits, got {s:?}"
        );
        assert!(
            s.full <= 3,
            "too many full refits for the RBF path: {s:?}"
        );
        // The search still improves on the initial design.
        let trace = history.best_trace(0.0);
        assert!(trace.last().unwrap() <= &trace[5]);
    }

    #[test]
    fn preload_then_propose_matches_propose_next() {
        use crate::optimizer::{propose_next, run_sync};
        let (ev, cfg) = setup();
        let h = run_sync(&ev, &cfg);
        let space = ev.space().clone();
        // Same rng state on both sides: identical proposals.
        let a = propose_next(&space, &h, &cfg, 3, &mut Rng::new(77));
        let mut prop = OnlineProposer::new(&cfg);
        prop.preload(&space, &h);
        let b = prop.propose(&space, &h, 3, &mut Rng::new(77));
        assert_eq!(a, b);
    }
}
