//! The HPO engine (paper Sec. III-IV): adaptive surrogate-based search
//! over the integer lattice with UQ-aware objectives.
//!
//! `run_sync` is the sequential reference loop (one evaluation per
//! iteration, refit, propose). The asynchronous nested-parallel loop —
//! the paper's Feature 3 — lives in `cluster::async_hpo` and reuses the
//! same `propose_next` machinery with per-completion refits.

pub mod candidates;
pub mod ga;
pub mod online;

pub use online::{OnlineProposer, RefitStats};
pub use crate::surrogate::scaling::{ScalingConfig, ScalingMode};

use crate::eval::{aggregate, EvalSummary, Evaluator};
use crate::optimizer::candidates::CandidateConfig;
use crate::sampling::rng::Rng;
use crate::sampling::{halton_lattice, lhs_lattice};
use crate::space::{Point, Space};
use crate::uq::UqWeights;

/// Which surrogate drives the iterative sampling (paper Feature 2).
#[derive(Debug, Clone, PartialEq)]
pub enum SurrogateKind {
    /// Cubic RBF + Regis-Shoemaker candidate search.
    Rbf,
    /// GP + expected improvement maximized by the integer GA.
    Gp,
    /// RBF ensemble over CI extremes scored by μ + ασ (Eq. 8).
    RbfEnsemble { alpha: f64, members: usize },
}

/// Adaptive trial-count policy (paper Feature 1's "directly accounts
/// for uncertainty", taken one step further): when the trained-loss
/// spread of a θ's completed trial set exceeds `std_threshold`, the
/// `exec::Session` schedules one extra UQ replica at a time — same θ,
/// same evaluation seed, next trial index — until the spread drops or
/// `max_trials` is reached. Needs `n_trials >= 2` to have a spread
/// signal; off by default (`HpoConfig::adaptive_trials = None`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptiveTrials {
    /// Extend while `EvalSummary::trained_std` would exceed this.
    pub std_threshold: f64,
    /// Hard cap on trials per evaluation (≥ `n_trials`).
    pub max_trials: usize,
}

/// Initial experimental design.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InitDesign {
    /// Uniform random lattice points.
    Random,
    /// Latin-hypercube sample snapped to the lattice.
    Lhs,
    /// Halton low-discrepancy sequence snapped to the lattice.
    Halton,
}

/// Full configuration of one HPO problem.
#[derive(Debug, Clone)]
pub struct HpoConfig {
    /// Total expensive evaluations (initial design included).
    pub max_evaluations: usize,
    /// Size of the initial design.
    pub n_init: usize,
    /// N repeated trainings per θ (paper Feature 1).
    pub n_trials: usize,
    /// Trained-vs-dropout weights of Eqs. (6)-(7).
    pub weights: UqWeights,
    /// Which surrogate drives the adaptive phase.
    pub surrogate: SurrogateKind,
    /// Eq. (9) regularization strength γ (0 disables).
    pub gamma: f64,
    /// Master seed; every stochastic component derives from it.
    pub seed: u64,
    /// Candidate-generation knobs of the RBF acquisition.
    pub candidates: CandidateConfig,
    /// How the initial design is drawn.
    pub init_design: InitDesign,
    /// Fixed initial points (e.g. Fig. 3 seeds the surrogate with 10
    /// deliberately bad evaluations); overrides `init_design` when set.
    pub initial_points: Option<Vec<Point>>,
    /// Optional adaptive replica policy (extra trials for high-variance
    /// θ, `exec::Session` only; the sync reference loop ignores it).
    pub adaptive_trials: Option<AdaptiveTrials>,
    /// Surrogate observation budgets: exact below `max_exact_n`,
    /// subset-GP/forest past it, mirror eviction past `max_history`
    /// (`surrogate::scaling`, DESIGN.md §14). The defaults keep every
    /// paper-scale study on the exact, bit-stable path.
    pub scaling: ScalingConfig,
}

impl Default for HpoConfig {
    fn default() -> Self {
        HpoConfig {
            max_evaluations: 50,
            n_init: 10,
            n_trials: 3,
            weights: UqWeights::default_paper(),
            surrogate: SurrogateKind::Rbf,
            gamma: 0.0,
            seed: 0,
            candidates: CandidateConfig::default(),
            init_design: InitDesign::Random,
            initial_points: None,
            adaptive_trials: None,
            scaling: ScalingConfig::default(),
        }
    }
}

/// One completed evaluation in the optimization history.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    /// Submission id (stable across checkpoint/resume).
    pub id: usize,
    /// The evaluated hyperparameter set.
    pub theta: Point,
    /// Aggregated outcome of the N trials (Feature 1).
    pub summary: EvalSummary,
    /// Trainable-parameter count of the θ architecture.
    pub n_params: u64,
    /// Ids of the evaluations the surrogate had seen when this point was
    /// proposed (Fig. 6's provenance; empty for the initial design).
    pub provenance: Vec<usize>,
}

impl EvalRecord {
    /// The value the surrogate is trained on: CI center plus the Eq. (9)
    /// regularizer.
    pub fn objective(&self, gamma: f64) -> f64 {
        crate::uq::regulated_loss(
            self.summary.interval.center,
            self.summary.v_model_g,
            gamma,
        )
    }
}

/// Optimization history + summary queries used by the reports.
#[derive(Debug, Clone, Default)]
pub struct History {
    /// Completed evaluations in the order the surrogate saw them.
    pub records: Vec<EvalRecord>,
}

impl History {
    /// Number of recorded evaluations.
    pub fn len(&self) -> usize {
        self.records.len()
    }
    /// True when nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// The record minimizing the γ-regulated objective.
    pub fn best(&self, gamma: f64) -> Option<&EvalRecord> {
        self.records.iter().min_by(|a, b| {
            a.objective(gamma).total_cmp(&b.objective(gamma))
        })
    }

    /// Cumulative best objective after each evaluation (Fig. 3 / 4 series).
    pub fn best_trace(&self, gamma: f64) -> Vec<f64> {
        let mut best = f64::INFINITY;
        self.records
            .iter()
            .map(|r| {
                best = best.min(r.objective(gamma));
                best
            })
            .collect()
    }

    /// First evaluation index whose objective is within `fraction` of the
    /// final best (the "iterations to reach the optimal region" metric
    /// behind the paper's order-of-magnitude claim).
    pub fn evals_to_reach(&self, target: f64, gamma: f64) -> Option<usize> {
        self.records
            .iter()
            .position(|r| r.objective(gamma) <= target)
            .map(|i| i + 1)
    }

    fn points(&self) -> Vec<Point> {
        self.records.iter().map(|r| r.theta.clone()).collect()
    }
}

/// Evaluate one θ: N trials through the black box, aggregated per Feature 1.
pub fn evaluate_point(
    evaluator: &dyn Evaluator,
    theta: &[crate::space::Value],
    n_trials: usize,
    weights: UqWeights,
    seed: u64,
) -> EvalSummary {
    let outcomes: Vec<_> = (0..n_trials.max(1))
        .map(|t| evaluator.run_trial(theta, t, seed))
        .collect();
    aggregate(evaluator, theta, &outcomes, weights)
}

/// Build the initial design.
pub fn initial_design(
    space: &Space,
    cfg: &HpoConfig,
    rng: &mut Rng,
) -> Vec<Point> {
    if let Some(pts) = &cfg.initial_points {
        return pts.clone();
    }
    let n = cfg.n_init.max(1);
    let mut pts = match cfg.init_design {
        InitDesign::Random => {
            (0..n).map(|_| space.random_point(rng)).collect()
        }
        InitDesign::Lhs => lhs_lattice(space, n, rng),
        InitDesign::Halton => halton_lattice(space, n, rng),
    };
    // Deduplicate (lattices can collide); top up with random points.
    pts.sort();
    pts.dedup();
    let mut guard = 0;
    while pts.len() < n && guard < 100 * n {
        guard += 1;
        let p = space.random_point(rng);
        if !pts.contains(&p) {
            pts.push(p);
        }
    }
    pts
}

/// Propose the next point to evaluate given the current history.
/// `iter` indexes the adaptive phase (for the weight cycle).
///
/// One-shot convenience over [`OnlineProposer`]: fits a fresh surrogate
/// on the whole history every call. Long-running loops (the `exec`
/// driver) should hold an `OnlineProposer` instead and absorb
/// completions incrementally.
pub fn propose_next(
    space: &Space,
    history: &History,
    cfg: &HpoConfig,
    iter: usize,
    rng: &mut Rng,
) -> Point {
    let mut proposer = OnlineProposer::new(cfg);
    proposer.preload(space, history);
    proposer.propose(space, history, iter, rng)
}

/// Sequential surrogate-based HPO (one evaluation per iteration).
pub fn run_sync(evaluator: &dyn Evaluator, cfg: &HpoConfig) -> History {
    let space = evaluator.space().clone();
    let mut rng = Rng::new(cfg.seed);
    let mut history = History::default();

    for theta in initial_design(&space, cfg, &mut rng) {
        if history.len() >= cfg.max_evaluations {
            break;
        }
        let summary = evaluate_point(
            evaluator,
            &theta,
            cfg.n_trials,
            cfg.weights,
            rng.next_u64(),
        );
        let id = history.len();
        history.records.push(EvalRecord {
            id,
            n_params: evaluator.n_params(&theta),
            theta,
            summary,
            provenance: vec![],
        });
    }

    let mut iter = 0;
    while history.len() < cfg.max_evaluations {
        let theta =
            propose_next(&space, &history, cfg, iter, &mut rng);
        let provenance: Vec<usize> =
            history.records.iter().map(|r| r.id).collect();
        let summary = evaluate_point(
            evaluator,
            &theta,
            cfg.n_trials,
            cfg.weights,
            rng.next_u64(),
        );
        let id = history.len();
        history.records.push(EvalRecord {
            id,
            n_params: evaluator.n_params(&theta),
            theta,
            summary,
            provenance,
        });
        iter += 1;
    }
    history
}

/// Pure random search over the lattice — the Fig. 3 reference sweep.
pub fn run_random(
    evaluator: &dyn Evaluator,
    n: usize,
    n_trials: usize,
    weights: UqWeights,
    seed: u64,
) -> History {
    let space = evaluator.space().clone();
    let mut rng = Rng::new(seed);
    let mut history = History::default();
    for id in 0..n {
        let theta = space.random_point(&mut rng);
        let summary = evaluate_point(
            evaluator,
            &theta,
            n_trials,
            weights,
            rng.next_u64(),
        );
        history.records.push(EvalRecord {
            id,
            n_params: evaluator.n_params(&theta),
            theta,
            summary,
            provenance: vec![],
        });
    }
    history
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::synthetic::SyntheticEvaluator;
    use crate::space::ParamSpec;

    fn evaluator(seed: u64) -> SyntheticEvaluator {
        let space = Space::new(vec![
            ParamSpec::new("a", 0, 24),
            ParamSpec::new("b", 0, 24),
            ParamSpec::new("c", 0, 24),
        ]);
        SyntheticEvaluator::new(space, seed)
    }

    fn run(kind: SurrogateKind, seed: u64) -> History {
        let ev = evaluator(7);
        let cfg = HpoConfig {
            max_evaluations: 40,
            n_init: 8,
            n_trials: 2,
            surrogate: kind,
            seed,
            ..Default::default()
        };
        run_sync(&ev, &cfg)
    }

    #[test]
    fn all_surrogates_complete_budget_and_improve() {
        for kind in [
            SurrogateKind::Rbf,
            SurrogateKind::Gp,
            SurrogateKind::RbfEnsemble { alpha: 1.0, members: 6 },
        ] {
            let h = run(kind.clone(), 1);
            assert_eq!(h.len(), 40, "{kind:?}");
            let trace = h.best_trace(0.0);
            assert!(
                trace.last().unwrap() < &trace[7],
                "{kind:?} did not improve over the initial design"
            );
        }
    }

    #[test]
    fn surrogate_beats_random_search_on_average() {
        let ev = evaluator(11);
        let mut surr_wins = 0;
        for seed in 0..5u64 {
            let cfg = HpoConfig {
                max_evaluations: 35,
                n_init: 8,
                n_trials: 2,
                seed,
                ..Default::default()
            };
            let h = run_sync(&ev, &cfg);
            let r = run_random(
                &ev,
                35,
                2,
                UqWeights::default_paper(),
                seed ^ 0xAAAA,
            );
            if h.best(0.0).unwrap().summary.interval.center
                <= r.best(0.0).unwrap().summary.interval.center
            {
                surr_wins += 1;
            }
        }
        assert!(
            surr_wins >= 3,
            "surrogate won only {surr_wins}/5 seeds vs random"
        );
    }

    #[test]
    fn no_duplicate_evaluations_in_adaptive_phase() {
        let h = run(SurrogateKind::Rbf, 5);
        let mut pts = h.points();
        let total = pts.len();
        pts.sort();
        pts.dedup();
        assert_eq!(pts.len(), total, "duplicate θ evaluated");
    }

    #[test]
    fn provenance_monotone_and_complete() {
        let h = run(SurrogateKind::Rbf, 9);
        for (i, r) in h.records.iter().enumerate() {
            assert_eq!(r.id, i);
            if i < 8 {
                assert!(r.provenance.is_empty());
            } else {
                // Sequential loop: proposal saw all earlier evaluations.
                assert_eq!(
                    r.provenance,
                    (0..i).collect::<Vec<usize>>()
                );
            }
        }
    }

    #[test]
    fn initial_points_override_design() {
        use crate::space::ints;
        let ev = evaluator(3);
        let fixed = vec![ints(&[0, 0, 0]), ints(&[24, 24, 24])];
        let cfg = HpoConfig {
            max_evaluations: 4,
            n_init: 10,
            initial_points: Some(fixed.clone()),
            n_trials: 1,
            seed: 2,
            ..Default::default()
        };
        let h = run_sync(&ev, &cfg);
        assert_eq!(h.records[0].theta, fixed[0]);
        assert_eq!(h.records[1].theta, fixed[1]);
    }

    #[test]
    fn gamma_changes_ranking() {
        // With a huge gamma, the regulated objective is dominated by the
        // variability term, so best(gamma) can differ from best(0).
        let h = run(SurrogateKind::Rbf, 13);
        let b0 = h.best(0.0).unwrap().id;
        let trace0 = h.best_trace(0.0);
        assert!(trace0.windows(2).all(|w| w[1] <= w[0]));
        // Not asserting inequality of ids (landscape-dependent), but the
        // regulated objective must be >= the plain center everywhere.
        for r in &h.records {
            assert!(r.objective(10.0) >= r.objective(0.0));
        }
        let _ = b0;
    }

    #[test]
    fn evals_to_reach_semantics() {
        let h = run(SurrogateKind::Rbf, 17);
        let best = h.best(0.0).unwrap().objective(0.0);
        assert_eq!(
            h.evals_to_reach(best, 0.0).unwrap(),
            h.records
                .iter()
                .position(|r| r.objective(0.0) <= best)
                .unwrap()
                + 1
        );
        assert!(h.evals_to_reach(f64::NEG_INFINITY, 0.0).is_none());
    }

    #[test]
    fn lhs_and_halton_designs_are_valid() {
        let ev = evaluator(21);
        for design in [InitDesign::Lhs, InitDesign::Halton] {
            let cfg = HpoConfig {
                max_evaluations: 12,
                n_init: 12,
                n_trials: 1,
                init_design: design,
                seed: 3,
                ..Default::default()
            };
            let h = run_sync(&ev, &cfg);
            assert_eq!(h.len(), 12);
            for r in &h.records {
                assert!(ev.space().contains(&r.theta));
            }
        }
    }
}
