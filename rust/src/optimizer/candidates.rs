//! Candidate-point sampling (paper Feature 2, following Regis & Shoemaker
//! 2007 / [25]).
//!
//! Each iteration generates a large candidate set: perturbations of the
//! best point found so far (local) plus uniform lattice samples (global),
//! integer constraints respected by construction. Each candidate is scored
//! by a weighted sum of its surrogate-predicted value rank and its
//! distance-to-evaluated-points rank; the weight cycles through a fixed
//! pattern to alternate between local exploitation (high weight on the
//! predicted value) and global exploration (high weight on distance).

use crate::sampling::rng::Rng;
use crate::space::{Point, Space, Value};

/// The cycling value-vs-distance weights of [25].
pub const WEIGHT_CYCLE: [f64; 4] = [0.3, 0.5, 0.8, 0.95];

/// Candidate-set sizing and perturbation knobs.
#[derive(Debug, Clone)]
pub struct CandidateConfig {
    /// Total candidates per iteration (half perturbed, half uniform).
    pub n_candidates: usize,
    /// Per-coordinate mutation probability for the perturbed half.
    pub p_mutate: f64,
    /// Relative perturbation scale (fraction of each range).
    pub sigma: f64,
}

impl Default for CandidateConfig {
    fn default() -> Self {
        CandidateConfig { n_candidates: 200, p_mutate: 0.5, sigma: 0.1 }
    }
}

/// Generate the candidate set, excluding already-evaluated points.
pub fn generate(
    space: &Space,
    best: &[Value],
    evaluated: &[Point],
    cfg: &CandidateConfig,
    rng: &mut Rng,
) -> Vec<Point> {
    let mut out: Vec<Point> = Vec::with_capacity(cfg.n_candidates);
    let half = cfg.n_candidates / 2;
    let mut guard = 0;
    while out.len() < cfg.n_candidates && guard < cfg.n_candidates * 20 {
        guard += 1;
        let cand = if out.len() < half {
            space.perturb(best, cfg.p_mutate, cfg.sigma, rng)
        } else {
            space.random_point(rng)
        };
        if evaluated.iter().any(|e| e == &cand)
            || out.iter().any(|e| e == &cand)
        {
            continue;
        }
        out.push(cand);
    }
    out
}

/// Score candidates and return the best one.
///
/// `values[i]` is the surrogate prediction for `candidates[i]` (lower is
/// better). `weight` ∈ [0,1] is the emphasis on the predicted value; the
/// remainder goes to the (negated) minimum normalized distance to the
/// evaluated set, so high-distance candidates win when `weight` is small.
pub fn select(
    space: &Space,
    candidates: &[Point],
    values: &[f64],
    evaluated: &[Point],
    weight: f64,
) -> Option<usize> {
    assert_eq!(candidates.len(), values.len());
    if candidates.is_empty() {
        return None;
    }
    // Encode once: dist2() would re-allocate feature vectors per pair,
    // which dominated this function in profiling (§Perf: 4.9x). The
    // encoding layer's feature space is shared with the surrogates, so
    // categorical blocks weigh into the distance rank consistently.
    let eval_units: Vec<Vec<f64>> =
        evaluated.iter().map(|e| space.encode(e)).collect();
    let dists: Vec<f64> = candidates
        .iter()
        .map(|c| {
            let cu = space.encode(c);
            eval_units
                .iter()
                .map(|eu| {
                    cu.iter()
                        .zip(eu)
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum::<f64>()
                        .sqrt()
                })
                .fold(f64::INFINITY, f64::min)
        })
        .collect();

    let (vmin, vmax) = min_max(values);
    let (dmin, dmax) = min_max(&dists);
    let score = |i: usize| {
        let v_norm = if vmax > vmin {
            (values[i] - vmin) / (vmax - vmin)
        } else {
            0.0
        };
        // Large distance is good -> low score contribution.
        let d_norm = if dmax > dmin {
            (dmax - dists[i]) / (dmax - dmin)
        } else {
            0.0
        };
        weight * v_norm + (1.0 - weight) * d_norm
    };
    (0..candidates.len()).min_by(|&a, &b| {
        score(a).partial_cmp(&score(b)).unwrap()
    })
}

fn min_max(xs: &[f64]) -> (f64, f64) {
    xs.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &v| {
        (lo.min(v), hi.max(v))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::space::ParamSpec;
    use crate::util::prop::forall;

    fn space() -> Space {
        Space::new(vec![
            ParamSpec::new("a", 0, 15),
            ParamSpec::new("b", 0, 15),
        ])
    }

    #[test]
    fn generate_respects_space_and_exclusions() {
        let sp = space();
        forall("candidates valid", 30, |rng| {
            let best = sp.random_point(rng);
            let evaluated: Vec<Point> =
                (0..10).map(|_| sp.random_point(rng)).collect();
            let cands = generate(
                &sp,
                &best,
                &evaluated,
                &CandidateConfig::default(),
                rng,
            );
            prop_assert!(!cands.is_empty(), "no candidates");
            for c in &cands {
                prop_assert!(sp.contains(c), "{c:?} out of bounds");
                prop_assert!(
                    !evaluated.contains(c),
                    "{c:?} already evaluated"
                );
            }
            // No duplicates.
            let mut s = cands.clone();
            s.sort();
            s.dedup();
            prop_assert!(s.len() == cands.len(), "duplicate candidates");
            Ok(())
        });
    }

    #[test]
    fn high_weight_prefers_low_predicted_value() {
        use crate::space::ints;
        let sp = space();
        let cands = vec![ints(&[1, 1]), ints(&[14, 14])];
        let values = vec![0.1, 5.0];
        let evaluated = vec![ints(&[0, 0])]; // near cands[0], far from [1]
        // weight ~1: value dominates -> candidate 0 despite proximity.
        let i = select(&sp, &cands, &values, &evaluated, 0.99).unwrap();
        assert_eq!(i, 0);
        // weight ~0: distance dominates -> candidate 1.
        let i = select(&sp, &cands, &values, &evaluated, 0.01).unwrap();
        assert_eq!(i, 1);
    }

    #[test]
    fn select_empty_returns_none() {
        let sp = space();
        assert!(select(&sp, &[], &[], &[], 0.5).is_none());
    }

    #[test]
    fn weight_cycle_matches_paper_pattern() {
        // Ends exploitative, starts explorative.
        assert!(WEIGHT_CYCLE.first().unwrap() < WEIGHT_CYCLE.last().unwrap());
        assert_eq!(WEIGHT_CYCLE.len(), 4);
    }
}
