//! Candidate-point sampling (paper Feature 2, following Regis & Shoemaker
//! 2007 / [25]).
//!
//! Each iteration generates a large candidate set: perturbations of the
//! best point found so far (local) plus uniform lattice samples (global),
//! integer constraints respected by construction. Each candidate is scored
//! by a weighted sum of its surrogate-predicted value rank and its
//! distance-to-evaluated-points rank; the weight cycles through a fixed
//! pattern to alternate between local exploitation (high weight on the
//! predicted value) and global exploration (high weight on distance).
//!
//! Scoring is the proposal hot path: distances are computed once per
//! candidate set (optionally fanned out over deterministic thread chunks,
//! see [`crate::util::par`]) and reused across every weight, and
//! generation dedups through a `HashSet` instead of the historical O(n²)
//! linear scans.

use std::collections::HashSet;

use crate::sampling::rng::Rng;
use crate::space::{Point, Space, Value};
use crate::util::par::par_chunks_stable;

/// The cycling value-vs-distance weights of [25].
pub const WEIGHT_CYCLE: [f64; 4] = [0.3, 0.5, 0.8, 0.95];

/// Candidate-set sizing and perturbation knobs.
#[derive(Debug, Clone)]
pub struct CandidateConfig {
    /// Total candidates per iteration (half perturbed, half uniform).
    pub n_candidates: usize,
    /// Per-coordinate mutation probability for the perturbed half.
    pub p_mutate: f64,
    /// Relative perturbation scale (fraction of each range).
    pub sigma: f64,
    /// Scoped worker threads for candidate/fitness scoring (1 =
    /// sequential). Proposals are bit-identical for every value — the
    /// deterministic-chunking rule of DESIGN.md §11, asserted at 1/2/8
    /// threads in `tests/exec.rs` — so this is purely a throughput knob.
    pub scoring_threads: usize,
}

impl Default for CandidateConfig {
    fn default() -> Self {
        CandidateConfig {
            n_candidates: 200,
            p_mutate: 0.5,
            sigma: 0.1,
            scoring_threads: 1,
        }
    }
}

/// A generated candidate set plus generation metadata — the guard-loop
/// outcome is surfaced to the caller instead of spamming stderr.
#[derive(Debug, Clone)]
pub struct Generated {
    /// The candidate points (deduplicated, never already-evaluated).
    pub points: Vec<Point>,
    /// True when the attempt budget (`n_candidates * 20`) ran out before
    /// the set was filled — expected on small or nearly-exhausted
    /// spaces; callers should treat the short set as a signal that the
    /// space is close to fully explored.
    pub exhausted: bool,
    /// Perturb/sample attempts consumed.
    pub attempts: usize,
}

/// Generate the candidate set, excluding already-evaluated points.
pub fn generate(
    space: &Space,
    best: &[Value],
    evaluated: &[Point],
    cfg: &CandidateConfig,
    rng: &mut Rng,
) -> Generated {
    let mut out: Vec<Point> = Vec::with_capacity(cfg.n_candidates);
    // O(1) membership per attempt instead of the former O(n) scans over
    // both lists. The evaluated history is indexed by reference — no
    // per-proposal deep clone of the whole history; only accepted
    // candidates (bounded by n_candidates) are cloned into `chosen`.
    let evaluated_set: HashSet<&Point> = evaluated.iter().collect();
    let mut chosen: HashSet<Point> =
        HashSet::with_capacity(cfg.n_candidates);
    let half = cfg.n_candidates / 2;
    let mut attempts = 0;
    while out.len() < cfg.n_candidates && attempts < cfg.n_candidates * 20
    {
        attempts += 1;
        let cand = if out.len() < half {
            space.perturb(best, cfg.p_mutate, cfg.sigma, rng)
        } else {
            space.random_point(rng)
        };
        if evaluated_set.contains(&cand) || chosen.contains(&cand) {
            continue;
        }
        chosen.insert(cand.clone());
        out.push(cand);
    }
    let exhausted = out.len() < cfg.n_candidates;
    Generated { points: out, exhausted, attempts }
}

/// Score candidates and return the best one (sequential convenience
/// over [`select_threaded`]).
///
/// `values[i]` is the surrogate prediction for `candidates[i]` (lower is
/// better). `weight` ∈ [0,1] is the emphasis on the predicted value; the
/// remainder goes to the (negated) minimum normalized distance to the
/// evaluated set, so high-distance candidates win when `weight` is small.
pub fn select(
    space: &Space,
    candidates: &[Point],
    values: &[f64],
    evaluated: &[Point],
    weight: f64,
) -> Option<usize> {
    select_threaded(space, candidates, values, evaluated, weight, 1)
}

/// [`select`] with the distance pass fanned out over `threads` scoped
/// workers. Bit-identical to the sequential path for every thread count
/// (deterministic contiguous chunking; each candidate's minimum distance
/// depends on nothing but the candidate itself).
pub fn select_threaded(
    space: &Space,
    candidates: &[Point],
    values: &[f64],
    evaluated: &[Point],
    weight: f64,
    threads: usize,
) -> Option<usize> {
    select_many(space, candidates, values, evaluated, &[weight], threads)
        .pop()
        .flatten()
}

/// [`select_threaded`] over candidates that are **already encoded** —
/// the proposer encodes the candidate set once and shares the feature
/// vectors between surrogate scoring and this distance ranking, so no
/// candidate is encoded twice per proposal.
pub fn select_encoded(
    space: &Space,
    encoded: &[Vec<f64>],
    values: &[f64],
    evaluated: &[Point],
    weight: f64,
    threads: usize,
) -> Option<usize> {
    select_many_encoded(
        space,
        encoded,
        values,
        evaluated,
        &[weight],
        threads,
    )
    .pop()
    .flatten()
}

/// Select the best candidate for **each** weight over one shared
/// distance/normalization pass: candidate encodings, distances,
/// `min`/`max` ranges, and the normalized rank buffers are computed
/// once and reused per weight instead of re-collected per call.
pub fn select_many(
    space: &Space,
    candidates: &[Point],
    values: &[f64],
    evaluated: &[Point],
    weights: &[f64],
    threads: usize,
) -> Vec<Option<usize>> {
    assert_eq!(candidates.len(), values.len());
    if candidates.is_empty() {
        return vec![None; weights.len()];
    }
    let encoded: Vec<Vec<f64>> =
        par_chunks_stable(candidates, threads, |chunk| {
            chunk.iter().map(|c| space.encode(c)).collect()
        });
    select_many_encoded(space, &encoded, values, evaluated, weights, threads)
}

/// The shared scoring core over pre-encoded candidates.
pub fn select_many_encoded(
    space: &Space,
    encoded: &[Vec<f64>],
    values: &[f64],
    evaluated: &[Point],
    weights: &[f64],
    threads: usize,
) -> Vec<Option<usize>> {
    assert_eq!(encoded.len(), values.len());
    if encoded.is_empty() {
        return vec![None; weights.len()];
    }
    let dists = min_dists(space, encoded, evaluated, threads);

    let (vmin, vmax) = min_max(values);
    let (dmin, dmax) = min_max(&dists);
    // Normalized ranks, one buffer each, shared by every weight.
    let v_norm: Vec<f64> = values
        .iter()
        .map(|v| if vmax > vmin { (v - vmin) / (vmax - vmin) } else { 0.0 })
        .collect();
    // Large distance is good -> low score contribution.
    let d_norm: Vec<f64> = dists
        .iter()
        .map(|d| if dmax > dmin { (dmax - d) / (dmax - dmin) } else { 0.0 })
        .collect();

    weights
        .iter()
        .map(|&weight| {
            let mut best: Option<(usize, f64)> = None;
            for (i, (v, d)) in v_norm.iter().zip(&d_norm).enumerate() {
                let s = weight * v + (1.0 - weight) * d;
                match best {
                    None => best = Some((i, s)),
                    Some((_, bs)) => match s.partial_cmp(&bs) {
                        // Strict Less keeps the first of equal minima —
                        // the tie-break `Iterator::min_by` applied
                        // historically.
                        Some(std::cmp::Ordering::Less) => {
                            best = Some((i, s));
                        }
                        Some(_) => {}
                        // A NaN surrogate value must fail loudly (as
                        // the historical min_by unwrap did), not get
                        // silently proposed.
                        None => panic!(
                            "NaN candidate score at index {i}"
                        ),
                    },
                }
            }
            best.map(|(i, _)| i)
        })
        .collect()
}

/// Per-candidate minimum distance (in the shared encoded feature space,
/// so categorical blocks weigh in consistently with the surrogates) to
/// the evaluated set. Encode-once + optional deterministic fan-out; this
/// dominated `select` in profiling (§Perf: 4.9x from encode-once alone).
fn min_dists(
    space: &Space,
    encoded: &[Vec<f64>],
    evaluated: &[Point],
    threads: usize,
) -> Vec<f64> {
    let eval_units: Vec<Vec<f64>> =
        evaluated.iter().map(|e| space.encode(e)).collect();
    par_chunks_stable(encoded, threads, |chunk| {
        chunk
            .iter()
            .map(|cu| {
                eval_units
                    .iter()
                    .map(|eu| {
                        cu.iter()
                            .zip(eu)
                            .map(|(a, b)| (a - b) * (a - b))
                            .sum::<f64>()
                            .sqrt()
                    })
                    .fold(f64::INFINITY, f64::min)
            })
            .collect()
    })
}

fn min_max(xs: &[f64]) -> (f64, f64) {
    xs.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &v| {
        (lo.min(v), hi.max(v))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::space::ParamSpec;
    use crate::util::prop::forall;

    fn space() -> Space {
        Space::new(vec![
            ParamSpec::new("a", 0, 15),
            ParamSpec::new("b", 0, 15),
        ])
    }

    #[test]
    fn generate_respects_space_and_exclusions() {
        let sp = space();
        forall("candidates valid", 30, |rng| {
            let best = sp.random_point(rng);
            let evaluated: Vec<Point> =
                (0..10).map(|_| sp.random_point(rng)).collect();
            let gen = generate(
                &sp,
                &best,
                &evaluated,
                &CandidateConfig::default(),
                rng,
            );
            let cands = gen.points;
            prop_assert!(!cands.is_empty(), "no candidates");
            prop_assert!(gen.attempts >= cands.len(), "attempt count");
            for c in &cands {
                prop_assert!(sp.contains(c), "{c:?} out of bounds");
                prop_assert!(
                    !evaluated.contains(c),
                    "{c:?} already evaluated"
                );
            }
            // No duplicates.
            let mut s = cands.clone();
            s.sort();
            s.dedup();
            prop_assert!(s.len() == cands.len(), "duplicate candidates");
            Ok(())
        });
    }

    #[test]
    fn generate_flags_exhaustion_on_tiny_spaces() {
        // A 2x2 lattice has 4 points; asking for 200 candidates must
        // come back short with the exhausted flag set (and no stderr).
        let sp = Space::new(vec![
            ParamSpec::new("a", 0, 1),
            ParamSpec::new("b", 0, 1),
        ]);
        let mut rng = Rng::new(3);
        let best = sp.random_point(&mut rng);
        let gen = generate(
            &sp,
            &best,
            &[],
            &CandidateConfig::default(),
            &mut rng,
        );
        assert!(gen.exhausted);
        assert!(gen.points.len() <= 4);
        assert_eq!(gen.attempts, 200 * 20);

        // A large space fills the set without exhaustion.
        let sp = space();
        let gen = generate(
            &sp,
            &sp.random_point(&mut rng),
            &[],
            &CandidateConfig::default(),
            &mut rng,
        );
        assert!(!gen.exhausted);
        assert_eq!(gen.points.len(), 200);
    }

    #[test]
    fn high_weight_prefers_low_predicted_value() {
        use crate::space::ints;
        let sp = space();
        let cands = vec![ints(&[1, 1]), ints(&[14, 14])];
        let values = vec![0.1, 5.0];
        let evaluated = vec![ints(&[0, 0])]; // near cands[0], far from [1]
        // weight ~1: value dominates -> candidate 0 despite proximity.
        let i = select(&sp, &cands, &values, &evaluated, 0.99).unwrap();
        assert_eq!(i, 0);
        // weight ~0: distance dominates -> candidate 1.
        let i = select(&sp, &cands, &values, &evaluated, 0.01).unwrap();
        assert_eq!(i, 1);
    }

    #[test]
    fn select_empty_returns_none() {
        let sp = space();
        assert!(select(&sp, &[], &[], &[], 0.5).is_none());
        assert_eq!(
            select_many(&sp, &[], &[], &[], &[0.3, 0.8], 4),
            vec![None, None]
        );
    }

    #[test]
    fn select_many_matches_individual_selects() {
        let sp = space();
        forall("select_many == per-weight select", 20, |rng| {
            let evaluated: Vec<Point> =
                (0..8).map(|_| sp.random_point(rng)).collect();
            let cands: Vec<Point> =
                (0..40).map(|_| sp.random_point(rng)).collect();
            let values: Vec<f64> =
                (0..cands.len()).map(|_| rng.normal()).collect();
            let many = select_many(
                &sp,
                &cands,
                &values,
                &evaluated,
                &WEIGHT_CYCLE,
                1,
            );
            for (w, got) in WEIGHT_CYCLE.iter().zip(&many) {
                let want =
                    select(&sp, &cands, &values, &evaluated, *w);
                prop_assert!(
                    *got == want,
                    "weight {w}: {got:?} vs {want:?}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn select_encoded_matches_point_level_select() {
        let sp = space();
        forall("select_encoded == select", 15, |rng| {
            let evaluated: Vec<Point> =
                (0..6).map(|_| sp.random_point(rng)).collect();
            let cands: Vec<Point> =
                (0..30).map(|_| sp.random_point(rng)).collect();
            let values: Vec<f64> =
                (0..cands.len()).map(|_| rng.normal()).collect();
            let encoded: Vec<Vec<f64>> =
                cands.iter().map(|c| sp.encode(c)).collect();
            for w in WEIGHT_CYCLE {
                let a = select(&sp, &cands, &values, &evaluated, w);
                let b = select_encoded(
                    &sp, &encoded, &values, &evaluated, w, 2,
                );
                prop_assert!(a == b, "weight {w}: {a:?} vs {b:?}");
            }
            Ok(())
        });
    }

    #[test]
    fn threaded_select_is_bitwise_sequential() {
        let sp = space();
        forall("select 1/2/8 threads identical", 15, |rng| {
            let evaluated: Vec<Point> =
                (0..12).map(|_| sp.random_point(rng)).collect();
            let cands: Vec<Point> =
                (0..60).map(|_| sp.random_point(rng)).collect();
            let values: Vec<f64> =
                (0..cands.len()).map(|_| rng.normal()).collect();
            let seq = select(&sp, &cands, &values, &evaluated, 0.8);
            for threads in [2usize, 8] {
                let par = select_threaded(
                    &sp, &cands, &values, &evaluated, 0.8, threads,
                );
                prop_assert!(
                    par == seq,
                    "{threads} threads: {par:?} vs {seq:?}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn weight_cycle_matches_paper_pattern() {
        // Ends exploitative, starts explorative.
        assert!(WEIGHT_CYCLE.first().unwrap() < WEIGHT_CYCLE.last().unwrap());
        assert_eq!(WEIGHT_CYCLE.len(), 4);
    }
}
