//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! `Rng` is splitmix64-seeded xoshiro256**, the standard simulation-grade
//! generator: fast, 2^256-1 period, passes BigCrush. All stochastic
//! components (initial designs, candidate perturbation, GA, synthetic
//! trainer, Poisson noise) draw from it so every experiment is replayable
//! from its seed.

/// xoshiro256** seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-worker / per-trial RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xa076_1d64_78bd_642f))
    }

    /// Snapshot the internal xoshiro256** state (for checkpointing).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Restore a generator from a [`Rng::state`] snapshot; the restored
    /// generator continues the exact sequence of the saved one.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform usize in [0, n).
    pub fn usize_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Poisson sample (Knuth for small lambda, normal approx for large) —
    /// used by the CT noise model.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            let v = lambda + lambda.sqrt() * self.normal();
            return v.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn i64_in_respects_bounds_and_covers() {
        let mut r = Rng::new(2);
        let mut seen = [false; 5];
        for _ in 0..1_000 {
            let v = r.i64_in(-2, 2);
            assert!((-2..=2).contains(&v));
            seen[(v + 2) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn poisson_mean_matches_lambda() {
        let mut r = Rng::new(4);
        for &lambda in &[0.5, 4.0, 30.0, 200.0] {
            let n = 20_000;
            let mean = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>()
                / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.sqrt() * 0.1 + 0.05,
                "lambda {lambda} mean {mean}"
            );
        }
    }

    #[test]
    fn state_snapshot_resumes_exact_sequence() {
        let mut a = Rng::new(42);
        for _ in 0..17 {
            a.next_u64();
        }
        let snap = a.state();
        let tail: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(snap);
        let tail2: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        assert_eq!(tail, tail2);
    }

    #[test]
    fn forked_streams_differ() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }
}
