//! Low-discrepancy sequences with integer-lattice adaptation.
//!
//! The paper uses low-discrepancy sampling to build the 825-point reference
//! sweep of Fig. 3 and discusses (Sec. VI) that off-the-shelf sequences are
//! not directly usable under integer constraints. We implement the Halton
//! sequence (radical-inverse per prime base) plus the integer adaptation the
//! paper sketches: map each continuous coordinate onto the lattice cell
//! whose *quantile bucket* it falls in, which preserves even coverage for
//! small ranges where naive rounding collapses points.

use crate::sampling::rng::Rng;
use crate::space::{Point, Space};

const PRIMES: [u64; 16] =
    [2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53];

/// Van der Corput radical inverse of `n` in base `b`.
pub fn radical_inverse(mut n: u64, b: u64) -> f64 {
    let mut inv = 0.0;
    let mut denom = 1.0;
    while n > 0 {
        denom *= b as f64;
        inv += (n % b) as f64 / denom;
        n /= b;
    }
    inv
}

/// Halton point `index` in `dim` dimensions, each coordinate in [0,1).
/// A random shift (Cranley-Patterson rotation) decorrelates replicated
/// sweeps while preserving low discrepancy.
pub fn halton(index: u64, dim: usize, shift: &[f64]) -> Vec<f64> {
    assert!(dim <= PRIMES.len(), "halton supports up to 16 dims");
    (0..dim)
        .map(|d| {
            let v = radical_inverse(index + 1, PRIMES[d])
                + shift.get(d).copied().unwrap_or(0.0);
            v - v.floor()
        })
        .collect()
}

/// Generate `n` typed points with low discrepancy over `space`.
///
/// Each unit-cube coordinate u maps through the space's encoding layer:
/// equal-width quantile buckets for the finite kinds (`lo + floor(u *
/// range_size)`, the integer adaptation discussed in the paper's Sec.
/// VI) and the (possibly log) warp for continuous parameters.
pub fn halton_lattice(space: &Space, n: usize, rng: &mut Rng) -> Vec<Point> {
    let dim = space.dim();
    let shift: Vec<f64> = (0..dim).map(|_| rng.f64()).collect();
    (0..n as u64)
        .map(|i| {
            let u = halton(i, dim, &shift);
            space.from_unit(&u)
        })
        .collect()
}

/// Latin hypercube design: stratifies each dimension into `n` slices
/// before mapping through the encoding layer. Used for initial
/// experimental designs when `n` is small.
pub fn lhs_lattice(space: &Space, n: usize, rng: &mut Rng) -> Vec<Point> {
    let dim = space.dim();
    let strata: Vec<Vec<usize>> = (0..dim)
        .map(|_| {
            let mut idx: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut idx);
            idx
        })
        .collect();
    (0..n)
        .map(|i| {
            let u: Vec<f64> = (0..dim)
                .map(|d| {
                    let stratum = strata[d][i];
                    (stratum as f64 + rng.f64()) / n as f64
                })
                .collect();
            space.from_unit(&u)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{ParamSpec, Space};

    fn space2() -> Space {
        Space::new(vec![
            ParamSpec::new("a", 0, 9),
            ParamSpec::new("b", -5, 5),
        ])
    }

    #[test]
    fn radical_inverse_base2_prefix() {
        // 1 -> 0.5, 2 -> 0.25, 3 -> 0.75 in base 2
        assert_eq!(radical_inverse(1, 2), 0.5);
        assert_eq!(radical_inverse(2, 2), 0.25);
        assert_eq!(radical_inverse(3, 2), 0.75);
    }

    #[test]
    fn halton_in_unit_cube() {
        let shift = [0.3, 0.7, 0.1];
        for i in 0..100 {
            for v in halton(i, 3, &shift) {
                assert!((0.0..1.0).contains(&v));
            }
        }
    }

    #[test]
    fn lattice_points_in_bounds() {
        let sp = space2();
        let mut rng = Rng::new(0);
        for p in halton_lattice(&sp, 200, &mut rng) {
            assert!(sp.contains(&p), "{p:?}");
        }
    }

    #[test]
    fn halton_covers_small_range_evenly() {
        // Naive rounding of a low-discrepancy sequence onto a 3-value range
        // collapses coverage; bucket mapping must hit each value ~n/3 times.
        let sp = Space::new(vec![ParamSpec::new("x", 1, 3)]);
        let mut rng = Rng::new(1);
        let pts = halton_lattice(&sp, 300, &mut rng);
        let mut counts = [0usize; 3];
        for p in pts {
            counts[(p[0].as_i64() - 1) as usize] += 1;
        }
        for c in counts {
            assert!((80..=120).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn halton_covers_mixed_typed_spaces() {
        use crate::space::{ParamKind, Value};
        let sp = Space::new(vec![
            ParamSpec::log_continuous("lr", 1e-4, 1e-1),
            ParamSpec::categorical("opt", &["sgd", "adam"]),
            ParamSpec::int("layers", 1, 3),
        ]);
        let mut rng = Rng::new(5);
        let pts = halton_lattice(&sp, 200, &mut rng);
        let mut cats = [0usize; 2];
        let mut low_decade = 0usize;
        for p in &pts {
            assert!(sp.contains(p), "{p:?}");
            cats[p[1].as_index()] += 1;
            if let Value::Float(lr) = p[0] {
                if lr < 1e-2 {
                    low_decade += 1;
                }
            }
        }
        // Even split across the categorical buckets.
        assert!((80..=120).contains(&cats[0]), "{cats:?}");
        // Log warp: two of three decades sit below 1e-2.
        assert!((110..=160).contains(&low_decade), "{low_decade}");
        assert!(matches!(
            sp.params()[0].kind,
            ParamKind::Continuous { log: true, .. }
        ));
    }

    #[test]
    fn lhs_stratifies_each_dimension() {
        let sp = Space::new(vec![
            ParamSpec::new("a", 0, 99),
            ParamSpec::new("b", 0, 99),
        ]);
        let mut rng = Rng::new(2);
        let n = 10;
        let pts = lhs_lattice(&sp, n, &mut rng);
        for d in 0..2 {
            let mut deciles: Vec<usize> =
                pts.iter().map(|p| (p[d].as_i64() / 10) as usize).collect();
            deciles.sort();
            deciles.dedup();
            assert_eq!(deciles.len(), n, "dim {d} not stratified");
        }
    }
}
