//! Sobol' low-discrepancy sequence with integer-lattice adaptation —
//! the paper's §VI roadmap item ("there is also an opportunity to modify
//! the computation of the sample points in Sobol's sequences" for integer
//! constraints), implemented.
//!
//! Direction numbers follow Joe & Kuo (2008) for the first 10 dimensions
//! (primitive polynomials + initial m values), enough for every search
//! space in this reproduction (max 8 hyperparameters in Table I). The
//! integer adaptation maps each coordinate through equal-width quantile
//! buckets (`Space::from_unit`), the same scheme validated for Halton.

use crate::sampling::rng::Rng;
use crate::space::{Point, Space};

const BITS: usize = 31;

/// (degree s, coefficient a, initial direction numbers m_1..m_s) per
/// dimension ≥ 1; dimension 0 is the van der Corput sequence in base 2.
/// From the Joe-Kuo "new-joe-kuo-6.21201" table.
const JOE_KUO: [(u32, u32, [u32; 7]); 9] = [
    (1, 0, [1, 0, 0, 0, 0, 0, 0]),
    (2, 1, [1, 3, 0, 0, 0, 0, 0]),
    (3, 1, [1, 3, 1, 0, 0, 0, 0]),
    (3, 2, [1, 1, 1, 0, 0, 0, 0]),
    (4, 1, [1, 1, 3, 3, 0, 0, 0]),
    (4, 4, [1, 3, 5, 13, 0, 0, 0]),
    (5, 2, [1, 1, 5, 5, 17, 0, 0]),
    (5, 4, [1, 1, 5, 5, 5, 0, 0]),
    (5, 7, [1, 1, 7, 11, 19, 0, 0]),
];

/// Sobol' sequence generator over [0,1)^dim.
#[derive(Debug, Clone)]
pub struct Sobol {
    dim: usize,
    /// v[d][b]: direction number b of dimension d (scaled to 2^BITS).
    v: Vec<[u32; BITS]>,
    x: Vec<u32>,
    index: u64,
    shift: Vec<u32>,
}

impl Sobol {
    /// Plain (unshifted) sequence.
    pub fn new(dim: usize) -> Self {
        Self::scrambled(dim, None)
    }

    /// Digitally shifted sequence (Owen-style random shift) for
    /// decorrelated replications.
    pub fn scrambled(dim: usize, rng: Option<&mut Rng>) -> Self {
        assert!(
            (1..=JOE_KUO.len() + 1).contains(&dim),
            "sobol supports 1..={} dims",
            JOE_KUO.len() + 1
        );
        let mut v = Vec::with_capacity(dim);
        // Dimension 0: v_b = 2^(BITS-1-b).
        let mut v0 = [0u32; BITS];
        for (b, item) in v0.iter_mut().enumerate() {
            *item = 1 << (BITS - 1 - b);
        }
        v.push(v0);
        for d in 1..dim {
            let (s, a, m) = JOE_KUO[d - 1];
            let s = s as usize;
            let mut vd = [0u32; BITS];
            for b in 0..BITS {
                if b < s {
                    vd[b] = m[b] << (BITS - 1 - b);
                } else {
                    let mut val = vd[b - s] ^ (vd[b - s] >> s);
                    for k in 1..s {
                        if (a >> (s - 1 - k)) & 1 == 1 {
                            val ^= vd[b - k];
                        }
                    }
                    vd[b] = val;
                }
            }
            v.push(vd);
        }
        let shift = match rng {
            Some(r) => (0..dim)
                .map(|_| (r.next_u64() as u32) & ((1 << BITS) - 1))
                .collect(),
            None => vec![0; dim],
        };
        Sobol { dim, v, x: vec![0; dim], index: 0, shift }
    }

    /// Next point in [0,1)^dim (Gray-code order).
    pub fn next_point(&mut self) -> Vec<f64> {
        // Gray code: flip the bit at the position of the lowest zero bit
        // of the running index.
        let c = (!self.index).trailing_zeros() as usize;
        let c = c.min(BITS - 1);
        for d in 0..self.dim {
            self.x[d] ^= self.v[d][c];
        }
        self.index += 1;
        self.x
            .iter()
            .zip(&self.shift)
            .map(|(x, s)| {
                ((x ^ s) as f64) / (1u64 << BITS) as f64
            })
            .collect()
    }
}

/// `n` typed points from a (shifted) Sobol' sequence, mapped through
/// the space's encoding layer.
pub fn sobol_lattice(space: &Space, n: usize, rng: &mut Rng) -> Vec<Point> {
    let mut seq = Sobol::scrambled(space.dim(), Some(rng));
    // Skip the first point (all-shift), conventional for shifted nets.
    let _ = seq.next_point();
    (0..n).map(|_| space.from_unit(&seq.next_point())).collect()
}

/// Star-discrepancy proxy: max deviation of the empirical CDF from
/// uniform over axis-aligned anchored boxes sampled at the points
/// themselves (exact star discrepancy is exponential; this proxy ranks
/// sequences reliably and is only used by tests/benches).
pub fn discrepancy_proxy(points: &[Vec<f64>]) -> f64 {
    let n = points.len() as f64;
    let mut worst: f64 = 0.0;
    for anchor in points {
        let vol: f64 = anchor.iter().product();
        let count = points
            .iter()
            .filter(|p| p.iter().zip(anchor).all(|(a, b)| a < b))
            .count() as f64;
        worst = worst.max((count / n - vol).abs());
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::ParamSpec;

    #[test]
    fn first_points_of_dim1_are_van_der_corput() {
        let mut s = Sobol::new(1);
        let seq: Vec<f64> =
            (0..4).map(|_| s.next_point()[0]).collect();
        assert_eq!(seq, vec![0.5, 0.75, 0.25, 0.375]);
    }

    #[test]
    fn points_in_unit_cube_and_distinct() {
        let mut s = Sobol::new(6);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..512 {
            let p = s.next_point();
            assert!(p.iter().all(|v| (0.0..1.0).contains(v)), "{p:?}");
            let key: Vec<u64> =
                p.iter().map(|v| (v * 1e12) as u64).collect();
            assert!(seen.insert(key), "duplicate Sobol point");
        }
    }

    #[test]
    fn beats_random_on_discrepancy() {
        let mut sobol = Sobol::new(4);
        let sp: Vec<Vec<f64>> =
            (0..256).map(|_| sobol.next_point()).collect();
        let mut rng = Rng::new(0);
        let rp: Vec<Vec<f64>> = (0..256)
            .map(|_| (0..4).map(|_| rng.f64()).collect())
            .collect();
        let ds = discrepancy_proxy(&sp);
        let dr = discrepancy_proxy(&rp);
        assert!(
            ds < dr * 0.6,
            "sobol {ds} not clearly better than random {dr}"
        );
    }

    #[test]
    fn shifted_sequences_differ_but_stay_low_discrepancy() {
        let mut rng = Rng::new(1);
        let mut a = Sobol::scrambled(3, Some(&mut rng));
        let mut b = Sobol::scrambled(3, Some(&mut rng));
        let pa: Vec<Vec<f64>> = (0..128).map(|_| a.next_point()).collect();
        let pb: Vec<Vec<f64>> = (0..128).map(|_| b.next_point()).collect();
        assert_ne!(pa[0], pb[0]);
        assert!(discrepancy_proxy(&pa) < 0.15);
        assert!(discrepancy_proxy(&pb) < 0.15);
    }

    #[test]
    fn lattice_points_valid_and_balanced() {
        let space = Space::new(vec![
            ParamSpec::new("a", 0, 3),
            ParamSpec::new("b", -2, 2),
        ]);
        let mut rng = Rng::new(2);
        let pts = sobol_lattice(&space, 400, &mut rng);
        let mut counts = [0usize; 4];
        for p in &pts {
            assert!(space.contains(p), "{p:?}");
            counts[p[0].as_i64() as usize] += 1;
        }
        // Quantile-bucket adaptation keeps each cell near n/4.
        for c in counts {
            assert!((70..=130).contains(&c), "{counts:?}");
        }
    }

    #[test]
    #[should_panic(expected = "sobol supports")]
    fn too_many_dims_rejected() {
        let _ = Sobol::new(64);
    }
}
