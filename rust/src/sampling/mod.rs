//! Sampling substrate: deterministic RNG, low-discrepancy sequences, and
//! Latin hypercube designs over the integer lattice.

pub mod lowdisc;
pub mod rng;
pub mod sobol;

pub use lowdisc::{halton_lattice, lhs_lattice};
pub use rng::Rng;
pub use sobol::{sobol_lattice, Sobol};
