//! The sans-IO experiment core: HYPPO's Fig. 6 loop as a pure state
//! machine.
//!
//! `Session` owns every *decision* of an experiment — what to evaluate
//! next, how the paper's trial-level uncertainty accounting folds N
//! trial outcomes into one history record, when the surrogate absorbs a
//! completion — and none of the *execution*: no threads, no sleeps, no
//! filesystem. Callers drive it with two calls:
//!
//! * [`Session::ask`] hands out the next [`Trial`] to run — an
//!   initial-design point, a surrogate proposal, or (under
//!   [`AdaptiveTrials`](crate::optimizer::AdaptiveTrials)) an extra UQ
//!   replica of an in-flight θ.
//! * [`Session::tell`] absorbs one completed [`TrialOutcome`]. When a
//!   θ's trial set is complete it is aggregated via
//!   [`aggregate`](crate::eval::aggregate) (Eqs. 4-9), recorded, and
//!   fed to the [`OnlineProposer`] incrementally.
//!
//! Everything that *runs* trials — the threaded `exec::driver`, the
//! virtual-time `cluster::sim::simulate_hpo`, external schedulers, the
//! `examples/ask_tell.rs` hand-rolled loop — is a shell around this
//! type, so the optimization brain exists exactly once (DESIGN.md §6).
//!
//! # State machine
//!
//! ```text
//!            ask()                        tell()
//!   Init ────────────► trials of the    ────────► buffer until the whole
//!   (barrier)           initial design             design is in, then
//!                                                  flush in id order
//!            ask()                        tell()
//!   Adaptive ────────► propose θ, hand  ────────► aggregate → record →
//!                       out its trials             observe (incremental
//!                       (then replicas)            refit) — or extend θ
//!                                                  with a replica when
//!                                                  trained-loss spread
//!                                                  is too high
//! ```
//!
//! # Invariants
//!
//! * An evaluation's trials are handed out contiguously: once `ask`
//!   returns trial j of evaluation e, the next `planned - j - 1` asks
//!   return e's remaining trials before any other work (shells may
//!   therefore batch one evaluation per worker).
//! * No proposal is created before the full initial design is recorded
//!   (the surrogate's starting state is independent of worker timing),
//!   and at most `max_evaluations` evaluations are ever created.
//! * `snapshot`/`restore` round-trips are exact for the decision state:
//!   RNG, counters, history, and in-flight jobs. Partially-told trial
//!   outcomes are deliberately *not* captured — a restored session asks
//!   for the full trial set of each in-flight θ again with its original
//!   `(θ, seed)` pair, so deterministic evaluators reproduce (and, under
//!   adaptive replicas, re-extend) the killed run exactly.

use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::eval::{aggregate, Evaluator, TrialOutcome};
use crate::exec::checkpoint::{Checkpoint, PendingJob, CHECKPOINT_VERSION};
use crate::optimizer::{
    initial_design, EvalRecord, History, HpoConfig, OnlineProposer,
    RefitStats,
};
use crate::sampling::rng::Rng;
use crate::space::{Point, Space};

/// Why a trial is being requested.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrialKind {
    /// Part of the initial experimental design.
    Init,
    /// Part of a surrogate-proposed evaluation.
    Proposal,
    /// An extra UQ replica scheduled by the
    /// [`AdaptiveTrials`](crate::optimizer::AdaptiveTrials) policy.
    Replica,
}

/// One unit of work handed to an executor: train one model for `theta`
/// (trial index `trial`, evaluation seed `seed`) and `tell` the outcome.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Trial {
    /// Evaluation (submission) id this trial belongs to.
    pub eval_id: usize,
    /// Trial index within the evaluation (passed to `run_trial`).
    pub trial: usize,
    /// Trials currently planned for this evaluation; this is trial
    /// `trial` of `planned`. May grow later under adaptive replicas.
    pub planned: usize,
    /// The hyperparameter set under evaluation.
    pub theta: Point,
    /// The evaluation seed (shared by all trials of this θ).
    pub seed: u64,
    /// What kind of work this is.
    pub kind: TrialKind,
}

/// Result of [`Session::ask`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Ask {
    /// Run this trial and `tell` its outcome.
    Trial(Trial),
    /// Nothing to hand out until more outcomes are told (all in-flight
    /// work is already dispatched, or the init barrier is pending).
    Wait,
    /// The full evaluation budget has been recorded.
    Done,
}

/// An evaluation-granular batch of trials (a convenience over [`Ask`]
/// for shells that dispatch whole evaluations to workers).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EvalJob {
    /// Evaluation id.
    pub id: usize,
    /// The hyperparameter set.
    pub theta: Point,
    /// The evaluation seed.
    pub seed: u64,
    /// Trial indices to run (contiguous slice of the evaluation's plan).
    pub trials: Vec<usize>,
}

/// What one [`Session::tell`] call did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Told {
    /// Evaluations recorded into the history by this call (usually 0 or
    /// 1; the init barrier flushes the whole design at once).
    pub recorded: usize,
    /// Extra replica trials scheduled for this θ by
    /// [`AdaptiveTrials`](crate::optimizer::AdaptiveTrials).
    pub extended: usize,
}

/// What a [`Session::tell`] for `(eval_id, trial)` would do — the typed
/// pre-flight the service boundary (`serve::shard`) uses to reject
/// duplicate or misaddressed deliveries with a protocol error code
/// instead of string-matching `tell`'s error text.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TellCheck {
    /// The outcome would be absorbed.
    Accept,
    /// No pending *or recorded* evaluation has this id.
    UnknownEval,
    /// The trial index is outside the evaluation's planned set.
    BadTrial,
    /// The outcome was already delivered (or the whole evaluation is
    /// already recorded) — a redelivery to reject idempotently.
    Duplicate,
}

/// One in-flight evaluation: its serializable identity plus the trial
/// bookkeeping that lives only between `ask` and `tell`.
#[derive(Debug, Clone)]
struct PendingEval {
    job: PendingJob,
    /// Initial-design evaluation (subject to the record barrier).
    init: bool,
    /// Total trials currently planned (≥ `HpoConfig::n_trials`).
    planned: usize,
    /// Trials handed out via `ask` so far (hand-out is in index order).
    handed: usize,
    /// Outcomes received, indexed by trial.
    outcomes: Vec<Option<TrialOutcome>>,
    /// Complete but buffered behind the init barrier.
    buffered: bool,
}

impl PendingEval {
    fn new(job: PendingJob, init: bool, planned: usize) -> Self {
        PendingEval {
            job,
            init,
            planned,
            handed: 0,
            outcomes: vec![None; planned],
            buffered: false,
        }
    }

    fn received(&self) -> usize {
        self.outcomes.iter().filter(|o| o.is_some()).count()
    }
}

/// The pure ask/tell experiment core. See the module docs for the state
/// machine; see `exec::driver` for the threaded shell.
pub struct Session<'ev> {
    evaluator: Box<dyn Evaluator + 'ev>,
    hpo: HpoConfig,
    space: Space,
    rng: Rng,
    next_id: usize,
    iter: usize,
    submitted: usize,
    history: History,
    proposer: OnlineProposer,
    pending: Vec<PendingEval>,
}

impl<'ev> Session<'ev> {
    /// Start a fresh experiment. The initial design is drawn immediately
    /// (so the first snapshot already fixes the whole design), but no
    /// trial runs until the caller asks for it.
    ///
    /// The evaluator reference is used only for its pure surface —
    /// `space()`, `n_params()`, `loss_of_mean_prediction()` — never for
    /// `run_trial`; running trials is the caller's job.
    pub fn new(evaluator: &'ev dyn Evaluator, hpo: &HpoConfig) -> Self {
        Self::new_boxed(Box::new(evaluator), hpo)
    }

    /// [`Session::new`] taking ownership of the evaluator. A
    /// `Box<dyn Evaluator>` (`'ev = 'static`) makes the session
    /// free-standing — the form the `serve` shards need to own a fleet
    /// of sessions whose studies come and go dynamically.
    pub fn new_boxed(
        evaluator: Box<dyn Evaluator + 'ev>,
        hpo: &HpoConfig,
    ) -> Self {
        let mut s = Session {
            space: evaluator.space().clone(),
            evaluator,
            hpo: hpo.clone(),
            rng: Rng::new(hpo.seed),
            next_id: 0,
            iter: 0,
            submitted: 0,
            history: History::default(),
            proposer: OnlineProposer::new(hpo),
            pending: Vec::new(),
        };
        s.submit_initial_design();
        s
    }

    /// Rebuild a session from a [`Checkpoint`] (the plain-data form of
    /// [`Session::snapshot`]). The checkpoint must come from a run with
    /// the same `HpoConfig::seed` — a cheap witness that the
    /// configuration matches.
    pub fn restore(
        evaluator: &'ev dyn Evaluator,
        hpo: &HpoConfig,
        ckpt: Checkpoint,
    ) -> Result<Self> {
        Self::restore_boxed(Box::new(evaluator), hpo, ckpt)
    }

    /// [`Session::restore`] taking ownership of the evaluator (see
    /// [`Session::new_boxed`]).
    pub fn restore_boxed(
        evaluator: Box<dyn Evaluator + 'ev>,
        hpo: &HpoConfig,
        ckpt: Checkpoint,
    ) -> Result<Self> {
        if ckpt.seed != hpo.seed {
            bail!(
                "checkpoint seed {} does not match config seed {}",
                ckpt.seed,
                hpo.seed
            );
        }
        let space = evaluator.space().clone();
        // Every θ in the snapshot must be a well-typed member of the
        // *current* space: a checkpoint taken under a different space
        // definition (e.g. a pre-typed-space integer encoding of a
        // parameter that is continuous now) would otherwise panic deep
        // inside the evaluator or silently feed the surrogate garbage
        // features.
        for theta in ckpt
            .history
            .records
            .iter()
            .map(|r| &r.theta)
            .chain(ckpt.in_flight.iter().map(|j| &j.theta))
        {
            if !space.contains(theta) {
                bail!(
                    "checkpoint θ {:?} is not a member of the current \
                     search space — the space definition changed since \
                     the snapshot was written",
                    theta
                );
            }
        }
        let mut proposer = OnlineProposer::new(hpo);
        proposer.preload(&space, &ckpt.history);
        let n_trials = hpo.n_trials.max(1);
        let mut s = Session {
            evaluator,
            hpo: hpo.clone(),
            space,
            rng: Rng::from_state(ckpt.rng_state),
            next_id: ckpt.next_id,
            iter: ckpt.iter,
            submitted: ckpt.submitted,
            history: ckpt.history,
            proposer,
            pending: ckpt
                .in_flight
                .into_iter()
                .map(|job| {
                    let init = job.provenance.is_empty();
                    PendingEval::new(job, init, n_trials)
                })
                .collect(),
        };
        // A snapshot taken before anything was submitted restores to a
        // fresh session.
        if s.history.is_empty() && s.pending.is_empty() && s.submitted == 0
        {
            s.submit_initial_design();
        }
        Ok(s)
    }

    fn submit_initial_design(&mut self) {
        let init = initial_design(&self.space, &self.hpo, &mut self.rng);
        let n_trials = self.hpo.n_trials.max(1);
        for theta in init.into_iter().take(self.hpo.max_evaluations) {
            let job = PendingJob {
                id: self.next_id,
                theta,
                provenance: vec![],
                seed: self.rng.next_u64(),
            };
            self.pending.push(PendingEval::new(job, true, n_trials));
            self.next_id += 1;
            self.submitted += 1;
        }
    }

    /// Initial-design evaluations not yet recorded (the barrier count).
    fn init_remaining(&self) -> usize {
        self.pending.iter().filter(|p| p.init).count()
    }

    /// The next trial to run, or why there is none.
    pub fn ask(&mut self) -> Ask {
        // 1. Hand out a queued trial: first pending evaluation (FIFO)
        //    with trials not yet dished out. Hand-out is contiguous per
        //    evaluation by construction.
        let n_trials = self.hpo.n_trials.max(1);
        if let Some(p) =
            self.pending.iter_mut().find(|p| p.handed < p.planned)
        {
            let trial = p.handed;
            p.handed += 1;
            // Replica wins over Init: an adaptively extended init eval's
            // extra trials are replicas too.
            let kind = if trial >= n_trials {
                TrialKind::Replica
            } else if p.init {
                TrialKind::Init
            } else {
                TrialKind::Proposal
            };
            return Ask::Trial(Trial {
                eval_id: p.job.id,
                trial,
                planned: p.planned,
                theta: p.job.theta.clone(),
                seed: p.job.seed,
                kind,
            });
        }
        // 2. Budget recorded: the experiment is over.
        if self.history.len() >= self.hpo.max_evaluations {
            return Ask::Done;
        }
        // 3. The init barrier is pending, or every evaluation in the
        //    budget has been created: outcomes must arrive first.
        if self.init_remaining() > 0
            || self.submitted >= self.hpo.max_evaluations
            || self.history.is_empty()
        {
            return Ask::Wait;
        }
        // 4. Propose a new evaluation and hand out its first trial.
        let theta = self.proposer.propose(
            &self.space,
            &self.history,
            self.iter,
            &mut self.rng,
        );
        self.iter += 1;
        let job = PendingJob {
            id: self.next_id,
            theta,
            provenance: self.history.records.iter().map(|r| r.id).collect(),
            seed: self.rng.next_u64(),
        };
        self.next_id += 1;
        self.submitted += 1;
        let mut p = PendingEval::new(job, false, n_trials);
        p.handed = 1;
        let t = Trial {
            eval_id: p.job.id,
            trial: 0,
            planned: p.planned,
            theta: p.job.theta.clone(),
            seed: p.job.seed,
            kind: TrialKind::Proposal,
        };
        self.pending.push(p);
        Ask::Trial(t)
    }

    /// Evaluation-granular convenience over [`Session::ask`]: the next
    /// askable trial plus every remaining currently-planned trial of the
    /// same evaluation (the contiguity invariant guarantees they follow).
    pub fn ask_eval(&mut self) -> Option<EvalJob> {
        let first = match self.ask() {
            Ask::Trial(t) => t,
            Ask::Wait | Ask::Done => return None,
        };
        let mut trials = vec![first.trial];
        for _ in first.trial + 1..first.planned {
            match self.ask() {
                Ask::Trial(t) if t.eval_id == first.eval_id => {
                    trials.push(t.trial)
                }
                _ => unreachable!(
                    "an evaluation's trials are handed out contiguously"
                ),
            }
        }
        Some(EvalJob {
            id: first.eval_id,
            theta: first.theta,
            seed: first.seed,
            trials,
        })
    }

    /// Forget every outcome of an in-flight evaluation and hand its
    /// trials out again from trial 0 — the recovery path for a worker
    /// that died, was preempted, or lost its result channel. The
    /// evaluation keeps its identity (id, θ, seed, provenance) and its
    /// current `planned` count, so a deterministic evaluator replays the
    /// exact same trial set and the optimization trace is unchanged (the
    /// chaos testbed's headline invariant, `tests/chaos.rs`). FIFO
    /// hand-out means a requeued evaluation re-emerges from
    /// [`Session::ask`] before any new proposal.
    pub fn requeue(&mut self, eval_id: usize) -> Result<()> {
        let p = self
            .pending
            .iter_mut()
            .find(|p| p.job.id == eval_id)
            .ok_or_else(|| {
                anyhow!("requeue for unknown evaluation {eval_id}")
            })?;
        if p.buffered {
            bail!(
                "evaluation {eval_id} already completed (buffered behind \
                 the init barrier); refusing to requeue finished work"
            );
        }
        p.handed = 0;
        p.outcomes = vec![None; p.planned];
        Ok(())
    }

    /// Absorb one trial outcome. When this completes the evaluation's
    /// trial set, the evaluation is aggregated (Eqs. 4-9) and recorded —
    /// or extended with a replica when the
    /// [`AdaptiveTrials`](crate::optimizer::AdaptiveTrials) policy says
    /// its trained-loss spread is still too high.
    pub fn tell(
        &mut self,
        eval_id: usize,
        trial: usize,
        outcome: TrialOutcome,
    ) -> Result<Told> {
        let idx = self
            .pending
            .iter()
            .position(|p| p.job.id == eval_id)
            .ok_or_else(|| {
                anyhow!("tell for unknown evaluation {eval_id}")
            })?;
        {
            let p = &mut self.pending[idx];
            if trial >= p.planned {
                bail!(
                    "trial {trial} out of range for evaluation {eval_id} \
                     ({} planned)",
                    p.planned
                );
            }
            if p.outcomes[trial].is_some() {
                bail!(
                    "duplicate outcome for evaluation {eval_id} trial \
                     {trial}"
                );
            }
            p.outcomes[trial] = Some(outcome);
            if p.received() < p.planned {
                return Ok(Told::default());
            }
        }
        // The trial set is complete. Adaptive policy: one more replica at
        // a time while the trained-loss spread stays above threshold.
        if let Some(pol) = self.hpo.adaptive_trials {
            let p = &mut self.pending[idx];
            let losses: Vec<f64> =
                p.outcomes.iter().flatten().map(|o| o.loss).collect();
            if p.planned < pol.max_trials.max(1)
                && crate::uq::stddev(&losses) > pol.std_threshold
            {
                p.planned += 1;
                p.outcomes.push(None);
                return Ok(Told { recorded: 0, extended: 1 });
            }
        }
        Ok(self.finish(idx))
    }

    /// Record the complete pending evaluation at `idx` — directly for
    /// adaptive-phase evaluations, behind the id-order barrier for the
    /// initial design. Shared completion tail of [`Session::tell`] and
    /// [`Session::poison`].
    fn finish(&mut self, idx: usize) -> Told {
        let mut told = Told::default();
        if self.pending[idx].init {
            self.pending[idx].buffered = true;
            if self.pending.iter().any(|p| p.init && !p.buffered) {
                return told;
            }
            let (mut inits, rest): (Vec<_>, Vec<_>) =
                std::mem::take(&mut self.pending)
                    .into_iter()
                    .partition(|p| p.init);
            self.pending = rest;
            inits.sort_by_key(|p| p.job.id);
            for p in inits {
                self.record(p);
                told.recorded += 1;
            }
        } else {
            let p = self.pending.remove(idx);
            self.record(p);
            told.recorded = 1;
        }
        told
    }

    /// Quarantine a pending evaluation: overwrite whatever partial
    /// outcomes exist with a deterministic penalty outcome for every
    /// planned trial, then record the evaluation through the normal
    /// completion path (init barrier included).
    ///
    /// This is the `serve` layer's poison-trial endpoint: an evaluation
    /// whose lease keeps expiring is *scored* as `penalty` rather than
    /// requeued forever or silently dropped — the record stays in the
    /// history (checkpoint schema unchanged) so replay and audit see it.
    /// The synthesized record is a function of `(θ, planned, penalty)`
    /// only — independent of which partial outcomes had arrived and of
    /// *when* the quarantine fired — so poisoned entries are bit-stable
    /// across faulted/fault-free runs. The adaptive-trials extension is
    /// deliberately bypassed: the trial set is synthetic, its spread is
    /// zero by construction, and extending a quarantined evaluation
    /// would hand out more doomed work.
    pub fn poison(&mut self, eval_id: usize, penalty: f64) -> Result<Told> {
        if !penalty.is_finite() {
            bail!("poison penalty must be finite, got {penalty}");
        }
        let idx = self
            .pending
            .iter()
            .position(|p| p.job.id == eval_id)
            .ok_or_else(|| {
                anyhow!("poison for unknown evaluation {eval_id}")
            })?;
        let Some(p) = self.pending.get_mut(idx) else {
            bail!("poison lost evaluation {eval_id} mid-flight");
        };
        if p.buffered {
            bail!(
                "evaluation {eval_id} already completed (buffered behind \
                 the init barrier); refusing to poison finished work"
            );
        }
        let quarantined = TrialOutcome {
            loss: penalty,
            dropout_losses: Vec::new(),
            predictions: None,
            dropout_predictions: Vec::new(),
            cost: Duration::ZERO,
        };
        p.outcomes = vec![Some(quarantined); p.planned];
        Ok(self.finish(idx))
    }

    /// Aggregate a completed evaluation into the history and feed the
    /// surrogate (incremental refit where the surrogate supports it).
    fn record(&mut self, p: PendingEval) {
        let outcomes: Vec<TrialOutcome> = p
            .outcomes
            .into_iter()
            .map(|o| o.expect("recorded evaluation is complete"))
            .collect();
        let summary = aggregate(
            &*self.evaluator,
            &p.job.theta,
            &outcomes,
            self.hpo.weights,
        );
        let record = EvalRecord {
            id: p.job.id,
            n_params: self.evaluator.n_params(&p.job.theta),
            theta: p.job.theta,
            summary,
            provenance: p.job.provenance,
        };
        self.proposer.observe(&self.space, &record);
        self.history.records.push(record);
    }

    /// Snapshot the decision state as plain data (see the module docs
    /// for what is deliberately *not* captured). `exec::checkpoint`
    /// serializes exactly this.
    pub fn snapshot(&self) -> Checkpoint {
        Checkpoint {
            version: CHECKPOINT_VERSION,
            seed: self.hpo.seed,
            rng_state: self.rng.state(),
            next_id: self.next_id,
            iter: self.iter,
            submitted: self.submitted,
            history: self.history.clone(),
            in_flight: self.pending.iter().map(|p| p.job.clone()).collect(),
        }
    }

    /// Evaluations recorded so far, in completion order.
    pub fn history(&self) -> &History {
        &self.history
    }

    /// Consume the session, returning the history.
    pub fn into_history(self) -> History {
        self.history
    }

    /// True when the full evaluation budget has been recorded.
    pub fn is_complete(&self) -> bool {
        self.history.len() >= self.hpo.max_evaluations
    }

    /// Evaluations created but not yet recorded.
    pub fn in_flight(&self) -> usize {
        self.pending.len()
    }

    /// Surrogate refit counters accumulated so far.
    pub fn stats(&self) -> RefitStats {
        self.proposer.stats()
    }

    /// The problem configuration the session was built with.
    pub fn hpo(&self) -> &HpoConfig {
        &self.hpo
    }

    /// The search space the session was built over.
    pub fn space(&self) -> &Space {
        &self.space
    }

    /// Ids of evaluations created but not yet recorded, in FIFO order.
    pub fn pending_ids(&self) -> Vec<usize> {
        self.pending.iter().map(|p| p.job.id).collect()
    }

    /// Pending evaluations whose trials were handed out but whose set is
    /// not yet complete — the evaluations some executor still owes
    /// outcomes for. After a crash no executor will answer: recovery
    /// ([`serve`](crate::serve)) requeues exactly this set.
    pub fn outstanding_ids(&self) -> Vec<usize> {
        self.pending
            .iter()
            .filter(|p| p.handed > 0 && !p.buffered)
            .map(|p| p.job.id)
            .collect()
    }

    /// Classify what [`Session::tell`] would do with `(eval_id, trial)`,
    /// without mutating anything.
    pub fn check_tell(&self, eval_id: usize, trial: usize) -> TellCheck {
        match self.pending.iter().find(|p| p.job.id == eval_id) {
            Some(p) if trial >= p.planned => TellCheck::BadTrial,
            Some(p) => {
                let delivered = p
                    .outcomes
                    .get(trial)
                    .map(|o| o.is_some())
                    .unwrap_or(false);
                if delivered || p.buffered {
                    TellCheck::Duplicate
                } else {
                    TellCheck::Accept
                }
            }
            None => {
                if self.history.records.iter().any(|r| r.id == eval_id) {
                    TellCheck::Duplicate
                } else {
                    TellCheck::UnknownEval
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::synthetic::SyntheticEvaluator;
    use crate::optimizer::AdaptiveTrials;
    use crate::space::{ParamSpec, Space};

    fn evaluator(seed: u64) -> SyntheticEvaluator {
        let space = Space::new(vec![
            ParamSpec::new("a", 0, 24),
            ParamSpec::new("b", 0, 24),
        ]);
        let mut ev = SyntheticEvaluator::new(space, seed);
        ev.t_dropout = 3;
        ev
    }

    fn cfg(budget: usize, seed: u64) -> HpoConfig {
        HpoConfig {
            max_evaluations: budget,
            n_init: 4,
            n_trials: 2,
            seed,
            ..Default::default()
        }
    }

    /// Run a session to completion with a sequential ask→run→tell loop.
    fn drain(session: &mut Session) {
        loop {
            match session.ask() {
                Ask::Trial(t) => {
                    let o = session
                        .evaluator
                        .run_trial(&t.theta, t.trial, t.seed);
                    session.tell(t.eval_id, t.trial, o).unwrap();
                }
                Ask::Wait => panic!("sequential loop can never starve"),
                Ask::Done => break,
            }
        }
    }

    #[test]
    fn sequential_ask_tell_completes_budget() {
        let ev = evaluator(7);
        let mut s = Session::new(&ev, &cfg(12, 1));
        drain(&mut s);
        assert!(s.is_complete());
        assert_eq!(s.in_flight(), 0);
        let h = s.into_history();
        assert_eq!(h.len(), 12);
        for (i, r) in h.records.iter().enumerate() {
            assert_eq!(r.id, i);
            if i < 4 {
                assert!(r.provenance.is_empty());
            } else {
                assert_eq!(r.provenance, (0..i).collect::<Vec<_>>());
            }
        }
    }

    #[test]
    fn trials_are_contiguous_per_evaluation() {
        let ev = evaluator(3);
        let mut s = Session::new(&ev, &cfg(8, 5));
        let mut last: Option<(usize, usize)> = None;
        loop {
            match s.ask() {
                Ask::Trial(t) => {
                    if let Some((id, trial)) = last {
                        if t.eval_id == id {
                            assert_eq!(t.trial, trial + 1);
                        } else {
                            assert_eq!(t.trial, 0);
                        }
                    }
                    last = Some((t.eval_id, t.trial));
                    let o =
                        s.evaluator.run_trial(&t.theta, t.trial, t.seed);
                    s.tell(t.eval_id, t.trial, o).unwrap();
                }
                Ask::Done => break,
                Ask::Wait => unreachable!(),
            }
        }
    }

    #[test]
    fn no_proposals_before_the_init_barrier() {
        let ev = evaluator(2);
        let mut s = Session::new(&ev, &cfg(10, 3));
        // Collect the whole initial design without telling anything.
        let mut init_trials = Vec::new();
        loop {
            match s.ask() {
                Ask::Trial(t) => {
                    assert_eq!(t.kind, TrialKind::Init);
                    init_trials.push(t);
                }
                Ask::Wait => break,
                Ask::Done => panic!("not done"),
            }
        }
        assert_eq!(init_trials.len(), 4 * 2);
        // Tell all but the last: still waiting.
        let last = init_trials.pop().unwrap();
        for t in &init_trials {
            let o = ev.run_trial(&t.theta, t.trial, t.seed);
            assert_eq!(
                s.tell(t.eval_id, t.trial, o).unwrap().recorded,
                0
            );
        }
        assert_eq!(s.ask(), Ask::Wait);
        // The last outcome flushes the barrier in id order.
        let o = ev.run_trial(&last.theta, last.trial, last.seed);
        let told = s.tell(last.eval_id, last.trial, o).unwrap();
        assert_eq!(told.recorded, 4);
        let ids: Vec<usize> =
            s.history().records.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        // Now proposals flow.
        match s.ask() {
            Ask::Trial(t) => assert_eq!(t.kind, TrialKind::Proposal),
            other => panic!("expected a proposal, got {other:?}"),
        }
    }

    #[test]
    fn tell_rejects_unknown_and_duplicate() {
        let ev = evaluator(1);
        let mut s = Session::new(&ev, &cfg(6, 2));
        let t = match s.ask() {
            Ask::Trial(t) => t,
            _ => unreachable!(),
        };
        let o = ev.run_trial(&t.theta, t.trial, t.seed);
        assert!(s.tell(999, 0, o.clone()).is_err());
        s.tell(t.eval_id, t.trial, o.clone()).unwrap();
        assert!(s.tell(t.eval_id, t.trial, o.clone()).is_err());
        assert!(s.tell(t.eval_id, 99, o).is_err());
    }

    #[test]
    fn poison_scores_penalty_and_ignores_partial_outcomes() {
        // Two sessions, same seed. In A the quarantined evaluation is
        // poisoned untouched; in B it first absorbs a partial outcome.
        // The poisoned record — and everything downstream of it — must
        // be bit-identical: quarantine is a function of (θ, planned,
        // penalty) only.
        let penalty = 123.5;
        let run = |partial: bool| {
            let ev = evaluator(11);
            let mut s = Session::new(&ev, &cfg(8, 6));
            drain_init(&mut s);
            let job = s.ask_eval().expect("proposal available");
            if partial {
                let t0 = *job.trials.first().unwrap();
                let o = ev.run_trial(&job.theta, t0, job.seed);
                s.tell(job.id, t0, o).unwrap();
            }
            let told = s.poison(job.id, penalty).unwrap();
            assert_eq!(told.recorded, 1);
            drain(&mut s);
            s.into_history()
        };
        let a = run(false);
        let b = run(true);
        assert_eq!(
            serde_json::to_string(&a).unwrap(),
            serde_json::to_string(&b).unwrap(),
            "poisoned history depends on partial outcomes"
        );
        // First proposal after the 4-evaluation initial design.
        let r = a.records.iter().find(|r| r.id == 4).unwrap();
        assert_eq!(r.summary.trained_mean, penalty);
        assert_eq!(r.summary.trained_std, 0.0);
        assert_eq!(r.summary.interval.center, penalty);
        assert_eq!(r.summary.v_model_g, 0.0);
        assert_eq!(r.summary.total_cost, Duration::ZERO);
    }

    /// Complete exactly the initial design, leaving the session at the
    /// start of the proposal phase.
    fn drain_init(s: &mut Session) {
        loop {
            match s.ask() {
                Ask::Trial(t) => {
                    let o =
                        s.evaluator.run_trial(&t.theta, t.trial, t.seed);
                    s.tell(t.eval_id, t.trial, o).unwrap();
                }
                Ask::Wait => unreachable!("init never starves"),
                Ask::Done => unreachable!("budget > init design"),
            }
            if !s.history().records.is_empty() {
                break;
            }
        }
    }

    #[test]
    fn poison_rejects_unknown_buffered_and_nonfinite() {
        let ev = evaluator(1);
        let mut s = Session::new(&ev, &cfg(6, 2));
        assert!(s.poison(999, 1.0).is_err(), "unknown eval");
        let t = match s.ask() {
            Ask::Trial(t) => t,
            _ => unreachable!(),
        };
        assert!(s.poison(t.eval_id, f64::NAN).is_err(), "NaN penalty");
        assert!(
            s.poison(t.eval_id, f64::INFINITY).is_err(),
            "infinite penalty"
        );
        // Complete one init evaluation fully: buffered behind the
        // barrier, so poisoning it must be refused like requeue is.
        let mut done_id = None;
        loop {
            match s.ask() {
                Ask::Trial(t) => {
                    let o =
                        s.evaluator.run_trial(&t.theta, t.trial, t.seed);
                    s.tell(t.eval_id, t.trial, o).unwrap();
                    // Pending but no longer outstanding ⇒ complete and
                    // buffered behind the init barrier.
                    if s.pending_ids().contains(&t.eval_id)
                        && !s.outstanding_ids().contains(&t.eval_id)
                    {
                        done_id = Some(t.eval_id);
                        break;
                    }
                }
                _ => break,
            }
        }
        let id = done_id.expect("one init evaluation completed");
        assert!(s.poison(id, 1.0).is_err(), "buffered eval");
    }

    #[test]
    fn poison_flushes_the_init_barrier() {
        // Poisoning the last outstanding init evaluation must release
        // the whole buffered design, exactly like the final tell does.
        let ev = evaluator(2);
        let mut s = Session::new(&ev, &cfg(10, 3));
        let mut trials = Vec::new();
        loop {
            match s.ask() {
                Ask::Trial(t) => trials.push(t),
                Ask::Wait => break,
                Ask::Done => unreachable!(),
            }
        }
        // Finish every evaluation except the last one's trials.
        let last_id = trials.iter().map(|t| t.eval_id).max().unwrap();
        for t in trials.iter().filter(|t| t.eval_id != last_id) {
            let o = ev.run_trial(&t.theta, t.trial, t.seed);
            assert_eq!(s.tell(t.eval_id, t.trial, o).unwrap().recorded, 0);
        }
        let told = s.poison(last_id, 9.0).unwrap();
        assert_eq!(told.recorded, 4);
        let poisoned = s
            .history()
            .records
            .iter()
            .find(|r| r.id == last_id)
            .unwrap();
        assert_eq!(poisoned.summary.trained_mean, 9.0);
    }

    #[test]
    fn adaptive_policy_extends_to_the_cap_on_noisy_landscapes() {
        let ev = evaluator(9); // noise > 0: spread never hits 0
        let mut hpo = cfg(8, 4);
        hpo.adaptive_trials =
            Some(AdaptiveTrials { std_threshold: 0.0, max_trials: 4 });
        let mut s = Session::new(&ev, &hpo);
        let mut per_eval = std::collections::HashMap::new();
        let mut replicas = 0;
        loop {
            match s.ask() {
                Ask::Trial(t) => {
                    *per_eval.entry(t.eval_id).or_insert(0usize) += 1;
                    if t.kind == TrialKind::Replica {
                        replicas += 1;
                    }
                    let o =
                        s.evaluator.run_trial(&t.theta, t.trial, t.seed);
                    s.tell(t.eval_id, t.trial, o).unwrap();
                }
                Ask::Done => break,
                Ask::Wait => unreachable!(),
            }
        }
        assert_eq!(s.history().len(), 8);
        // Zero threshold on a noisy landscape: every θ runs max_trials.
        for (id, n) in &per_eval {
            assert_eq!(*n, 4, "evaluation {id} ran {n} trials");
        }
        assert_eq!(replicas, 8 * 2);
    }

    #[test]
    fn requeue_replays_bit_identically() {
        let ev = evaluator(5);
        let hpo = cfg(10, 6);

        let mut reference = Session::new(&ev, &hpo);
        drain(&mut reference);
        let reference = reference.into_history();

        // Run 13 trials (leaving one proposal mid-evaluation), then
        // pretend its worker died: requeue and finish.
        let mut s = Session::new(&ev, &hpo);
        let mut last_id = 0;
        for _ in 0..13 {
            match s.ask() {
                Ask::Trial(t) => {
                    last_id = t.eval_id;
                    let o = ev.run_trial(&t.theta, t.trial, t.seed);
                    s.tell(t.eval_id, t.trial, o).unwrap();
                }
                _ => unreachable!(),
            }
        }
        s.requeue(last_id).unwrap();
        drain(&mut s);
        let replayed = s.into_history();

        assert_eq!(reference.len(), replayed.len());
        for (a, b) in reference.records.iter().zip(&replayed.records) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.theta, b.theta);
            assert_eq!(a.provenance, b.provenance);
            assert_eq!(
                a.summary.interval.center.to_bits(),
                b.summary.interval.center.to_bits()
            );
            assert_eq!(
                a.summary.interval.radius.to_bits(),
                b.summary.interval.radius.to_bits()
            );
        }
    }

    #[test]
    fn requeue_rejects_unknown_recorded_and_buffered() {
        let ev = evaluator(2);
        let mut s = Session::new(&ev, &cfg(10, 3));
        // Unknown id.
        assert!(s.requeue(999).is_err());
        // Complete evaluation 0 only: it buffers behind the init barrier
        // — its work is finished, so requeueing it must be refused.
        let mut trials = Vec::new();
        while let Ask::Trial(t) = s.ask() {
            trials.push(t);
        }
        for t in trials.iter().filter(|t| t.eval_id == 0) {
            let o = ev.run_trial(&t.theta, t.trial, t.seed);
            s.tell(t.eval_id, t.trial, o).unwrap();
        }
        let err = s.requeue(0).unwrap_err();
        assert!(format!("{err:#}").contains("completed"));
        // Finish the rest of the design: recorded evals are unknown.
        for t in trials.iter().filter(|t| t.eval_id != 0) {
            let o = ev.run_trial(&t.theta, t.trial, t.seed);
            s.tell(t.eval_id, t.trial, o).unwrap();
        }
        let err = s.requeue(0).unwrap_err();
        assert!(format!("{err:#}").contains("unknown"));
    }

    #[test]
    fn requeued_evaluation_re_emerges_before_new_proposals() {
        let ev = evaluator(4);
        let mut s = Session::new(&ev, &cfg(6, 8));
        // Record the whole initial design.
        let mut trials = Vec::new();
        while let Ask::Trial(t) = s.ask() {
            trials.push(t);
        }
        for t in &trials {
            let o = ev.run_trial(&t.theta, t.trial, t.seed);
            s.tell(t.eval_id, t.trial, o).unwrap();
        }
        // Two proposals dispatched, nothing told.
        let a = s.ask_eval().unwrap();
        let b = s.ask_eval().unwrap();
        assert_eq!((a.id, b.id), (4, 5));
        // Worker running `a` dies: the requeued evaluation comes back
        // first, with its full trial set and original identity.
        s.requeue(a.id).unwrap();
        let again = s.ask_eval().unwrap();
        assert_eq!(again.id, a.id);
        assert_eq!(again.theta, a.theta);
        assert_eq!(again.seed, a.seed);
        assert_eq!(again.trials, vec![0, 1]);
    }

    #[test]
    fn snapshot_restore_roundtrips_through_json() {
        let ev = evaluator(5);
        let hpo = cfg(10, 6);

        // Reference: one uninterrupted sequential run.
        let mut reference = Session::new(&ev, &hpo);
        drain(&mut reference);
        let reference = reference.into_history();

        // Interrupted: stop mid-stream (including mid-evaluation), pass
        // the snapshot through its JSON wire format, restore, finish.
        let mut first = Session::new(&ev, &hpo);
        for _ in 0..13 {
            match first.ask() {
                Ask::Trial(t) => {
                    let o = ev.run_trial(&t.theta, t.trial, t.seed);
                    first.tell(t.eval_id, t.trial, o).unwrap();
                }
                _ => break,
            }
        }
        let wire = first.snapshot().to_json_string();
        drop(first);
        let ckpt = Checkpoint::from_json_str(&wire).unwrap();
        let mut resumed = Session::restore(&ev, &hpo, ckpt).unwrap();
        drain(&mut resumed);
        let resumed = resumed.into_history();

        assert_eq!(reference.len(), resumed.len());
        for (a, b) in reference.records.iter().zip(&resumed.records) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.theta, b.theta);
            assert_eq!(a.provenance, b.provenance);
            assert_eq!(
                a.summary.interval.center,
                b.summary.interval.center
            );
        }
    }

    #[test]
    fn restore_rejects_seed_mismatch() {
        let ev = evaluator(5);
        let s = Session::new(&ev, &cfg(6, 1));
        let ckpt = s.snapshot();
        let err =
            Session::restore(&ev, &cfg(6, 2), ckpt).unwrap_err();
        assert!(format!("{err:#}").contains("seed"));
    }
}
