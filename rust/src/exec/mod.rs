//! The experiment-execution subsystem: a sans-IO decision core plus the
//! I/O shells that run it.
//!
//! The architectural seam is [`Session`] (`exec::session`): the paper's
//! Fig. 6 loop as a pure ask/tell state machine — no threads, no
//! sleeps, no filesystem. Everything that *executes* experiments is a
//! shell looping `ask → execute → tell` over it:
//!
//! * [`run_experiment`] / [`resume_experiment`] (`exec::driver`) — the
//!   threaded steps × tasks pool with real/scaled sleeps and checkpoint
//!   files; `cluster::workers::run_async` and the `hyppo run` CLI wrap
//!   it.
//! * `cluster::sim::simulate_hpo` — the same loop in deterministic
//!   virtual time (no sleeps).
//! * [`run_sweep`] (`exec::sweep`) — seed × topology grids over the
//!   threaded shell.
//! * External executors — embed `Session` directly; see
//!   `examples/ask_tell.rs` and DESIGN.md §6.
//!
//! Checkpoints (`exec::checkpoint`) serialize exactly
//! [`Session::snapshot`]. See DESIGN.md §5-§6 for the design and the
//! schema.

pub mod checkpoint;
pub mod driver;
pub mod session;
pub mod sweep;

pub use checkpoint::{Checkpoint, PendingJob, CHECKPOINT_VERSION};
pub use driver::{
    resume_experiment, run_experiment, CheckpointPolicy, ExecConfig,
    ExecOutcome, ExecStats, DEFAULT_MAX_RETRIES,
};
pub use session::{
    Ask, EvalJob, Session, TellCheck, Told, Trial, TrialKind,
};
pub use sweep::{run_sweep, SweepCell};
