//! The experiment-execution subsystem: a reusable asynchronous driver
//! with incremental surrogate refits, checkpoint/resume, and grid sweeps.
//!
//! This is the architectural seam between the HPO engine (`optimizer`)
//! and the parallel substrate (`cluster`): everything that *runs*
//! experiments — the `hyppo` CLI, `cluster::workers::run_async`, the
//! sweep grid, future sharded/multi-backend drivers — goes through
//! [`run_experiment`] / [`resume_experiment`]. See DESIGN.md §4 for the
//! design and the checkpoint schema.

pub mod checkpoint;
pub mod driver;
pub mod sweep;

pub use checkpoint::{Checkpoint, PendingJob, CHECKPOINT_VERSION};
pub use driver::{
    resume_experiment, run_experiment, CheckpointPolicy, ExecConfig,
    ExecOutcome, ExecStats,
};
pub use sweep::{run_sweep, SweepCell};
