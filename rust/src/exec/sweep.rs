//! Multi-experiment sweeps: drive a seed × topology grid through the
//! asynchronous executor (each cell is one threaded ask → execute →
//! tell shell over a fresh `exec::Session`).
//!
//! The sweep reuses whatever the evaluator factory captures — for the
//! HLO backend that is one `Arc<SharedEngine>`, so every experiment in
//! the grid shares the PJRT compile cache and each distinct architecture
//! is compiled exactly once across the whole sweep (the "shared
//! artifact/engine caching" the CLI's `sweep` subcommand advertises).

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::cluster::Topology;
use crate::eval::Evaluator;
use crate::exec::driver::{run_experiment, ExecConfig, ExecStats};
use crate::space::Point;

/// One cell of the sweep grid: the run's identity plus its result.
#[derive(Debug, Clone)]
pub struct SweepCell {
    /// The HPO seed this cell ran with.
    pub seed: u64,
    /// The worker topology this cell ran with.
    pub topology: Topology,
    /// Best (γ-regulated) objective found.
    pub best_objective: f64,
    /// The best hyperparameter set.
    pub best_theta: Point,
    /// Evaluations recorded (equals the budget on a completed run).
    pub evaluations: usize,
    /// Wall-clock the cell took.
    pub wall: Duration,
    /// Driver counters (incremental vs full refits etc.).
    pub stats: ExecStats,
}

/// Run `seeds × topologies` experiments through the executor.
///
/// `make_evaluator` is called once per seed; captured state (datasets,
/// a shared PJRT engine) is reused across all cells. Cells run
/// sequentially — each cell's own workers provide the parallelism.
pub fn run_sweep<F>(
    make_evaluator: F,
    base: &ExecConfig,
    seeds: &[u64],
    topologies: &[Topology],
) -> Result<Vec<SweepCell>>
where
    F: Fn(u64) -> Result<Box<dyn Evaluator>>,
{
    let mut cells = Vec::with_capacity(seeds.len() * topologies.len());
    for &seed in seeds {
        let evaluator = make_evaluator(seed)?;
        for &topology in topologies {
            let mut cfg = base.clone();
            cfg.hpo.seed = seed;
            cfg.topology = topology;
            // Sweeps are batch jobs; per-cell checkpoints would clobber
            // one another on the shared path.
            cfg.checkpoint = None;
            let start = Instant::now();
            let out = run_experiment(evaluator.as_ref(), &cfg)?;
            let gamma = cfg.hpo.gamma;
            let best = out
                .history
                .best(gamma)
                .expect("completed run has records");
            cells.push(SweepCell {
                seed,
                topology,
                best_objective: best.objective(gamma),
                best_theta: best.theta.clone(),
                evaluations: out.history.len(),
                wall: start.elapsed(),
                stats: out.stats,
            });
        }
    }
    Ok(cells)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ParallelMode;
    use crate::eval::synthetic::SyntheticEvaluator;
    use crate::optimizer::HpoConfig;
    use crate::space::{ParamSpec, Space};

    #[test]
    fn sweep_covers_the_grid() {
        let space = Space::new(vec![
            ParamSpec::new("a", 0, 20),
            ParamSpec::new("b", 0, 20),
        ]);
        let base = ExecConfig::new(
            HpoConfig {
                max_evaluations: 14,
                n_init: 6,
                n_trials: 2,
                ..Default::default()
            },
            Topology::new(1, 1),
            ParallelMode::TrialParallel,
            1e-6,
        );
        let sp = space.clone();
        let cells = run_sweep(
            move |seed| {
                Ok(Box::new(SyntheticEvaluator::new(sp.clone(), seed))
                    as Box<dyn Evaluator>)
            },
            &base,
            &[1, 2],
            &[Topology::new(1, 1), Topology::new(3, 2)],
        )
        .unwrap();
        assert_eq!(cells.len(), 4);
        for c in &cells {
            assert_eq!(c.evaluations, 14);
            assert!(c.best_objective.is_finite());
            assert_eq!(c.stats.refits.proposals, 8);
        }
        // Same seed, different topology: same initial design, possibly
        // different adaptive path — but both must report the grid cell
        // they were asked to run.
        assert_eq!(cells[0].seed, 1);
        assert_eq!(cells[1].topology, Topology::new(3, 2));
    }
}
