//! The asynchronous experiment driver — the reusable engine behind
//! `cluster::workers::run_async`, `hyppo run --resume`, and `hyppo sweep`.
//!
//! Semantics match the paper's Fig. 6 loop (and the seed implementation):
//! the initial design runs across all workers and is recorded in id order
//! once complete, then every worker is kept busy with surrogate
//! proposals, the surrogate absorbing each completion *as it arrives*.
//! Two things are new relative to the seed loop:
//!
//! * **Incremental refits** — the driver holds one `OnlineProposer` for
//!   the whole experiment, so a completion costs an O(n²) rank-1 update
//!   instead of the O(n³) from-scratch refit that used to stall the
//!   coordinator (DESIGN.md §4).
//! * **Checkpoint / resume** — with a `CheckpointPolicy`, the coordinator
//!   snapshots its state (history, RNG, in-flight job provenance) after
//!   completions; `resume_experiment` re-enqueues the in-flight jobs with
//!   their original `(θ, seed)` pairs and continues. With deterministic
//!   completion order (one worker, or cost-ordered simulated sleeps) the
//!   resumed run is bit-for-bit the run that was killed.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::{mpsc, Arc, Condvar, Mutex};

use anyhow::{bail, Result};

use crate::cluster::{ParallelMode, Topology};
use crate::eval::{aggregate, Evaluator, TrialOutcome};
use crate::exec::checkpoint::{Checkpoint, PendingJob, CHECKPOINT_VERSION};
use crate::optimizer::{
    initial_design, EvalRecord, History, HpoConfig, OnlineProposer,
    RefitStats,
};
use crate::sampling::rng::Rng;
use crate::space::Space;

/// When and where the driver snapshots coordinator state.
#[derive(Debug, Clone)]
pub struct CheckpointPolicy {
    /// Snapshot file (written atomically via a `.tmp` sibling).
    pub path: PathBuf,
    /// Snapshot after every `every`-th recorded completion (1 = always).
    pub every: usize,
}

impl CheckpointPolicy {
    /// Snapshot to `path` after every completion.
    pub fn every_completion<P: Into<PathBuf>>(path: P) -> Self {
        CheckpointPolicy { path: path.into(), every: 1 }
    }
}

/// Full configuration of one asynchronous experiment.
#[derive(Debug, Clone)]
pub struct ExecConfig {
    /// The HPO problem (budget, surrogate, seed, ...).
    pub hpo: HpoConfig,
    /// steps × tasks worker topology.
    pub topology: Topology,
    /// Inner (per-evaluation) parallelization mode.
    pub mode: ParallelMode,
    /// Seconds of real sleep per second of reported virtual cost
    /// (0 for real backends whose cost is genuine wall time).
    pub time_scale: f64,
    /// Optional checkpointing policy.
    pub checkpoint: Option<CheckpointPolicy>,
    /// Stop (and checkpoint) after this many completions have been
    /// recorded *in this process* — used by tests and by operators who
    /// want to hand an experiment over to a larger allocation.
    pub max_completions: Option<usize>,
}

impl ExecConfig {
    /// A plain in-memory experiment (no checkpointing, full budget).
    pub fn new(
        hpo: HpoConfig,
        topology: Topology,
        mode: ParallelMode,
        time_scale: f64,
    ) -> Self {
        ExecConfig {
            hpo,
            topology,
            mode,
            time_scale,
            checkpoint: None,
            max_completions: None,
        }
    }
}

/// Counters describing what the driver did.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    /// Surrogate refit counters (incremental vs full).
    pub refits: RefitStats,
    /// Completions recorded in this process (resumed runs start at 0).
    pub completions: u64,
    /// Checkpoint snapshots written.
    pub checkpoints_written: u64,
    /// Whether this run continued a checkpoint.
    pub resumed: bool,
}

/// Result of driving one experiment (possibly partially, under
/// `max_completions`).
#[derive(Debug, Clone)]
pub struct ExecOutcome {
    /// Evaluations recorded so far, in completion order.
    pub history: History,
    /// Driver counters.
    pub stats: ExecStats,
    /// True when the full evaluation budget has been recorded.
    pub complete: bool,
}

/// What a worker needs to execute one evaluation.
struct WorkerJob {
    id: usize,
    theta: Vec<i64>,
    seed: u64,
}

struct Completion {
    id: usize,
    outcomes: Vec<TrialOutcome>,
}

type JobQueue = Arc<(Mutex<VecDeque<Option<WorkerJob>>>, Condvar)>;

/// Coordinator state — exactly what a checkpoint captures.
struct Coordinator {
    rng: Rng,
    next_id: usize,
    iter: usize,
    submitted: usize,
    history: History,
    in_flight: Vec<PendingJob>,
}

impl Coordinator {
    fn fresh(hpo: &HpoConfig) -> Self {
        Coordinator {
            rng: Rng::new(hpo.seed),
            next_id: 0,
            iter: 0,
            submitted: 0,
            history: History::default(),
            in_flight: Vec::new(),
        }
    }

    fn snapshot(&self, seed: u64) -> Checkpoint {
        Checkpoint {
            version: CHECKPOINT_VERSION,
            seed,
            rng_state: self.rng.state(),
            next_id: self.next_id,
            iter: self.iter,
            submitted: self.submitted,
            history: self.history.clone(),
            in_flight: self.in_flight.clone(),
        }
    }
}

/// Run one evaluation's N trials with nested task parallelism (the
/// paper's MPI-rank slicing for trial parallelism, or a data-parallel
/// cost discount).
pub(crate) fn run_evaluation(
    evaluator: &dyn Evaluator,
    theta: &[i64],
    n_trials: usize,
    seed: u64,
    tasks: usize,
    mode: ParallelMode,
    time_scale: f64,
) -> Vec<TrialOutcome> {
    let run_one = |trial: usize| {
        let o = evaluator.run_trial(theta, trial, seed);
        if time_scale > 0.0 {
            let scaled = o.cost.mul_f64(match mode {
                ParallelMode::TrialParallel => time_scale,
                // Data-parallel: the trial itself is sharded over tasks.
                ParallelMode::DataParallel => {
                    time_scale / (tasks as f64 * 0.85).max(1.0)
                }
            });
            std::thread::sleep(scaled);
        }
        o
    };

    if tasks <= 1 || n_trials <= 1 || mode == ParallelMode::DataParallel {
        return (0..n_trials).map(run_one).collect();
    }

    // Trial parallelism: slice trial indices over `tasks` inner threads.
    let mut outcomes: Vec<Option<TrialOutcome>> = Vec::new();
    outcomes.resize_with(n_trials, || None);
    let slots = Mutex::new(&mut outcomes);
    std::thread::scope(|scope| {
        for task in 0..tasks.min(n_trials) {
            let slots = &slots;
            let run_one = &run_one;
            scope.spawn(move || {
                let mut t = task;
                while t < n_trials {
                    let o = run_one(t);
                    slots.lock().unwrap()[t] = Some(o);
                    t += tasks;
                }
            });
        }
    });
    outcomes.into_iter().map(|o| o.expect("trial ran")).collect()
}

fn push_job(queue: &JobQueue, job: Option<WorkerJob>) {
    let (lock, cv) = &**queue;
    lock.lock().unwrap().push_back(job);
    cv.notify_one();
}

fn worker_job(j: &PendingJob) -> WorkerJob {
    WorkerJob { id: j.id, theta: j.theta.clone(), seed: j.seed }
}

/// Record one completion: move the job out of `in_flight`, aggregate its
/// outcomes into the history, and feed the surrogate.
fn record_completion(
    st: &mut Coordinator,
    proposer: &mut OnlineProposer,
    evaluator: &dyn Evaluator,
    hpo: &HpoConfig,
    space: &Space,
    c: Completion,
) {
    let pos = st
        .in_flight
        .iter()
        .position(|j| j.id == c.id)
        .expect("completion for an in-flight job");
    let job = st.in_flight.swap_remove(pos);
    let summary = aggregate(evaluator, &job.theta, &c.outcomes, hpo.weights);
    let record = EvalRecord {
        id: job.id,
        n_params: evaluator.n_params(&job.theta),
        theta: job.theta,
        summary,
        provenance: job.provenance,
    };
    proposer.observe(space, &record);
    st.history.records.push(record);
}

/// Propose the next point and submit it to the worker pool.
fn submit_proposal(
    st: &mut Coordinator,
    proposer: &mut OnlineProposer,
    space: &Space,
    queue: &JobQueue,
) {
    let theta = proposer.propose(space, &st.history, st.iter, &mut st.rng);
    st.iter += 1;
    let job = PendingJob {
        id: st.next_id,
        theta,
        provenance: st.history.records.iter().map(|r| r.id).collect(),
        seed: st.rng.next_u64(),
    };
    push_job(queue, Some(worker_job(&job)));
    st.in_flight.push(job);
    st.next_id += 1;
    st.submitted += 1;
}

/// Start a fresh experiment.
pub fn run_experiment(
    evaluator: &dyn Evaluator,
    cfg: &ExecConfig,
) -> Result<ExecOutcome> {
    let st = Coordinator::fresh(&cfg.hpo);
    drive(evaluator, cfg, st, false)
}

/// Continue an experiment from a checkpoint. The checkpoint must come
/// from a run with the same `HpoConfig::seed` (a cheap witness that the
/// configuration matches).
pub fn resume_experiment(
    evaluator: &dyn Evaluator,
    cfg: &ExecConfig,
    ckpt: Checkpoint,
) -> Result<ExecOutcome> {
    if ckpt.seed != cfg.hpo.seed {
        bail!(
            "checkpoint seed {} does not match config seed {}",
            ckpt.seed,
            cfg.hpo.seed
        );
    }
    let st = Coordinator {
        rng: Rng::from_state(ckpt.rng_state),
        next_id: ckpt.next_id,
        iter: ckpt.iter,
        submitted: ckpt.submitted,
        history: ckpt.history,
        in_flight: ckpt.in_flight,
    };
    drive(evaluator, cfg, st, true)
}

fn drive(
    evaluator: &dyn Evaluator,
    cfg: &ExecConfig,
    mut st: Coordinator,
    resumed: bool,
) -> Result<ExecOutcome> {
    let space = evaluator.space().clone();
    let budget = cfg.hpo.max_evaluations;
    let n_workers = cfg.topology.steps;
    let tasks = cfg.topology.tasks_per_step;

    let mut proposer = OnlineProposer::new(&cfg.hpo);
    proposer.preload(&space, &st.history);

    let mut stats = ExecStats { resumed, ..Default::default() };
    let mut ckpt_err: Option<anyhow::Error> = None;

    let queue: JobQueue =
        Arc::new((Mutex::new(VecDeque::new()), Condvar::new()));
    let (done_tx, done_rx) = mpsc::channel::<Completion>();

    std::thread::scope(|scope| {
        // --- workers ------------------------------------------------------
        for _worker in 0..n_workers {
            let queue = Arc::clone(&queue);
            let done_tx = done_tx.clone();
            let evaluator: &dyn Evaluator = evaluator;
            let hpo = &cfg.hpo;
            let mode = cfg.mode;
            let time_scale = cfg.time_scale;
            scope.spawn(move || loop {
                let job = {
                    let (lock, cv) = &*queue;
                    let mut q = lock.lock().unwrap();
                    loop {
                        match q.pop_front() {
                            Some(j) => break j,
                            None => q = cv.wait(q).unwrap(),
                        }
                    }
                };
                let Some(job) = job else { break }; // poison pill
                let outcomes = run_evaluation(
                    evaluator,
                    &job.theta,
                    hpo.n_trials,
                    job.seed,
                    tasks,
                    mode,
                    time_scale,
                );
                let _ = done_tx.send(Completion { id: job.id, outcomes });
            });
        }
        drop(done_tx);

        // --- coordinator --------------------------------------------------
        let fresh_start = st.history.is_empty()
            && st.in_flight.is_empty()
            && st.submitted == 0;
        if fresh_start {
            let init = initial_design(&space, &cfg.hpo, &mut st.rng);
            for theta in init.into_iter().take(budget) {
                let job = PendingJob {
                    id: st.next_id,
                    theta,
                    provenance: vec![],
                    seed: st.rng.next_u64(),
                };
                push_job(&queue, Some(worker_job(&job)));
                st.in_flight.push(job);
                st.next_id += 1;
                st.submitted += 1;
            }
        } else {
            // Resume: re-enqueue every in-flight job with its original
            // (θ, seed); deterministic evaluators reproduce the killed
            // run's outcomes exactly.
            for job in &st.in_flight {
                push_job(&queue, Some(worker_job(job)));
            }
        }
        // Make the submission wave durable before waiting on it.
        let mut unsaved_changes = false;
        if let Some(pol) = &cfg.checkpoint {
            match st.snapshot(cfg.hpo.seed).save(&pol.path) {
                Ok(()) => stats.checkpoints_written += 1,
                Err(e) => ckpt_err = Some(e),
            }
        }

        // Initial-design barrier: provenance-free completions are
        // buffered and recorded in id order once the whole design is in,
        // so the surrogate's starting state is independent of worker
        // timing (as in the seed loop).
        let mut init_pending = st
            .in_flight
            .iter()
            .filter(|j| j.provenance.is_empty())
            .count();
        let mut init_buffer: Vec<Completion> = Vec::new();
        let mut completions_this_run: u64 = 0;
        let mut stop_early = ckpt_err.is_some();

        while !st.in_flight.is_empty() && !stop_early {
            let Ok(c) = done_rx.recv() else { break };
            let is_init = st
                .in_flight
                .iter()
                .find(|j| j.id == c.id)
                .map(|j| j.provenance.is_empty())
                .unwrap_or(false);
            let mut recorded_now = 0u64;
            if is_init {
                init_buffer.push(c);
                init_pending -= 1;
                if init_pending > 0 {
                    continue;
                }
                init_buffer.sort_by_key(|c| c.id);
                for c in init_buffer.drain(..) {
                    record_completion(
                        &mut st,
                        &mut proposer,
                        evaluator,
                        &cfg.hpo,
                        &space,
                        c,
                    );
                    recorded_now += 1;
                }
                // Fill the pool with the first adaptive wave.
                let wave = n_workers.min(budget.saturating_sub(st.submitted));
                for _ in 0..wave {
                    submit_proposal(&mut st, &mut proposer, &space, &queue);
                }
            } else {
                record_completion(
                    &mut st,
                    &mut proposer,
                    evaluator,
                    &cfg.hpo,
                    &space,
                    c,
                );
                recorded_now = 1;
                if st.submitted < budget {
                    // Asynchronous update (Fig. 6): the surrogate has
                    // already absorbed this completion incrementally;
                    // propose and resubmit without waiting for peers.
                    submit_proposal(&mut st, &mut proposer, &space, &queue);
                }
            }
            completions_this_run += recorded_now;
            unsaved_changes = true;

            let due_now = cfg
                .checkpoint
                .as_ref()
                .map(|p| completions_this_run % p.every.max(1) as u64 == 0)
                .unwrap_or(false);
            if let Some(maxc) = cfg.max_completions {
                if completions_this_run >= maxc as u64 {
                    stop_early = true;
                }
            }
            if due_now || (stop_early && cfg.checkpoint.is_some()) {
                let pol = cfg.checkpoint.as_ref().expect("policy present");
                match st.snapshot(cfg.hpo.seed).save(&pol.path) {
                    Ok(()) => {
                        stats.checkpoints_written += 1;
                        unsaved_changes = false;
                    }
                    Err(e) => {
                        ckpt_err = Some(e);
                        stop_early = true;
                    }
                }
            }
        }

        // Final snapshot of a completed run (so `--resume` on a finished
        // experiment is a clean no-op) — but only if the last in-loop
        // save didn't already capture this exact state.
        if !stop_early && unsaved_changes {
            if let Some(pol) = &cfg.checkpoint {
                match st.snapshot(cfg.hpo.seed).save(&pol.path) {
                    Ok(()) => stats.checkpoints_written += 1,
                    Err(e) => ckpt_err = Some(e),
                }
            }
        }

        // Shutdown: discard queued-but-unstarted work (those jobs stay in
        // `in_flight`, hence in the checkpoint), stop the workers, drain
        // stragglers whose results we deliberately drop for the same
        // reason.
        {
            let (lock, cv) = &*queue;
            let mut q = lock.lock().unwrap();
            q.clear();
            for _ in 0..n_workers {
                q.push_back(None);
            }
            cv.notify_all();
        }
        while done_rx.recv().is_ok() {}

        stats.completions = completions_this_run;
    });

    if let Some(e) = ckpt_err {
        return Err(e);
    }
    stats.refits = proposer.stats();
    let complete = st.history.len() >= budget;
    Ok(ExecOutcome { history: st.history, stats, complete })
}
