//! The threaded experiment driver — an I/O shell over the sans-IO
//! [`Session`] core.
//!
//! All decisions (what to evaluate, trial accounting, surrogate refits,
//! checkpoint content) live in `exec::session`; this module supplies the
//! execution substrate the paper's Fig. 6 loop needs on a real machine:
//! a pool of `topology.steps` worker threads, nested trial-/data-parallel
//! execution of each evaluation's trials, real sleeps for simulated
//! costs, and checkpoint files written after recorded completions.
//!
//! The shell's scheduling policy reproduces the seed loop exactly:
//! every worker is kept busy with one evaluation-granular job at a time
//! ([`Session::ask_eval`]); the init barrier and the propose-on-complete
//! asynchrony are `Session` invariants, not driver logic. Two properties
//! carry over from the PR-1 driver:
//!
//! * **Incremental refits** — one `OnlineProposer` lives for the whole
//!   experiment inside the session, so a completion costs an O(n²)
//!   rank-1 update instead of an O(n³) from-scratch refit (DESIGN.md §5).
//! * **Checkpoint / resume** — with a `CheckpointPolicy`, the driver
//!   saves [`Session::snapshot`] after completions; `resume_experiment`
//!   restores the session and re-runs the in-flight jobs with their
//!   original `(θ, seed)` pairs. With deterministic completion order
//!   (one worker, or cost-ordered simulated sleeps) the resumed run is
//!   bit-for-bit the run that was killed.

use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::{mpsc, Arc, Condvar, Mutex};

use anyhow::{anyhow, Result};

use crate::cluster::{ParallelMode, Topology};
use crate::eval::{Evaluator, TrialOutcome};
use crate::exec::checkpoint::Checkpoint;
use crate::exec::session::{EvalJob, Session};
use crate::optimizer::{History, HpoConfig, RefitStats};

/// Default number of times one evaluation may die (worker panic in the
/// driver, crash/lost-result faults in the chaos simulator) before the
/// whole run fails. Shared by `ExecConfig` and `cluster::sim::ChaosConfig`
/// so the real and simulated recovery paths tolerate the same abuse.
pub const DEFAULT_MAX_RETRIES: usize = 8;

/// When and where the driver snapshots the session.
#[derive(Debug, Clone)]
pub struct CheckpointPolicy {
    /// Snapshot file (written atomically via a `.tmp` sibling).
    pub path: PathBuf,
    /// Snapshot after every `every`-th recorded completion (1 = always).
    pub every: usize,
}

impl CheckpointPolicy {
    /// Snapshot to `path` after every completion.
    pub fn every_completion<P: Into<PathBuf>>(path: P) -> Self {
        CheckpointPolicy { path: path.into(), every: 1 }
    }
}

/// Full configuration of one asynchronous experiment.
#[derive(Debug, Clone)]
pub struct ExecConfig {
    /// The HPO problem (budget, surrogate, seed, ...).
    pub hpo: HpoConfig,
    /// steps × tasks worker topology.
    pub topology: Topology,
    /// Inner (per-evaluation) parallelization mode.
    pub mode: ParallelMode,
    /// Seconds of real sleep per second of reported virtual cost
    /// (0 for real backends whose cost is genuine wall time).
    pub time_scale: f64,
    /// Optional checkpointing policy.
    pub checkpoint: Option<CheckpointPolicy>,
    /// Stop (and checkpoint) after this many completions have been
    /// recorded *in this process* — used by tests and by operators who
    /// want to hand an experiment over to a larger allocation.
    pub max_completions: Option<usize>,
    /// Worker deaths (panics) tolerated per evaluation before the run
    /// fails; each death requeues the evaluation through the session.
    pub max_retries: usize,
}

impl ExecConfig {
    /// A plain in-memory experiment (no checkpointing, full budget).
    pub fn new(
        hpo: HpoConfig,
        topology: Topology,
        mode: ParallelMode,
        time_scale: f64,
    ) -> Self {
        ExecConfig {
            hpo,
            topology,
            mode,
            time_scale,
            checkpoint: None,
            max_completions: None,
            max_retries: DEFAULT_MAX_RETRIES,
        }
    }
}

/// Counters describing what the driver did.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    /// Surrogate refit counters (incremental vs full).
    pub refits: RefitStats,
    /// Completions recorded in this process (resumed runs start at 0).
    pub completions: u64,
    /// Checkpoint snapshots written.
    pub checkpoints_written: u64,
    /// Evaluations requeued after a worker death.
    pub requeues: u64,
    /// Whether this run continued a checkpoint.
    pub resumed: bool,
}

/// Result of driving one experiment (possibly partially, under
/// `max_completions`).
#[derive(Debug, Clone)]
pub struct ExecOutcome {
    /// Evaluations recorded so far, in completion order.
    pub history: History,
    /// Driver counters.
    pub stats: ExecStats,
    /// True when the full evaluation budget has been recorded.
    pub complete: bool,
}

/// One executed trial of a job, tagged with its index.
struct Completion {
    id: usize,
    outcomes: Vec<(usize, TrialOutcome)>,
}

/// What a worker reports back to the coordinator.
enum WorkerMsg {
    /// The evaluation ran to completion.
    Done(Completion),
    /// The evaluation panicked mid-run (a simulated or genuine worker
    /// death); the coordinator decides whether to requeue or fail.
    Died { id: usize },
}

type JobQueue = Arc<(Mutex<VecDeque<Option<EvalJob>>>, Condvar)>;

/// Run the given trials of one evaluation with nested task parallelism
/// (the paper's MPI-rank slicing for trial parallelism, or a
/// data-parallel cost discount).
pub(crate) fn run_evaluation(
    evaluator: &dyn Evaluator,
    theta: &[crate::space::Value],
    trials: &[usize],
    seed: u64,
    tasks: usize,
    mode: ParallelMode,
    time_scale: f64,
) -> Vec<TrialOutcome> {
    let run_one = |trial: usize| {
        let o = evaluator.run_trial(theta, trial, seed);
        if time_scale > 0.0 {
            let scaled = o.cost.mul_f64(match mode {
                ParallelMode::TrialParallel => time_scale,
                // Data-parallel: the trial itself is sharded over tasks.
                ParallelMode::DataParallel => {
                    time_scale / (tasks as f64 * 0.85).max(1.0)
                }
            });
            std::thread::sleep(scaled);
        }
        o
    };

    if tasks <= 1 || trials.len() <= 1 || mode == ParallelMode::DataParallel
    {
        return trials.iter().map(|&t| run_one(t)).collect();
    }

    // Trial parallelism: slice the trial list over `tasks` inner threads.
    let n = trials.len();
    let mut outcomes: Vec<Option<TrialOutcome>> = Vec::new();
    outcomes.resize_with(n, || None);
    let slots = Mutex::new(&mut outcomes);
    std::thread::scope(|scope| {
        for task in 0..tasks.min(n) {
            let slots = &slots;
            let run_one = &run_one;
            scope.spawn(move || {
                let mut i = task;
                while i < n {
                    let o = run_one(trials[i]);
                    slots.lock().unwrap()[i] = Some(o);
                    i += tasks;
                }
            });
        }
    });
    outcomes.into_iter().map(|o| o.expect("trial ran")).collect()
}

fn push_job(queue: &JobQueue, job: Option<EvalJob>) {
    let (lock, cv) = &**queue;
    lock.lock().unwrap().push_back(job);
    cv.notify_one();
}

/// Start a fresh experiment.
pub fn run_experiment(
    evaluator: &dyn Evaluator,
    cfg: &ExecConfig,
) -> Result<ExecOutcome> {
    let session = Session::new(evaluator, &cfg.hpo);
    drive(evaluator, cfg, session, false)
}

/// Continue an experiment from a checkpoint. The checkpoint must come
/// from a run with the same `HpoConfig::seed` (a cheap witness that the
/// configuration matches).
pub fn resume_experiment(
    evaluator: &dyn Evaluator,
    cfg: &ExecConfig,
    ckpt: Checkpoint,
) -> Result<ExecOutcome> {
    let session = Session::restore(evaluator, &cfg.hpo, ckpt)?;
    drive(evaluator, cfg, session, true)
}

/// The ask → execute → tell loop: workers execute evaluation-granular
/// jobs, the coordinator feeds their outcomes back to the session and
/// refills the pool from `ask_eval`.
fn drive(
    evaluator: &dyn Evaluator,
    cfg: &ExecConfig,
    mut session: Session,
    resumed: bool,
) -> Result<ExecOutcome> {
    let n_workers = cfg.topology.steps;
    let tasks = cfg.topology.tasks_per_step;

    let mut stats = ExecStats { resumed, ..Default::default() };
    let mut fatal: Option<anyhow::Error> = None;

    let queue: JobQueue =
        Arc::new((Mutex::new(VecDeque::new()), Condvar::new()));
    let (done_tx, done_rx) = mpsc::channel::<WorkerMsg>();

    std::thread::scope(|scope| {
        // --- workers ------------------------------------------------------
        for _worker in 0..n_workers {
            let queue = Arc::clone(&queue);
            let done_tx = done_tx.clone();
            let evaluator: &dyn Evaluator = evaluator;
            let mode = cfg.mode;
            let time_scale = cfg.time_scale;
            scope.spawn(move || loop {
                let job = {
                    let (lock, cv) = &*queue;
                    let mut q = lock.lock().unwrap();
                    loop {
                        match q.pop_front() {
                            Some(j) => break j,
                            None => q = cv.wait(q).unwrap(),
                        }
                    }
                };
                let Some(job) = job else { break }; // poison pill
                // Contain evaluator panics to the evaluation: a dead
                // worker reports `Died` and survives to take the next
                // job, instead of poisoning the whole pool.
                let ran = catch_unwind(AssertUnwindSafe(|| {
                    run_evaluation(
                        evaluator,
                        &job.theta,
                        &job.trials,
                        job.seed,
                        tasks,
                        mode,
                        time_scale,
                    )
                }));
                let msg = match ran {
                    Ok(outcomes) => WorkerMsg::Done(Completion {
                        id: job.id,
                        outcomes: job
                            .trials
                            .iter()
                            .copied()
                            .zip(outcomes)
                            .collect(),
                    }),
                    Err(_) => WorkerMsg::Died { id: job.id },
                };
                let _ = done_tx.send(msg);
            });
        }
        drop(done_tx);

        // --- coordinator --------------------------------------------------
        // Fill the pool: one evaluation-granular job per worker. During
        // the initial design the session hands out init jobs only; after
        // the barrier this is the paper's adaptive wave.
        let mut outstanding = 0usize;
        while outstanding < n_workers {
            match session.ask_eval() {
                Some(job) => {
                    push_job(&queue, Some(job));
                    outstanding += 1;
                }
                None => break,
            }
        }
        // Make the submission wave durable before waiting on it.
        let mut unsaved_changes = false;
        if let Some(pol) = &cfg.checkpoint {
            match session.snapshot().save(&pol.path) {
                Ok(()) => stats.checkpoints_written += 1,
                Err(e) => fatal = Some(e),
            }
        }

        let mut completions_this_run: u64 = 0;
        let mut stop_early = fatal.is_some();
        let mut deaths: HashMap<usize, usize> = HashMap::new();

        while outstanding > 0 && !stop_early {
            let msg = match done_rx.recv() {
                Ok(m) => m,
                Err(_) => {
                    // Workers only exit on poison pills, which are sent
                    // after this loop — a disconnect here means the pool
                    // died out from under us.
                    fatal = Some(anyhow!(
                        "worker pool terminated with {outstanding} \
                         evaluation(s) outstanding"
                    ));
                    break;
                }
            };
            outstanding -= 1;
            let mut recorded_now = 0u64;
            match msg {
                // Feed every trial outcome back; the session records the
                // evaluation (or schedules adaptive replicas) on the last.
                WorkerMsg::Done(c) => {
                    for (trial, outcome) in c.outcomes {
                        match session.tell(c.id, trial, outcome) {
                            Ok(told) => {
                                recorded_now += told.recorded as u64
                            }
                            Err(e) => {
                                fatal = Some(e);
                                stop_early = true;
                                break;
                            }
                        }
                    }
                }
                // A worker died mid-evaluation: requeue (the session
                // re-hands the same (θ, seed) job, so a deterministic
                // evaluator reproduces the lost work exactly) until the
                // retry budget runs out.
                WorkerMsg::Died { id } => {
                    let n = deaths.entry(id).or_insert(0);
                    *n += 1;
                    if *n > cfg.max_retries {
                        fatal = Some(anyhow!(
                            "evaluation {id} died {n} time(s), \
                             exceeding max_retries = {}",
                            cfg.max_retries
                        ));
                        stop_early = true;
                    } else {
                        match session.requeue(id) {
                            Ok(()) => stats.requeues += 1,
                            Err(e) => {
                                fatal = Some(e);
                                stop_early = true;
                            }
                        }
                    }
                }
            }
            // Refill the pool (Fig. 6): the surrogate has already
            // absorbed this completion incrementally; new proposals (or
            // replica batches) go out without waiting for peers.
            while !stop_early && outstanding < n_workers {
                match session.ask_eval() {
                    Some(job) => {
                        push_job(&queue, Some(job));
                        outstanding += 1;
                    }
                    None => break,
                }
            }
            if recorded_now == 0 {
                continue;
            }
            completions_this_run += recorded_now;
            unsaved_changes = true;

            let due_now = cfg
                .checkpoint
                .as_ref()
                .map(|p| completions_this_run % p.every.max(1) as u64 == 0)
                .unwrap_or(false);
            if let Some(maxc) = cfg.max_completions {
                if completions_this_run >= maxc as u64 {
                    stop_early = true;
                }
            }
            if due_now || (stop_early && cfg.checkpoint.is_some()) {
                let pol = cfg.checkpoint.as_ref().expect("policy present");
                match session.snapshot().save(&pol.path) {
                    Ok(()) => {
                        stats.checkpoints_written += 1;
                        unsaved_changes = false;
                    }
                    Err(e) => {
                        fatal = Some(e);
                        stop_early = true;
                    }
                }
            }
        }

        // Final snapshot of a completed run (so `--resume` on a finished
        // experiment is a clean no-op) — but only if the last in-loop
        // save didn't already capture this exact state.
        if !stop_early && unsaved_changes {
            if let Some(pol) = &cfg.checkpoint {
                match session.snapshot().save(&pol.path) {
                    Ok(()) => stats.checkpoints_written += 1,
                    Err(e) => fatal = Some(e),
                }
            }
        }

        // Shutdown: discard queued-but-unstarted work (those jobs stay
        // in-flight in the session, hence in the checkpoint), stop the
        // workers, drain stragglers whose results we deliberately drop
        // for the same reason.
        {
            let (lock, cv) = &*queue;
            let mut q = lock.lock().unwrap();
            q.clear();
            for _ in 0..n_workers {
                q.push_back(None);
            }
            cv.notify_all();
        }
        while done_rx.recv().is_ok() {}

        stats.completions = completions_this_run;
    });

    if let Some(e) = fatal {
        return Err(e);
    }
    stats.refits = session.stats();
    let complete = session.is_complete();
    Ok(ExecOutcome { history: session.into_history(), stats, complete })
}
