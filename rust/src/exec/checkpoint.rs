//! Checkpoint format: the serialized form of `Session::snapshot`.
//!
//! A checkpoint is exactly the sans-IO session's decision state — the
//! recorded history, the coordinator RNG state, the submission
//! counters, and the identity of every evaluation that was created but
//! not yet recorded (in-flight) — and captures everything needed to
//! continue a killed experiment bit-for-bit (given deterministic
//! completion order — see DESIGN.md §5-§6). On restore the in-flight
//! evaluations are asked again from trial 0 with their original
//! `(θ, seed)` pairs, so deterministic evaluators reproduce the exact
//! outcomes the killed run would have recorded; partially-told trial
//! outcomes are deliberately not serialized.
//!
//! Serialization is JSON through the hand-rolled `util::json` substrate.
//! `u64` values (seeds, RNG words) are encoded as **decimal strings**:
//! the substrate stores numbers as `f64`, which would silently round
//! anything above 2⁵³ and break bit-for-bit resumption.
//!
//! # Schema history
//!
//! * **v1** — the pre-typed-space format: every θ coordinate is a plain
//!   JSON integer (the Eq. 2 lattice).
//! * **v2** (current) — typed θ coordinates: integers stay plain
//!   numbers (so an all-`Int` v2 checkpoint is byte-identical to v1 up
//!   to the version field), continuous values serialize as `{"f": v}`,
//!   categorical choices as `{"c": i}`.
//!
//! v1 checkpoints load losslessly: plain numbers migrate to
//! `Value::Int`, which is exactly what they meant, and a resumed run
//! replays bit-for-bit (asserted in `tests/exec.rs`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::analysis::persistence::{
    record_from_json, record_to_json, value_from_json, value_to_json,
};
use crate::optimizer::History;
use crate::space::{Point, Value};
use crate::util::json::{parse, write, Json};

/// Current checkpoint schema version (see DESIGN.md §5 for the layout
/// and the module docs for the v1 → v2 migration).
pub const CHECKPOINT_VERSION: i64 = 2;

/// An evaluation the session created but has not recorded yet (its
/// trials may be queued, executing, or partially told).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PendingJob {
    /// Submission id (stable across kill/resume).
    pub id: usize,
    /// The hyperparameter set under evaluation.
    pub theta: Point,
    /// Ids of the evaluations the surrogate had seen at proposal time
    /// (empty for initial-design jobs).
    pub provenance: Vec<usize>,
    /// The evaluation seed drawn at submission time; re-enqueueing with
    /// the same seed reproduces the same trial outcomes.
    pub seed: u64,
}

/// A serializable snapshot of the sans-IO session's decision state
/// (`exec::Session::snapshot`).
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Schema version ([`CHECKPOINT_VERSION`]).
    pub version: i64,
    /// `HpoConfig::seed` of the run that wrote the snapshot; resume
    /// refuses a checkpoint written under a different seed.
    pub seed: u64,
    /// Coordinator xoshiro256** state at snapshot time.
    pub rng_state: [u64; 4],
    /// Next submission id.
    pub next_id: usize,
    /// Adaptive-phase iteration counter (drives the weight cycle).
    pub iter: usize,
    /// Total jobs submitted so far (recorded + in-flight).
    pub submitted: usize,
    /// Evaluations recorded, in completion order.
    pub history: History,
    /// Jobs submitted but not yet recorded.
    pub in_flight: Vec<PendingJob>,
}

fn u64_to_json(v: u64) -> Json {
    Json::Str(v.to_string())
}

fn u64_from_json(v: &Json, what: &str) -> Result<u64> {
    let s = v
        .as_str()
        .with_context(|| format!("{what}: expected decimal string"))?;
    s.parse::<u64>()
        .map_err(|e| anyhow!("{what}: bad u64 {s:?}: {e}"))
}

fn job_to_json(j: &PendingJob) -> Json {
    let mut o = BTreeMap::new();
    o.insert("id".into(), Json::Num(j.id as f64));
    o.insert(
        "theta".into(),
        Json::Arr(j.theta.iter().map(value_to_json).collect()),
    );
    o.insert(
        "provenance".into(),
        Json::Arr(
            j.provenance
                .iter()
                .map(|v| Json::Num(*v as f64))
                .collect(),
        ),
    );
    o.insert("seed".into(), u64_to_json(j.seed));
    Json::Obj(o)
}

fn job_from_json(v: &Json) -> Result<PendingJob> {
    let theta = v
        .get("theta")
        .as_arr()
        .context("job theta")?
        .iter()
        .map(|x| value_from_json(x).context("job theta item"))
        .collect::<Result<Vec<Value>>>()?;
    let provenance = v
        .get("provenance")
        .as_arr()
        .context("job provenance")?
        .iter()
        .map(|x| x.as_i64().map(|i| i as usize).context("job prov item"))
        .collect::<Result<Vec<usize>>>()?;
    Ok(PendingJob {
        id: v.get("id").as_i64().context("job id")? as usize,
        theta,
        provenance,
        seed: u64_from_json(v.get("seed"), "job seed")?,
    })
}

impl Checkpoint {
    /// Serialize to compact JSON text.
    pub fn to_json_string(&self) -> String {
        let mut root = BTreeMap::new();
        root.insert("version".into(), Json::Num(self.version as f64));
        root.insert("seed".into(), u64_to_json(self.seed));
        root.insert(
            "rng_state".into(),
            Json::Arr(self.rng_state.iter().map(|w| u64_to_json(*w)).collect()),
        );
        root.insert("next_id".into(), Json::Num(self.next_id as f64));
        root.insert("iter".into(), Json::Num(self.iter as f64));
        root.insert(
            "submitted".into(),
            Json::Num(self.submitted as f64),
        );
        root.insert(
            "records".into(),
            Json::Arr(
                self.history.records.iter().map(record_to_json).collect(),
            ),
        );
        root.insert(
            "in_flight".into(),
            Json::Arr(self.in_flight.iter().map(job_to_json).collect()),
        );
        write(&Json::Obj(root))
    }

    /// Parse a checkpoint back from [`Checkpoint::to_json_string`]
    /// text. Accepts the current v2 schema and migrates v1 checkpoints
    /// in place (all-integer θ → `Value::Int`, lossless); the returned
    /// struct always reports [`CHECKPOINT_VERSION`].
    pub fn from_json_str(text: &str) -> Result<Checkpoint> {
        let root =
            parse(text).map_err(|e| anyhow!("checkpoint parse: {e}"))?;
        let version = root.get("version").as_i64().context("version")?;
        if !(1..=CHECKPOINT_VERSION).contains(&version) {
            bail!("unsupported checkpoint version {version}");
        }
        let rng_arr = root.get("rng_state").as_arr().context("rng_state")?;
        if rng_arr.len() != 4 {
            bail!("rng_state must hold 4 words, got {}", rng_arr.len());
        }
        let mut rng_state = [0u64; 4];
        for (i, w) in rng_arr.iter().enumerate() {
            rng_state[i] = u64_from_json(w, "rng_state word")?;
        }
        let records = root
            .get("records")
            .as_arr()
            .context("records")?
            .iter()
            .map(record_from_json)
            .collect::<Result<Vec<_>>>()?;
        let in_flight = root
            .get("in_flight")
            .as_arr()
            .context("in_flight")?
            .iter()
            .map(job_from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Checkpoint {
            version: CHECKPOINT_VERSION,
            seed: u64_from_json(root.get("seed"), "seed")?,
            rng_state,
            next_id: root.get("next_id").as_i64().context("next_id")?
                as usize,
            iter: root.get("iter").as_i64().context("iter")? as usize,
            submitted: root
                .get("submitted")
                .as_i64()
                .context("submitted")? as usize,
            history: History { records },
            in_flight,
        })
    }

    /// Serialize to JSON text and parse straight back — the in-memory
    /// equivalent of a kill + resume from disk. The chaos simulator's
    /// restart fault (`cluster::sim::simulate_chaos`) recovers through
    /// this call, so simulated recovery exercises the real wire format,
    /// not a clone of the live state.
    pub fn wire_roundtrip(&self) -> Result<Checkpoint> {
        Self::from_json_str(&self.to_json_string())
    }

    /// Atomically and durably write the checkpoint: serialize to
    /// `<path>.tmp`, `fsync`, rename over `path`, then `fsync` the
    /// parent directory ([`crate::util::fsio::atomic_write_sync`]), so a
    /// kill at any point — including between the rename and the
    /// directory sync — never corrupts or loses the last good snapshot.
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        crate::util::fsio::atomic_write_sync(
            path.as_ref(),
            self.to_json_string().as_bytes(),
        )
    }

    /// Load a checkpoint from disk.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Checkpoint> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json_str(&text)
            .with_context(|| format!("parsing {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::synthetic::SyntheticEvaluator;
    use crate::optimizer::{run_sync, HpoConfig};
    use crate::space::{ints, ParamSpec, Space};

    fn sample() -> Checkpoint {
        let space = Space::new(vec![
            ParamSpec::new("a", 0, 10),
            ParamSpec::new("b", 0, 10),
        ]);
        let ev = SyntheticEvaluator::new(space, 1);
        let history = run_sync(
            &ev,
            &HpoConfig {
                max_evaluations: 9,
                n_init: 4,
                n_trials: 2,
                seed: 3,
                ..Default::default()
            },
        );
        Checkpoint {
            version: CHECKPOINT_VERSION,
            seed: 3,
            // Values above 2^53 exercise the decimal-string encoding.
            rng_state: [u64::MAX, 1, 2_u64.pow(63) + 7, 42],
            next_id: 11,
            iter: 5,
            submitted: 11,
            history,
            in_flight: vec![
                PendingJob {
                    id: 9,
                    theta: ints(&[1, 2]),
                    provenance: vec![0, 1, 2, 3, 4],
                    seed: u64::MAX - 12345,
                },
                PendingJob {
                    id: 10,
                    // Typed coordinates exercise the v2 encoding.
                    theta: vec![Value::Float(3.5e-4), Value::Cat(2)],
                    provenance: vec![],
                    seed: 17,
                },
            ],
        }
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let c = sample();
        let c2 = Checkpoint::from_json_str(&c.to_json_string()).unwrap();
        assert_eq!(c2.version, c.version);
        assert_eq!(c2.seed, c.seed);
        assert_eq!(c2.rng_state, c.rng_state);
        assert_eq!(c2.next_id, c.next_id);
        assert_eq!(c2.iter, c.iter);
        assert_eq!(c2.submitted, c.submitted);
        assert_eq!(c2.in_flight, c.in_flight);
        assert_eq!(c2.history.len(), c.history.len());
        for (a, b) in c.history.records.iter().zip(&c2.history.records) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.theta, b.theta);
            assert_eq!(a.provenance, b.provenance);
            // f64 fields survive the shortest-roundtrip Display format.
            assert_eq!(a.summary.interval.center, b.summary.interval.center);
            assert_eq!(a.summary.trained_std, b.summary.trained_std);
        }
    }

    #[test]
    fn wire_roundtrip_matches_disk_roundtrip() {
        let c = sample();
        let w = c.wire_roundtrip().unwrap();
        assert_eq!(w.seed, c.seed);
        assert_eq!(w.rng_state, c.rng_state);
        assert_eq!(w.in_flight, c.in_flight);
        assert_eq!(w.to_json_string(), c.to_json_string());
    }

    #[test]
    fn save_load_atomic_file() {
        let c = sample();
        let p = std::env::temp_dir().join("hyppo_ckpt_test.json");
        c.save(&p).unwrap();
        assert!(!p.with_extension("tmp").exists(), "tmp file left behind");
        let c2 = Checkpoint::load(&p).unwrap();
        assert_eq!(c2.rng_state, c.rng_state);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn v1_checkpoints_parse_and_report_current_version() {
        // An all-Int v2 checkpoint is byte-identical to v1 except for
        // the version field — rewriting it back yields a genuine v1
        // document, which must migrate losslessly.
        let mut c = sample();
        c.in_flight.truncate(1); // drop the typed (v2-only) job
        let v1 = c
            .to_json_string()
            .replace("\"version\":2", "\"version\":1");
        let m = Checkpoint::from_json_str(&v1).unwrap();
        assert_eq!(m.version, CHECKPOINT_VERSION);
        assert_eq!(m.seed, c.seed);
        assert_eq!(m.rng_state, c.rng_state);
        assert_eq!(m.in_flight, c.in_flight);
        for (a, b) in m.history.records.iter().zip(&c.history.records) {
            assert_eq!(a.theta, b.theta);
        }
    }

    #[test]
    fn rejects_garbage_and_wrong_version() {
        assert!(Checkpoint::from_json_str("nope").is_err());
        let mut c = sample();
        c.version = 99;
        assert!(Checkpoint::from_json_str(&c.to_json_string()).is_err());
        assert!(Checkpoint::from_json_str(
            &sample()
                .to_json_string()
                .replace("\"version\":2", "\"version\":0"),
        )
        .is_err());
        // A u64 encoded as a JSON number (not a string) must be rejected
        // rather than silently rounded.
        let text = sample().to_json_string().replace(
            &format!("\"seed\":\"{}\"", 3),
            "\"seed\":3",
        );
        assert!(Checkpoint::from_json_str(&text).is_err());
    }
}
