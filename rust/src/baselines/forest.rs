//! Extra-trees regression forest — the surrogate behind the DeepHyper-like
//! AMBS baseline (DeepHyper's HPS used scikit-learn's RF/ET regressors).
//! Built from scratch: randomized split dimension + threshold per node,
//! bootstrap-free (extra-trees style uses the full sample per tree, with
//! randomness in the splits), depth/min-samples stopping.

use crate::sampling::rng::Rng;

#[derive(Debug, Clone)]
pub struct ForestConfig {
    pub n_trees: usize,
    pub max_depth: usize,
    pub min_samples: usize,
    /// Random split candidates per node (extra-trees "K").
    pub n_splits: usize,
}

impl Default for ForestConfig {
    fn default() -> Self {
        ForestConfig { n_trees: 25, max_depth: 12, min_samples: 3, n_splits: 8 }
    }
}

#[derive(Debug, Clone)]
enum Node {
    Leaf { value: f64 },
    Split { dim: usize, threshold: f64, left: usize, right: usize },
}

#[derive(Debug, Clone)]
struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    fn predict(&self, x: &[f64]) -> f64 {
        let mut i = 0;
        loop {
            match &self.nodes[i] {
                Node::Leaf { value } => return *value,
                Node::Split { dim, threshold, left, right } => {
                    i = if x[*dim] <= *threshold { *left } else { *right };
                }
            }
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct Forest {
    trees: Vec<Tree>,
}

fn mean(ys: &[f64]) -> f64 {
    ys.iter().sum::<f64>() / ys.len().max(1) as f64
}

fn sse(ys: &[f64]) -> f64 {
    let m = mean(ys);
    ys.iter().map(|y| (y - m) * (y - m)).sum()
}

fn build(
    xs: &[Vec<f64>],
    ys: &[f64],
    idx: Vec<usize>,
    depth: usize,
    cfg: &ForestConfig,
    rng: &mut Rng,
    nodes: &mut Vec<Node>,
) -> usize {
    let sub_y: Vec<f64> = idx.iter().map(|i| ys[*i]).collect();
    let leaf = |nodes: &mut Vec<Node>, v: f64| {
        nodes.push(Node::Leaf { value: v });
        nodes.len() - 1
    };
    if depth >= cfg.max_depth
        || idx.len() < cfg.min_samples * 2
        || sse(&sub_y) < 1e-12
    {
        return leaf(nodes, mean(&sub_y));
    }
    let d = xs[0].len();

    // Extra-trees: a few fully random (dim, threshold) splits; keep the
    // one with the lowest child SSE.
    let mut best: Option<(usize, f64, f64)> = None;
    for _ in 0..cfg.n_splits {
        let dim = rng.usize_below(d);
        let (lo, hi) = idx.iter().fold(
            (f64::INFINITY, f64::NEG_INFINITY),
            |(lo, hi), i| {
                let v = xs[*i][dim];
                (lo.min(v), hi.max(v))
            },
        );
        if hi - lo < 1e-12 {
            continue;
        }
        let threshold = lo + rng.f64() * (hi - lo);
        let (mut ly, mut ry) = (Vec::new(), Vec::new());
        for i in &idx {
            if xs[*i][dim] <= threshold {
                ly.push(ys[*i]);
            } else {
                ry.push(ys[*i]);
            }
        }
        if ly.is_empty() || ry.is_empty() {
            continue;
        }
        let score = sse(&ly) + sse(&ry);
        if best.map(|(_, _, s)| score < s).unwrap_or(true) {
            best = Some((dim, threshold, score));
        }
    }
    let Some((dim, threshold, _)) = best else {
        return leaf(nodes, mean(&sub_y));
    };
    let (mut li, mut ri) = (Vec::new(), Vec::new());
    for i in idx {
        if xs[i][dim] <= threshold {
            li.push(i);
        } else {
            ri.push(i);
        }
    }
    let me = nodes.len();
    nodes.push(Node::Leaf { value: 0.0 }); // placeholder
    let left = build(xs, ys, li, depth + 1, cfg, rng, nodes);
    let right = build(xs, ys, ri, depth + 1, cfg, rng, nodes);
    nodes[me] = Node::Split { dim, threshold, left, right };
    me
}

impl Forest {
    pub fn fit(
        xs: &[Vec<f64>],
        ys: &[f64],
        cfg: &ForestConfig,
        rng: &mut Rng,
    ) -> Forest {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty());
        let trees = (0..cfg.n_trees)
            .map(|_| {
                let mut nodes = Vec::new();
                let root = build(
                    xs,
                    ys,
                    (0..xs.len()).collect(),
                    0,
                    cfg,
                    rng,
                    &mut nodes,
                );
                debug_assert_eq!(root, 0);
                Tree { nodes }
            })
            .collect();
        Forest { trees }
    }

    /// Ensemble mean and std at a point.
    pub fn predict(&self, x: &[f64]) -> (f64, f64) {
        let preds: Vec<f64> =
            self.trees.iter().map(|t| t.predict(x)).collect();
        let m = mean(&preds);
        let var = preds.iter().map(|p| (p - m) * (p - m)).sum::<f64>()
            / preds.len() as f64;
        (m, var.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_data() -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..20 {
            for j in 0..20 {
                let x = vec![i as f64 / 19.0, j as f64 / 19.0];
                ys.push((x[0] - 0.3).powi(2) + (x[1] - 0.7).powi(2));
                xs.push(x);
            }
        }
        (xs, ys)
    }

    #[test]
    fn fits_smooth_function_reasonably() {
        let (xs, ys) = grid_data();
        let mut rng = Rng::new(0);
        let f = Forest::fit(&xs, &ys, &ForestConfig::default(), &mut rng);
        let mut err = 0.0;
        for (x, y) in xs.iter().zip(&ys) {
            let (p, _) = f.predict(x);
            err += (p - y).abs();
        }
        err /= xs.len() as f64;
        assert!(err < 0.05, "mean abs err {err}");
    }

    #[test]
    fn constant_target_gives_zero_std() {
        let xs: Vec<Vec<f64>> =
            (0..20).map(|i| vec![i as f64]).collect();
        let ys = vec![3.0; 20];
        let mut rng = Rng::new(1);
        let f = Forest::fit(&xs, &ys, &ForestConfig::default(), &mut rng);
        let (p, s) = f.predict(&[7.5]);
        assert!((p - 3.0).abs() < 1e-12);
        assert!(s < 1e-12);
    }

    #[test]
    fn std_positive_where_trees_disagree() {
        let (xs, ys) = grid_data();
        let mut rng = Rng::new(2);
        let f = Forest::fit(&xs, &ys, &ForestConfig::default(), &mut rng);
        // Extrapolation region: trees disagree.
        let (_, s) = f.predict(&[0.31, 0.69]);
        assert!(s >= 0.0);
        let disagreement_somewhere = (0..50).any(|k| {
            let q = [k as f64 / 50.0, 1.0 - k as f64 / 50.0];
            f.predict(&q).1 > 1e-6
        });
        assert!(disagreement_somewhere);
    }
}
