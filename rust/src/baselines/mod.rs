//! Baseline HPO methods the paper compares against: pure random search
//! (`optimizer::run_random`) and a DeepHyper-like asynchronous
//! model-based search (`ambs`) on an extra-trees surrogate (`forest`).

pub mod ambs;
pub mod forest;

pub use ambs::{run_ambs, AmbsConfig};
pub use forest::{Forest, ForestConfig};
