//! DeepHyper-like asynchronous model-based search (the Fig. 4 comparator).
//!
//! DeepHyper's HPS (Balaprakash et al. 2018) drives a centralized Bayesian
//! loop with a random-forest surrogate and a lower-confidence-bound
//! acquisition over randomly sampled candidates. We implement that
//! algorithm (rather than wrapping the package — unavailable offline;
//! DESIGN.md §3): random init, fit forest, sample K lattice candidates,
//! pick argmin of μ − κσ, evaluate, repeat.

use crate::baselines::forest::{Forest, ForestConfig};
use crate::eval::Evaluator;
use crate::optimizer::{evaluate_point, EvalRecord, History};
use crate::sampling::rng::Rng;
use crate::space::Point;
use crate::uq::UqWeights;

#[derive(Debug, Clone)]
pub struct AmbsConfig {
    pub max_evaluations: usize,
    pub n_init: usize,
    pub n_trials: usize,
    /// LCB exploration strength κ (DeepHyper default ~1.96).
    pub kappa: f64,
    pub n_candidates: usize,
    pub forest: ForestConfig,
    pub seed: u64,
}

impl Default for AmbsConfig {
    fn default() -> Self {
        AmbsConfig {
            max_evaluations: 200,
            n_init: 10,
            n_trials: 1,
            kappa: 1.96,
            n_candidates: 500,
            forest: ForestConfig::default(),
            seed: 0,
        }
    }
}

pub fn run_ambs(evaluator: &dyn Evaluator, cfg: &AmbsConfig) -> History {
    let space = evaluator.space().clone();
    let mut rng = Rng::new(cfg.seed);
    let weights = UqWeights::default_paper();
    let mut history = History::default();

    let record = |history: &mut History,
                      theta: Point,
                      provenance: Vec<usize>,
                      rng: &mut Rng| {
        let summary = evaluate_point(
            evaluator,
            &theta,
            cfg.n_trials,
            weights,
            rng.next_u64(),
        );
        let id = history.len();
        history.records.push(EvalRecord {
            id,
            n_params: evaluator.n_params(&theta),
            theta,
            summary,
            provenance,
        });
    };

    for _ in 0..cfg.n_init.min(cfg.max_evaluations) {
        let theta = space.random_point(&mut rng);
        record(&mut history, theta, vec![], &mut rng);
    }

    while history.len() < cfg.max_evaluations {
        let xs: Vec<Vec<f64>> = history
            .records
            .iter()
            .map(|r| space.encode(&r.theta))
            .collect();
        let ys: Vec<f64> = history
            .records
            .iter()
            .map(|r| r.summary.interval.center)
            .collect();
        let forest = Forest::fit(&xs, &ys, &cfg.forest, &mut rng);

        let evaluated: Vec<Point> =
            history.records.iter().map(|r| r.theta.clone()).collect();
        let mut best: Option<(Point, f64)> = None;
        for _ in 0..cfg.n_candidates {
            let cand = space.random_point(&mut rng);
            if evaluated.contains(&cand) {
                continue;
            }
            let (mu, sd) = forest.predict(&space.encode(&cand));
            let lcb = mu - cfg.kappa * sd;
            if best.as_ref().map(|(_, b)| lcb < *b).unwrap_or(true) {
                best = Some((cand, lcb));
            }
        }
        let theta = best
            .map(|(t, _)| t)
            .unwrap_or_else(|| space.random_point(&mut rng));
        let provenance: Vec<usize> =
            history.records.iter().map(|r| r.id).collect();
        record(&mut history, theta, provenance, &mut rng);
    }
    history
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::synthetic::SyntheticEvaluator;
    use crate::space::{ParamSpec, Space};

    fn evaluator() -> SyntheticEvaluator {
        let space = Space::new(vec![
            ParamSpec::new("a", 0, 24),
            ParamSpec::new("b", 0, 24),
            ParamSpec::new("c", 0, 24),
        ]);
        let mut ev = SyntheticEvaluator::new(space, 5);
        ev.t_dropout = 3;
        ev
    }

    #[test]
    fn completes_and_improves() {
        let ev = evaluator();
        let cfg = AmbsConfig {
            max_evaluations: 40,
            n_init: 10,
            seed: 1,
            ..Default::default()
        };
        let h = run_ambs(&ev, &cfg);
        assert_eq!(h.len(), 40);
        let trace = h.best_trace(0.0);
        assert!(trace.last().unwrap() <= &trace[9]);
    }

    #[test]
    fn beats_pure_random_usually() {
        let ev = evaluator();
        let mut wins = 0;
        for seed in 0..4 {
            let h = run_ambs(
                &ev,
                &AmbsConfig {
                    max_evaluations: 30,
                    n_init: 8,
                    seed,
                    ..Default::default()
                },
            );
            let r = crate::optimizer::run_random(
                &ev,
                30,
                1,
                UqWeights::default_paper(),
                seed ^ 0x55,
            );
            if h.best(0.0).unwrap().summary.interval.center
                <= r.best(0.0).unwrap().summary.interval.center
            {
                wins += 1;
            }
        }
        assert!(wins >= 2, "AMBS won only {wins}/4");
    }
}
