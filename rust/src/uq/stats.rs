//! Estimators for prediction mean/variance and loss variability.
//!
//! Notation follows the paper: N trained models ("trials") of the same
//! architecture θ, T MC-dropout passes per trained model, weights
//! w_T (trained) and w_D (dropout) with w_T + w_D = 1.

/// Weights for the trained-vs-dropout average of Eqs. (6)-(7).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UqWeights {
    pub w_trained: f64,
    pub w_dropout: f64,
}

impl UqWeights {
    /// Paper default: w_T = w_D = 0.5.
    pub fn default_paper() -> Self {
        UqWeights { w_trained: 0.5, w_dropout: 0.5 }
    }

    pub fn new(w_trained: f64, w_dropout: f64) -> Self {
        assert!(w_trained >= 0.0, "w_T must be >= 0");
        assert!(w_dropout > 0.0, "w_D must be > 0 (paper Sec. IV)");
        let s = w_trained + w_dropout;
        assert!((s - 1.0).abs() < 1e-9, "w_T + w_D must equal 1");
        UqWeights { w_trained, w_dropout }
    }
}

/// All predictions gathered for one architecture θ on a fixed input batch:
/// `trained[i]` is model i's no-dropout output, `dropout[i][t]` its t-th
/// MC-dropout pass. Each inner `Vec<f64>` is the flattened output vector.
#[derive(Debug, Clone, Default)]
pub struct PredictionSet {
    pub trained: Vec<Vec<f64>>,
    pub dropout: Vec<Vec<Vec<f64>>>,
}

impl PredictionSet {
    pub fn n_trained(&self) -> usize {
        self.trained.len()
    }

    pub fn n_dropout_total(&self) -> usize {
        self.dropout.iter().map(Vec::len).sum()
    }

    fn dim(&self) -> usize {
        self.trained
            .first()
            .map(Vec::len)
            .or_else(|| {
                self.dropout
                    .first()
                    .and_then(|d| d.first())
                    .map(Vec::len)
            })
            .unwrap_or(0)
    }

    /// μ_pred (Eq. 6): weighted mean of trained and dropout outputs.
    pub fn mu_pred(&self, w: UqWeights) -> Vec<f64> {
        let d = self.dim();
        let n = self.n_trained().max(1) as f64;
        let nt = self.n_dropout_total().max(1) as f64;
        let mut mu = vec![0.0; d];
        if w.w_trained > 0.0 {
            for y in &self.trained {
                for (m, v) in mu.iter_mut().zip(y) {
                    *m += w.w_trained / n * v;
                }
            }
        }
        for per_model in &self.dropout {
            for y in per_model {
                for (m, v) in mu.iter_mut().zip(y) {
                    *m += w.w_dropout / nt * v;
                }
            }
        }
        mu
    }

    /// V_model (Eq. 7): weighted elementwise variance around μ_pred.
    pub fn v_model(&self, w: UqWeights) -> Vec<f64> {
        let mu = self.mu_pred(w);
        let d = self.dim();
        let n = self.n_trained().max(1) as f64;
        let nt = self.n_dropout_total().max(1) as f64;
        let mut var = vec![0.0; d];
        if w.w_trained > 0.0 {
            for y in &self.trained {
                for ((v, m), yi) in var.iter_mut().zip(&mu).zip(y) {
                    let e = m - yi;
                    *v += w.w_trained / n * e * e;
                }
            }
        }
        for per_model in &self.dropout {
            for y in per_model {
                for ((v, m), yi) in var.iter_mut().zip(&mu).zip(y) {
                    let e = m - yi;
                    *v += w.w_dropout / nt * e * e;
                }
            }
        }
        var
    }
}

/// Confidence interval for the outer loss ℓ₁ of one architecture:
/// center = ℓ₁ computed from μ_pred, radius = std-dev of the N + NT
/// per-model loss values (paper Sec. IV, Feature 1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LossInterval {
    pub center: f64,
    pub radius: f64,
}

impl LossInterval {
    pub fn lower(&self) -> f64 {
        self.center - self.radius
    }
    pub fn upper(&self) -> f64 {
        self.center + self.radius
    }
}

/// Build the ℓ₁ confidence interval from the loss computed on μ_pred and
/// the individual per-model / per-dropout-pass losses.
pub fn loss_interval(center_loss: f64, member_losses: &[f64]) -> LossInterval {
    LossInterval { center: center_loss, radius: stddev(member_losses) }
}

/// Regulated loss ℓ_reg (Eq. 9): ℓ₁ + γ Σ_d g(V_model(x^d)) with the
/// default `g = ||max(0, ·)||₂` the paper suggests.
pub fn regulated_loss(ell1: f64, v_model_sum_g: f64, gamma: f64) -> f64 {
    assert!(gamma >= 0.0);
    ell1 + gamma * v_model_sum_g
}

/// The default g: Euclidean norm of the positive part.
pub fn g_norm_relu(v: &[f64]) -> f64 {
    v.iter().map(|x| x.max(0.0).powi(2)).sum::<f64>().sqrt()
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation (paper uses the plain σ of the member
/// losses as the CI radius).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Median absolute deviation (Fig. 9's variability axis).
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let m = median(xs);
    let dev: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&dev)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set() -> PredictionSet {
        PredictionSet {
            trained: vec![vec![1.0, 2.0], vec![3.0, 4.0]],
            dropout: vec![
                vec![vec![1.0, 1.0], vec![2.0, 2.0]],
                vec![vec![3.0, 3.0], vec![4.0, 4.0]],
            ],
        }
    }

    #[test]
    fn mu_pred_weighted_average() {
        // trained mean = [2,3]; dropout mean = [2.5,2.5]
        let mu = set().mu_pred(UqWeights::default_paper());
        assert!((mu[0] - 2.25).abs() < 1e-12);
        assert!((mu[1] - 2.75).abs() < 1e-12);
    }

    #[test]
    fn dropout_only_when_wt_zero() {
        let w = UqWeights::new(0.0, 1.0);
        let mu = set().mu_pred(w);
        assert!((mu[0] - 2.5).abs() < 1e-12);
        assert!((mu[1] - 2.5).abs() < 1e-12);
    }

    #[test]
    fn v_model_zero_for_constant_predictions() {
        let s = PredictionSet {
            trained: vec![vec![5.0]; 3],
            dropout: vec![vec![vec![5.0]; 4]; 3],
        };
        let v = s.v_model(UqWeights::default_paper());
        assert!(v[0].abs() < 1e-12);
    }

    #[test]
    fn v_model_positive_and_scales() {
        let v = set().v_model(UqWeights::default_paper());
        assert!(v.iter().all(|x| *x > 0.0));
        // More weight on trained (whose dim-1 spread is 1.0 vs dropout 1.0)
        // keeps variance positive either way.
        let v2 = set().v_model(UqWeights::new(0.2, 0.8));
        assert!(v2.iter().all(|x| *x > 0.0));
    }

    #[test]
    #[should_panic(expected = "w_D")]
    fn weights_validate_wd_positive() {
        let _ = UqWeights::new(1.0, 0.0);
    }

    #[test]
    fn interval_bounds() {
        let ci = loss_interval(10.0, &[9.0, 10.0, 11.0]);
        assert_eq!(ci.center, 10.0);
        assert!(ci.radius > 0.0);
        assert!(ci.lower() < ci.center && ci.center < ci.upper());
    }

    #[test]
    fn median_and_mad() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(mad(&[1.0, 1.0, 2.0, 2.0, 4.0]), 1.0);
        assert!(median(&[]).is_nan());
    }

    #[test]
    fn stddev_basics() {
        assert_eq!(stddev(&[2.0]), 0.0);
        let s = stddev(&[1.0, 3.0]);
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn regulated_loss_monotone_in_gamma() {
        let g = g_norm_relu(&[0.5, -1.0, 0.5]);
        assert!((g - (0.5f64.powi(2) * 2.0).sqrt()).abs() < 1e-12);
        let l0 = regulated_loss(1.0, g, 0.0);
        let l1 = regulated_loss(1.0, g, 10.0);
        assert_eq!(l0, 1.0);
        assert!(l1 > l0);
    }
}
