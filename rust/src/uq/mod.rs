//! Uncertainty quantification (paper Sec. IV, Feature 1).
//!
//! Implements the weighted MC-dropout estimators of Eqs. (4)-(7), the
//! confidence interval over the outer loss ℓ₁, the regulated loss of
//! Eq. (9), and the robust statistics (median / MAD) used by Fig. 9.

pub mod stats;

pub use stats::*;
