//! Image quality metrics of Table I / Fig. 10: per-pixel MSE, PSNR, and
//! SSIM (uniform 8x8 windows, standard constants; SSIM characterizes
//! structural rather than absolute error — paper §V-B).

use crate::tomo::Image;

/// Mean squared error.
pub fn mse(a: &Image, b: &Image) -> f64 {
    assert_eq!(a.data.len(), b.data.len());
    a.data
        .iter()
        .zip(&b.data)
        .map(|(x, y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.data.len() as f64
}

/// Peak signal-to-noise ratio in dB, with the peak taken from the
/// reference image (floor 1.0 to avoid degenerate blanks).
pub fn psnr(reference: &Image, test: &Image) -> f64 {
    let peak = reference.max().max(1.0) as f64;
    let e = mse(reference, test);
    if e == 0.0 {
        return f64::INFINITY;
    }
    10.0 * (peak * peak / e).log10()
}

/// Mean SSIM over dense 8x8 windows (stride 4), constants
/// C1=(0.01·L)², C2=(0.03·L)² with L = reference dynamic range.
pub fn ssim(reference: &Image, test: &Image) -> f64 {
    assert_eq!(reference.rows, test.rows);
    assert_eq!(reference.cols, test.cols);
    let l = {
        let lo = reference.data.iter().copied().fold(f32::MAX, f32::min);
        ((reference.max() - lo) as f64).max(1e-6)
    };
    let c1 = (0.01 * l).powi(2);
    let c2 = (0.03 * l).powi(2);

    let win = 8usize.min(reference.rows).min(reference.cols);
    let stride = (win / 2).max(1);
    let mut total = 0.0;
    let mut count = 0usize;

    let mut r = 0;
    while r + win <= reference.rows {
        let mut c = 0;
        while c + win <= reference.cols {
            let (mut ma, mut mb) = (0.0f64, 0.0f64);
            for i in r..r + win {
                for j in c..c + win {
                    ma += reference.at(i, j) as f64;
                    mb += test.at(i, j) as f64;
                }
            }
            let n = (win * win) as f64;
            ma /= n;
            mb /= n;
            let (mut va, mut vb, mut cov) = (0.0f64, 0.0f64, 0.0f64);
            for i in r..r + win {
                for j in c..c + win {
                    let da = reference.at(i, j) as f64 - ma;
                    let db = test.at(i, j) as f64 - mb;
                    va += da * da;
                    vb += db * db;
                    cov += da * db;
                }
            }
            va /= n - 1.0;
            vb /= n - 1.0;
            cov /= n - 1.0;
            let s = ((2.0 * ma * mb + c1) * (2.0 * cov + c2))
                / ((ma * ma + mb * mb + c1) * (va + vb + c2));
            total += s;
            count += 1;
            c += stride;
        }
        r += stride;
    }
    if count == 0 {
        1.0
    } else {
        total / count as f64
    }
}

/// Absolute-error map (Fig. 11).
pub fn error_map(reference: &Image, test: &Image) -> Image {
    let mut out = reference.clone();
    for (o, t) in out.data.iter_mut().zip(&test.data) {
        *o = (*o - t).abs();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::rng::Rng;
    use crate::tomo::phantom::{generate, PhantomConfig};

    fn phantom(seed: u64) -> Image {
        let cfg = PhantomConfig { size: 64, ..Default::default() };
        generate(&cfg, &mut Rng::new(seed))
    }

    #[test]
    fn identical_images_are_perfect() {
        let a = phantom(0);
        assert_eq!(mse(&a, &a), 0.0);
        assert_eq!(psnr(&a, &a), f64::INFINITY);
        let s = ssim(&a, &a);
        assert!((s - 1.0).abs() < 1e-9, "ssim {s}");
    }

    #[test]
    fn noisier_is_worse_in_all_metrics() {
        let a = phantom(1);
        let mut rng = Rng::new(9);
        let perturb = |img: &Image, sigma: f32, rng: &mut Rng| {
            let mut out = img.clone();
            for v in out.data.iter_mut() {
                *v += sigma * rng.normal() as f32;
            }
            out
        };
        let slight = perturb(&a, 0.02, &mut rng);
        let heavy = perturb(&a, 0.3, &mut rng);
        assert!(mse(&a, &slight) < mse(&a, &heavy));
        assert!(psnr(&a, &slight) > psnr(&a, &heavy));
        assert!(ssim(&a, &slight) > ssim(&a, &heavy));
    }

    #[test]
    fn ssim_in_valid_range() {
        let a = phantom(2);
        let b = phantom(3);
        let s = ssim(&a, &b);
        assert!((-1.0..=1.0).contains(&s), "ssim {s}");
    }

    #[test]
    fn ssim_penalizes_structure_loss_more_than_offset() {
        // A constant offset keeps structure: SSIM stays high while MSE is
        // large. Shuffled pixels destroy structure: SSIM collapses.
        let a = phantom(4);
        let mut offset = a.clone();
        for v in offset.data.iter_mut() {
            *v += 0.2;
        }
        let mut shuffled = a.clone();
        Rng::new(5).shuffle(&mut shuffled.data);
        assert!(ssim(&a, &offset) > ssim(&a, &shuffled) + 0.2);
    }

    #[test]
    fn error_map_is_absolute_difference() {
        let a = phantom(6);
        let mut b = a.clone();
        b.data[0] += 0.5;
        b.data[1] -= 0.25;
        let e = error_map(&a, &b);
        assert!((e.data[0] - 0.5).abs() < 1e-6);
        assert!((e.data[1] - 0.25).abs() < 1e-6);
        assert_eq!(e.data[2], 0.0);
    }
}
