//! SIRT — Simultaneous Iterative Reconstruction Technique (Gilbert 1972).
//!
//! Implements the update quoted in paper §V-A:
//!
//! ```text
//! x_{k+1} = x_k + C Aᵀ R (b − A x_k)
//! ```
//!
//! where `C` and `R` are diagonal matrices holding the inverse column and
//! row sums of `A`. With this preconditioning the iteration is a
//! non-expansive map and the projection residual is non-increasing — a
//! property the tests assert.

use crate::tomo::radon::{Geometry, Sinogram};
use crate::tomo::Image;

#[derive(Debug, Clone)]
pub struct SirtConfig {
    pub iterations: usize,
    /// Clamp negatives after each update (physical prior).
    pub nonneg: bool,
}

impl Default for SirtConfig {
    fn default() -> Self {
        SirtConfig { iterations: 100, nonneg: true }
    }
}

/// Reconstruction result with the residual trace (for convergence tests
/// and the §Perf bench).
#[derive(Debug, Clone)]
pub struct SirtResult {
    pub image: Image,
    pub residuals: Vec<f64>,
}

/// Run SIRT on measurements `b` under geometry `g`.
///
/// Internally builds a precomputed `Projector` once — the per-iteration
/// forward/back projections are the entire cost of SIRT, and the table
/// amortizes after the first iteration (§Perf: 3.2x on 10 iterations).
pub fn reconstruct(g: &Geometry, b: &Sinogram, cfg: &SirtConfig) -> SirtResult {
    let proj = crate::tomo::radon::Projector::new(g.clone());
    let r_inv = inv(&proj.forward(&ones_image(g.size)).data);
    let c_inv = inv(&g.col_sums().data);

    let mut x = Image::zeros(g.size, g.size);
    let mut residuals = Vec::with_capacity(cfg.iterations);

    for _ in 0..cfg.iterations {
        let ax = proj.forward(&x);
        // r = R (b - A x)
        let mut resid = Image::zeros(g.n_angles, g.n_det);
        let mut res_norm = 0.0f64;
        for i in 0..resid.data.len() {
            let d = b.data[i] - ax.data[i];
            res_norm += (d as f64) * (d as f64);
            resid.data[i] = d * r_inv[i];
        }
        residuals.push(res_norm.sqrt());
        let update = proj.back(&resid);
        for i in 0..x.data.len() {
            x.data[i] += update.data[i] * c_inv[i];
            if cfg.nonneg && x.data[i] < 0.0 {
                x.data[i] = 0.0;
            }
        }
    }
    SirtResult { image: x, residuals }
}

fn ones_image(size: usize) -> Image {
    Image { rows: size, cols: size, data: vec![1.0; size * size] }
}

fn inv(sums: &[f32]) -> Vec<f32> {
    sums.iter()
        .map(|s| if *s > 1e-8 { 1.0 / s } else { 0.0 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::rng::Rng;
    use crate::tomo::phantom::{generate, PhantomConfig};

    fn small_case() -> (Geometry, Image) {
        let cfg = PhantomConfig { size: 32, ..Default::default() };
        let mut rng = Rng::new(3);
        let img = generate(&cfg, &mut rng);
        (Geometry::new(12, 48, 32), img)
    }

    #[test]
    fn residual_nonincreasing_on_consistent_data() {
        let (g, img) = small_case();
        let b = g.forward(&img);
        let res = reconstruct(&g, &b, &SirtConfig { iterations: 30, nonneg: false });
        for w in res.residuals.windows(2) {
            assert!(
                w[1] <= w[0] * 1.0001,
                "residual increased: {} -> {}",
                w[0],
                w[1]
            );
        }
        // And substantially decreased overall.
        assert!(res.residuals.last().unwrap() < &(res.residuals[0] * 0.2));
    }

    #[test]
    fn reconstruction_approaches_phantom() {
        let (g, img) = small_case();
        let b = g.forward(&img);
        let res = reconstruct(&g, &b, &SirtConfig { iterations: 80, nonneg: true });
        let mse: f64 = img
            .data
            .iter()
            .zip(&res.image.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / img.data.len() as f64;
        // 12 angles over a 32px image is mildly underdetermined; SIRT
        // should still get close on a consistent system.
        assert!(mse < 5e-3, "mse {mse}");
    }

    #[test]
    fn nonneg_clamp_respected() {
        let (g, img) = small_case();
        let b = g.forward(&img);
        let res = reconstruct(&g, &b, &SirtConfig { iterations: 10, nonneg: true });
        assert!(res.image.data.iter().all(|v| *v >= 0.0));
    }

    #[test]
    fn zero_measurements_give_zero_image() {
        let g = Geometry::new(8, 48, 32);
        let b = Image::zeros(8, 48);
        let res = reconstruct(&g, &b, &SirtConfig::default());
        assert!(res.image.data.iter().all(|v| *v == 0.0));
    }
}
