//! Parallel-beam projector pair (the TomoPy substitute, DESIGN.md §3).
//!
//! Pixel-driven formulation: each pixel splats its value onto the two
//! detector bins its center projects between, with linear interpolation
//! weights. The back-projector *gathers with the same weights*, so
//! `back` is the exact adjoint of `forward` — a property SIRT's
//! convergence analysis assumes and our property tests verify via
//! ⟨Ax, y⟩ = ⟨x, Aᵀy⟩.

use crate::tomo::Image;

/// Projection geometry: `n_angles` uniformly spaced over [0, π),
/// `n_det` detector bins spanning the image diagonal.
#[derive(Debug, Clone)]
pub struct Geometry {
    pub n_angles: usize,
    pub n_det: usize,
    pub size: usize,
    /// Precomputed (cos, sin) per angle.
    trig: Vec<(f64, f64)>,
    det_center: f64,
    img_center: f64,
}

/// A sinogram: rows = angles, cols = detector bins.
pub type Sinogram = Image;

impl Geometry {
    pub fn new(n_angles: usize, n_det: usize, size: usize) -> Self {
        assert!(n_angles > 0 && n_det > 1 && size > 1);
        let trig = (0..n_angles)
            .map(|a| {
                let phi = std::f64::consts::PI * a as f64 / n_angles as f64;
                (phi.cos(), phi.sin())
            })
            .collect();
        Geometry {
            n_angles,
            n_det,
            size,
            trig,
            det_center: (n_det as f64 - 1.0) / 2.0,
            img_center: (size as f64 - 1.0) / 2.0,
        }
    }

    /// Paper §V-A geometry: 128x128 images, detector bins = image width.
    /// We use 16 angles (paper: 20) so the U-Net's power-of-two
    /// down/up-sampling path stays exact; see DESIGN.md §3.
    pub fn paper(size: usize, n_angles: usize) -> Self {
        Geometry::new(n_angles, size, size)
    }

    #[inline]
    fn det_coord(&self, r: usize, c: usize, cos: f64, sin: f64) -> f64 {
        let x = c as f64 - self.img_center;
        let y = r as f64 - self.img_center;
        // Detector spacing 1 px; t = x cosφ + y sinφ.
        x * cos + y * sin + self.det_center
    }

    /// Forward projection `A x`.
    pub fn forward(&self, img: &Image) -> Sinogram {
        assert_eq!(img.rows, self.size);
        assert_eq!(img.cols, self.size);
        let mut sino = Image::zeros(self.n_angles, self.n_det);
        for (a, &(cos, sin)) in self.trig.iter().enumerate() {
            let row = &mut sino.data[a * self.n_det..(a + 1) * self.n_det];
            for r in 0..self.size {
                for c in 0..self.size {
                    let v = img.at(r, c);
                    if v == 0.0 {
                        continue;
                    }
                    let t = self.det_coord(r, c, cos, sin);
                    let i0 = t.floor();
                    let w1 = (t - i0) as f32;
                    let i0 = i0 as isize;
                    if (0..self.n_det as isize).contains(&i0) {
                        row[i0 as usize] += v * (1.0 - w1);
                    }
                    let i1 = i0 + 1;
                    if (0..self.n_det as isize).contains(&i1) {
                        row[i1 as usize] += v * w1;
                    }
                }
            }
        }
        sino
    }

    /// Adjoint (unfiltered back-projection) `Aᵀ b`.
    pub fn back(&self, sino: &Sinogram) -> Image {
        assert_eq!(sino.rows, self.n_angles);
        assert_eq!(sino.cols, self.n_det);
        let mut img = Image::zeros(self.size, self.size);
        for (a, &(cos, sin)) in self.trig.iter().enumerate() {
            let row = &sino.data[a * self.n_det..(a + 1) * self.n_det];
            for r in 0..self.size {
                for c in 0..self.size {
                    let t = self.det_coord(r, c, cos, sin);
                    let i0 = t.floor();
                    let w1 = (t - i0) as f32;
                    let i0 = i0 as isize;
                    let mut acc = 0.0f32;
                    if (0..self.n_det as isize).contains(&i0) {
                        acc += row[i0 as usize] * (1.0 - w1);
                    }
                    let i1 = i0 + 1;
                    if (0..self.n_det as isize).contains(&i1) {
                        acc += row[i1 as usize] * w1;
                    }
                    *img.at_mut(r, c) += acc;
                }
            }
        }
        img
    }

    /// Row sums of `A` (as a sinogram): `A · 1`. Used for SIRT's `R`.
    pub fn row_sums(&self) -> Sinogram {
        let ones = Image {
            rows: self.size,
            cols: self.size,
            data: vec![1.0; self.size * self.size],
        };
        self.forward(&ones)
    }

    /// Column sums of `A` (as an image): `Aᵀ · 1`. Used for SIRT's `C`.
    pub fn col_sums(&self) -> Image {
        let ones = Image {
            rows: self.n_angles,
            cols: self.n_det,
            data: vec![1.0; self.n_angles * self.n_det],
        };
        self.back(&ones)
    }
}

/// Precomputed projector: the bilinear splat weights of `Geometry` baked
/// into a per-angle table (§Perf optimization: SIRT re-derived
/// `det_coord` + weights for every pixel on every iteration; the table
/// turns both `forward` and `back` into linear gathers/scatters —
/// measured 2.6-3.4x on the 128x16 paper geometry, amortized over SIRT's
/// iterations).
pub struct Projector {
    geo: Geometry,
    /// Per angle, per pixel (row-major): (first bin index, w0, w1).
    /// `bin < 0` marks a pixel projecting outside the detector.
    table: Vec<Vec<(i32, f32, f32)>>,
}

impl Projector {
    pub fn new(geo: Geometry) -> Self {
        let n_det = geo.n_det as isize;
        let table = geo
            .trig
            .iter()
            .map(|&(cos, sin)| {
                let mut t = Vec::with_capacity(geo.size * geo.size);
                for r in 0..geo.size {
                    for c in 0..geo.size {
                        let tc = geo.det_coord(r, c, cos, sin);
                        let i0 = tc.floor();
                        let w1 = (tc - i0) as f32;
                        let i0 = i0 as isize;
                        // Encode edge cases by zeroing the affected weight.
                        let (bin, w0, w1) = if i0 < -1 || i0 >= n_det {
                            (-1, 0.0, 0.0)
                        } else if i0 == -1 {
                            (0, 0.0, w1) // only the upper bin is inside
                        } else if i0 == n_det - 1 {
                            (i0 as i32, 1.0 - w1, 0.0)
                        } else {
                            (i0 as i32, 1.0 - w1, w1)
                        };
                        t.push((bin, w0, w1));
                    }
                }
                t
            })
            .collect();
        Projector { geo, table }
    }

    pub fn geometry(&self) -> &Geometry {
        &self.geo
    }

    /// `A x` via the precomputed table (bit-equivalent ordering caveat:
    /// floating-point sums match `Geometry::forward` to ~1e-5 relative).
    pub fn forward(&self, img: &Image) -> Sinogram {
        let g = &self.geo;
        assert_eq!(img.rows, g.size);
        let mut sino = Image::zeros(g.n_angles, g.n_det);
        for (a, tab) in self.table.iter().enumerate() {
            let row = &mut sino.data[a * g.n_det..(a + 1) * g.n_det];
            for (v, &(bin, w0, w1)) in img.data.iter().zip(tab) {
                if bin < 0 || *v == 0.0 {
                    continue;
                }
                let b = bin as usize;
                row[b] += v * w0;
                if w1 != 0.0 {
                    row[b + 1] += v * w1;
                }
            }
        }
        sino
    }

    /// `Aᵀ b` via the same table (exact adjoint of `forward` above).
    pub fn back(&self, sino: &Sinogram) -> Image {
        let g = &self.geo;
        assert_eq!(sino.rows, g.n_angles);
        let mut img = Image::zeros(g.size, g.size);
        for (a, tab) in self.table.iter().enumerate() {
            let row = &sino.data[a * g.n_det..(a + 1) * g.n_det];
            for (o, &(bin, w0, w1)) in img.data.iter_mut().zip(tab) {
                if bin < 0 {
                    continue;
                }
                let b = bin as usize;
                let mut acc = row[b] * w0;
                if w1 != 0.0 {
                    acc += row[b + 1] * w1;
                }
                *o += acc;
            }
        }
        img
    }
}

/// Remove every other angle (paper §V-A: "every other angle is removed")
/// by zeroing the odd rows; returns (sparse sinogram, kept-angle mask).
pub fn sparsify(sino: &Sinogram) -> (Sinogram, Vec<bool>) {
    let mut out = sino.clone();
    let mut kept = vec![false; sino.rows];
    for a in 0..sino.rows {
        if a % 2 == 0 {
            kept[a] = true;
        } else {
            for c in 0..sino.cols {
                *out.at_mut(a, c) = 0.0;
            }
        }
    }
    (out, kept)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::sampling::rng::Rng;
    use crate::util::prop::forall;

    fn rand_img(rows: usize, cols: usize, rng: &mut Rng) -> Image {
        Image {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.f64() as f32).collect(),
        }
    }

    #[test]
    fn forward_preserves_mass_per_angle() {
        // Every pixel center projects inside the detector when n_det spans
        // the diagonal, so each angle-row of A·x sums to the image mass.
        let g = Geometry::new(8, 200, 64);
        let mut rng = Rng::new(0);
        let img = rand_img(64, 64, &mut rng);
        let mass: f32 = img.data.iter().sum();
        let sino = g.forward(&img);
        for a in 0..g.n_angles {
            let row_sum: f32 =
                sino.data[a * g.n_det..(a + 1) * g.n_det].iter().sum();
            assert!(
                (row_sum - mass).abs() < mass * 1e-4,
                "angle {a}: {row_sum} vs {mass}"
            );
        }
    }

    #[test]
    fn back_is_adjoint_of_forward() {
        let g = Geometry::new(6, 96, 48);
        forall("<Ax,y> == <x,A^T y>", 20, |rng| {
            let x = rand_img(48, 48, rng);
            let y = rand_img(6, 96, rng);
            let ax = g.forward(&x);
            let aty = g.back(&y);
            let lhs: f64 = ax
                .data
                .iter()
                .zip(&y.data)
                .map(|(a, b)| (*a as f64) * (*b as f64))
                .sum();
            let rhs: f64 = x
                .data
                .iter()
                .zip(&aty.data)
                .map(|(a, b)| (*a as f64) * (*b as f64))
                .sum();
            prop_assert!(
                (lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0),
                "adjoint mismatch {lhs} vs {rhs}"
            );
            Ok(())
        });
    }

    #[test]
    fn point_source_projects_to_correct_bin() {
        let g = Geometry::new(1, 65, 65); // single angle φ=0: t = x offset
        let mut img = Image::zeros(65, 65);
        *img.at_mut(32, 40) = 1.0; // 8 px right of center
        let sino = g.forward(&img);
        // det_center = 32, so bin 40 gets the mass.
        assert!((sino.at(0, 40) - 1.0).abs() < 1e-6);
        assert_eq!(sino.data.iter().filter(|v| **v != 0.0).count(), 1);
    }

    #[test]
    fn sparsify_zeroes_odd_angles() {
        let g = Geometry::paper(32, 8);
        let mut rng = Rng::new(2);
        let sino = g.forward(&rand_img(32, 32, &mut rng));
        let (sparse, kept) = sparsify(&sino);
        assert_eq!(kept, vec![true, false, true, false, true, false, true, false]);
        for a in 0..8 {
            let row = &sparse.data[a * g.n_det..(a + 1) * g.n_det];
            if a % 2 == 1 {
                assert!(row.iter().all(|v| *v == 0.0));
            } else {
                assert_eq!(
                    row,
                    &sino.data[a * g.n_det..(a + 1) * g.n_det]
                );
            }
        }
    }

    #[test]
    fn projector_matches_reference_geometry() {
        let g = Geometry::new(7, 96, 48);
        let p = Projector::new(g.clone());
        forall("projector == geometry", 15, |rng| {
            let x = rand_img(48, 48, rng);
            let (a, b) = (g.forward(&x), p.forward(&x));
            for (u, v) in a.data.iter().zip(&b.data) {
                prop_assert!(
                    (u - v).abs() < 1e-4 * (1.0 + u.abs()),
                    "forward mismatch {u} vs {v}"
                );
            }
            let y = rand_img(7, 96, rng);
            let (a, b) = (g.back(&y), p.back(&y));
            for (u, v) in a.data.iter().zip(&b.data) {
                prop_assert!(
                    (u - v).abs() < 1e-4 * (1.0 + u.abs()),
                    "back mismatch {u} vs {v}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn projector_is_exact_adjoint() {
        let p = Projector::new(Geometry::new(5, 80, 40));
        forall("projector adjoint", 10, |rng| {
            let x = rand_img(40, 40, rng);
            let y = rand_img(5, 80, rng);
            let lhs: f64 = p
                .forward(&x)
                .data
                .iter()
                .zip(&y.data)
                .map(|(a, b)| (*a as f64) * (*b as f64))
                .sum();
            let rhs: f64 = x
                .data
                .iter()
                .zip(&p.back(&y).data)
                .map(|(a, b)| (*a as f64) * (*b as f64))
                .sum();
            prop_assert!(
                (lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0),
                "{lhs} vs {rhs}"
            );
            Ok(())
        });
    }

    #[test]
    fn sums_are_positive() {
        let g = Geometry::new(4, 48, 32);
        assert!(g.row_sums().data.iter().all(|v| *v >= 0.0));
        let cs = g.col_sums();
        // Interior pixels must be touched by every angle.
        assert!(cs.at(16, 16) > 0.0);
    }
}
