//! Poisson measurement noise (paper §V-A: "Poisson noise is added").
//!
//! Photon-counting model: the clean sinogram is scaled to an expected
//! count level, Poisson-sampled, and rescaled. Higher `counts_per_unit`
//! means higher dose ⇒ lower relative noise.

use crate::sampling::rng::Rng;
use crate::tomo::radon::Sinogram;

/// Apply Poisson noise with the given expected counts per unit intensity.
pub fn poisson_noise(
    sino: &Sinogram,
    counts_per_unit: f64,
    rng: &mut Rng,
) -> Sinogram {
    assert!(counts_per_unit > 0.0);
    let mut out = sino.clone();
    for v in out.data.iter_mut() {
        let lambda = (*v as f64).max(0.0) * counts_per_unit;
        *v = (rng.poisson(lambda) as f64 / counts_per_unit) as f32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tomo::Image;

    #[test]
    fn noise_preserves_mean_roughly() {
        let sino = Image {
            rows: 4,
            cols: 64,
            data: vec![2.0; 256],
        };
        let mut rng = Rng::new(0);
        let noisy = poisson_noise(&sino, 100.0, &mut rng);
        let mean: f32 = noisy.data.iter().sum::<f32>() / 256.0;
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
        // And actually perturbs values.
        assert!(noisy.data.iter().any(|v| (*v - 2.0).abs() > 1e-6));
    }

    #[test]
    fn higher_dose_means_less_noise() {
        let sino = Image { rows: 8, cols: 64, data: vec![1.0; 512] };
        let mut rng = Rng::new(1);
        let spread = |counts: f64, rng: &mut Rng| {
            let noisy = poisson_noise(&sino, counts, rng);
            let m: f64 =
                noisy.data.iter().map(|v| *v as f64).sum::<f64>() / 512.0;
            (noisy
                .data
                .iter()
                .map(|v| (*v as f64 - m).powi(2))
                .sum::<f64>()
                / 512.0)
                .sqrt()
        };
        let low_dose = spread(10.0, &mut rng);
        let high_dose = spread(10_000.0, &mut rng);
        assert!(high_dose < low_dose * 0.2, "{high_dose} vs {low_dose}");
    }

    #[test]
    fn zero_input_stays_zero() {
        let sino = Image { rows: 2, cols: 8, data: vec![0.0; 16] };
        let mut rng = Rng::new(2);
        let noisy = poisson_noise(&sino, 1000.0, &mut rng);
        assert!(noisy.data.iter().all(|v| *v == 0.0));
    }
}
