//! Computed-tomography substrate (paper §V).
//!
//! Everything the CT case study needs, built from scratch: phantom
//! generation (XDesign substitute), a parallel-beam projector pair
//! (forward `A`, adjoint `Aᵀ`), Poisson measurement noise, the SIRT
//! reconstruction of Gilbert 1972 (the update equation quoted in §V-A),
//! and the image metrics (MSE / PSNR / SSIM) of Table I.

pub mod metrics;
pub mod noise;
pub mod phantom;
pub mod radon;
pub mod sirt;

/// Dense 2-D image, row-major `(rows, cols)`.
#[derive(Debug, Clone, PartialEq)]
pub struct Image {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Image {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Image { rows, cols, data: vec![0.0; rows * cols] }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn max(&self) -> f32 {
        self.data.iter().copied().fold(f32::MIN, f32::max)
    }

    /// Write as binary PGM (P5) for quick visual inspection of Fig. 10/11
    /// style outputs; values are min-max scaled to 0..255.
    pub fn write_pgm(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let lo = self.data.iter().copied().fold(f32::MAX, f32::min);
        let hi = self.max();
        let span = (hi - lo).max(1e-12);
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "P5\n{} {}\n255", self.cols, self.rows)?;
        let bytes: Vec<u8> = self
            .data
            .iter()
            .map(|v| (((v - lo) / span) * 255.0).round() as u8)
            .collect();
        f.write_all(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_indexing() {
        let mut im = Image::zeros(2, 3);
        *im.at_mut(1, 2) = 5.0;
        assert_eq!(im.at(1, 2), 5.0);
        assert_eq!(im.at(0, 0), 0.0);
        assert_eq!(im.max(), 5.0);
    }

    #[test]
    fn pgm_roundtrip_header() {
        let im = Image::zeros(4, 6);
        let p = std::env::temp_dir().join("hyppo_tomo_test.pgm");
        im.write_pgm(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert!(bytes.starts_with(b"P5\n6 4\n255\n"));
        assert_eq!(bytes.len(), 11 + 24);
        std::fs::remove_file(&p).ok();
    }
}
