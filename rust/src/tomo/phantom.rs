//! Random circle phantoms — the XDesign substitute (DESIGN.md §3).
//!
//! The paper's dataset is 17,500 simulated 128x128 images of "circles of
//! various sizes, emulating the different feature scales present in
//! experimental data". We reproduce that statistical class: each phantom
//! is a handful of anti-aliased discs of log-uniform radius and random
//! contrast inside the circular scanner support.

use crate::sampling::rng::Rng;
use crate::tomo::Image;

/// Configuration for phantom sampling.
#[derive(Debug, Clone)]
pub struct PhantomConfig {
    pub size: usize,
    pub min_circles: usize,
    pub max_circles: usize,
    pub min_radius: f64,
    pub max_radius: f64,
}

impl Default for PhantomConfig {
    fn default() -> Self {
        PhantomConfig {
            size: 128,
            min_circles: 3,
            max_circles: 10,
            min_radius: 3.0,
            max_radius: 28.0,
        }
    }
}

/// Sample one phantom.
pub fn generate(cfg: &PhantomConfig, rng: &mut Rng) -> Image {
    let n = cfg.size;
    let mut im = Image::zeros(n, n);
    let n_circ =
        rng.i64_in(cfg.min_circles as i64, cfg.max_circles as i64) as usize;
    let center = (n as f64 - 1.0) / 2.0;
    let support = center * 0.95;

    for _ in 0..n_circ {
        // Log-uniform radius emulates XDesign's multi-scale features.
        let lr = cfg.min_radius.ln()
            + rng.f64() * (cfg.max_radius.ln() - cfg.min_radius.ln());
        let radius = lr.exp();
        // Center inside the support ring so the disc stays in view.
        let max_off = (support - radius).max(1.0);
        let ang = rng.f64() * std::f64::consts::TAU;
        let off = rng.f64().sqrt() * max_off;
        let cx = center + off * ang.cos();
        let cy = center + off * ang.sin();
        let intensity = (0.2 + 0.8 * rng.f64()) as f32;

        let r0 = ((cy - radius - 1.0).floor().max(0.0)) as usize;
        let r1 = ((cy + radius + 1.0).ceil().min(n as f64 - 1.0)) as usize;
        let c0 = ((cx - radius - 1.0).floor().max(0.0)) as usize;
        let c1 = ((cx + radius + 1.0).ceil().min(n as f64 - 1.0)) as usize;
        for r in r0..=r1 {
            for c in c0..=c1 {
                let d = ((r as f64 - cy).powi(2)
                    + (c as f64 - cx).powi(2))
                .sqrt();
                // 1-pixel anti-aliased edge.
                let cov = (radius - d + 0.5).clamp(0.0, 1.0) as f32;
                if cov > 0.0 {
                    let v = im.at_mut(r, c);
                    *v = (*v + intensity * cov).min(1.5);
                }
            }
        }
    }
    im
}

/// Generate a dataset of phantoms with a deterministic per-index seed
/// derived from `base_seed` (so train/val/test splits are reproducible
/// regardless of generation order).
pub fn dataset(cfg: &PhantomConfig, base_seed: u64, count: usize) -> Vec<Image> {
    (0..count)
        .map(|i| {
            let mut rng = Rng::new(
                base_seed ^ (i as u64).wrapping_mul(0x9e3779b97f4a7c15),
            );
            generate(cfg, &mut rng)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phantom_values_bounded() {
        let cfg = PhantomConfig::default();
        let mut rng = Rng::new(0);
        let im = generate(&cfg, &mut rng);
        assert_eq!(im.rows, 128);
        assert!(im.data.iter().all(|v| (0.0..=1.5).contains(v)));
        assert!(im.max() > 0.0, "phantom must not be empty");
    }

    #[test]
    fn phantom_mass_inside_support() {
        let cfg = PhantomConfig::default();
        let mut rng = Rng::new(1);
        let im = generate(&cfg, &mut rng);
        let n = im.rows as f64;
        let center = (n - 1.0) / 2.0;
        let mut outside = 0.0f32;
        for r in 0..im.rows {
            for c in 0..im.cols {
                let d = ((r as f64 - center).powi(2)
                    + (c as f64 - center).powi(2))
                .sqrt();
                if d > center {
                    outside += im.at(r, c);
                }
            }
        }
        assert!(
            outside < 0.01 * im.data.iter().sum::<f32>(),
            "mass must concentrate inside the scanner support"
        );
    }

    #[test]
    fn dataset_deterministic_and_distinct() {
        let cfg = PhantomConfig { size: 32, ..Default::default() };
        let a = dataset(&cfg, 7, 3);
        let b = dataset(&cfg, 7, 3);
        assert_eq!(a[0], b[0]);
        assert_eq!(a[2], b[2]);
        assert_ne!(a[0], a[1], "different indices must differ");
        let c = dataset(&cfg, 8, 1);
        assert_ne!(a[0], c[0], "different seeds must differ");
    }
}
