//! `hyppo` — the Layer-3 coordinator CLI.
//!
//! Subcommands:
//!   run        run an HPO experiment from a TOML config (synthetic or HLO
//!              backend) on the simulated cluster
//!   slurm      emit the SLURM batch script for a steps × tasks topology
//!   artifacts  inspect the AOT artifact manifest
//!   speedup    print the Fig. 8-style virtual-time speedup for a topology

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use hyppo::cluster::sim::{simulate, speedup, EvalCost, SimConfig};
use hyppo::cluster::slurm::{render, SlurmJobConfig};
use hyppo::cluster::workers::{run_async, AsyncConfig};
use hyppo::cluster::Topology;
use hyppo::eval::hlo::MlpHloEvaluator;
use hyppo::eval::synthetic::SyntheticEvaluator;
use hyppo::eval::Evaluator;
use hyppo::optimizer::History;
use hyppo::report::{print_table, write_history_csv};
use hyppo::runtime::{artifact_dir, SharedEngine};
use hyppo::util::cli::Args;

const USAGE: &str = "\
hyppo — surrogate-based multi-level-parallelism HPO (MLHPC'21 reproduction)

USAGE:
  hyppo run --config <file.toml> [--backend synthetic|mlp] [--out out.csv]
  hyppo slurm [--steps N] [--tasks M] [--cpu]
  hyppo artifacts [--family mlp|cnn|unet]
  hyppo speedup [--steps N] [--tasks M] [--evals E] [--trials T]
  hyppo help
";

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let result = match cmd {
        "run" => cmd_run(&args),
        "slurm" => cmd_slurm(&args),
        "artifacts" => cmd_artifacts(&args),
        "speedup" => cmd_speedup(&args),
        "help" | "--help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprint!("unknown command {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn summarize(history: &History, gamma: f64) {
    let best = history.best(gamma).expect("non-empty history");
    let rows: Vec<Vec<String>> = vec![vec![
        best.id.to_string(),
        format!("{:?}", best.theta),
        format!("{:.4e}", best.summary.interval.center),
        format!("{:.4e}", best.summary.interval.radius),
        best.n_params.to_string(),
    ]];
    print_table(
        "best evaluation",
        &["id", "theta", "loss", "ci_radius", "n_params"],
        &rows,
    );
    println!(
        "evaluations: {}   best objective: {:.6e}",
        history.len(),
        best.objective(gamma)
    );
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg_path = args
        .get("config")
        .context("--config <file.toml> is required")?;
    let cfg = hyppo::config::load(std::path::Path::new(cfg_path))?;
    let backend = args.str_or("backend", "synthetic");

    let history = match backend.as_str() {
        "synthetic" => {
            let ev = SyntheticEvaluator::new(cfg.space.clone(), cfg.hpo.seed);
            run_async(
                &ev,
                &AsyncConfig {
                    hpo: cfg.hpo.clone(),
                    topology: cfg.topology,
                    mode: cfg.mode,
                    time_scale: args.f64_or("time-scale", 1e-5),
                },
            )
        }
        "mlp" => {
            let dir = artifact_dir()
                .context("artifacts not found; run `make artifacts`")?;
            let engine = Arc::new(SharedEngine::load(dir)?);
            let series = hyppo::data::timeseries::generate(
                &hyppo::data::timeseries::SeriesConfig::default(),
                cfg.hpo.seed,
            );
            let ws = hyppo::data::timeseries::windowed(&series, 16);
            let split = hyppo::data::timeseries::split(&ws, 0.7, 0.15);
            let to_ds = |w: &hyppo::data::timeseries::WindowedSeries| {
                hyppo::eval::hlo::Dataset {
                    x: w.x.clone(),
                    y: w.y.iter().map(|v| vec![*v]).collect(),
                }
            };
            let ev = MlpHloEvaluator::new(
                engine,
                to_ds(&split.train),
                to_ds(&split.val),
                16,
                1,
                10,
            );
            run_async(
                &ev,
                &AsyncConfig {
                    hpo: cfg.hpo.clone(),
                    topology: cfg.topology,
                    mode: cfg.mode,
                    time_scale: 0.0,
                },
            )
        }
        other => bail!("unknown backend {other:?} (synthetic|mlp)"),
    };

    summarize(&history, cfg.hpo.gamma);
    if let Some(out) = args.get("out") {
        write_history_csv(&history, cfg.hpo.gamma, out)?;
        println!("history -> {out}");
    }
    Ok(())
}

fn cmd_slurm(args: &Args) -> Result<()> {
    let cfg = SlurmJobConfig {
        topology: Topology::new(
            args.usize_or("steps", 2),
            args.usize_or("tasks", 3),
        ),
        use_gpu: !args.flag("cpu"),
        ..Default::default()
    };
    print!("{}", render(&cfg));
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = artifact_dir()
        .context("artifacts not found; run `make artifacts`")?;
    let manifest = hyppo::runtime::Manifest::load(&dir)?;
    let family = args.get("family");
    let mut rows = Vec::new();
    for a in manifest.iter() {
        if family.map(|f| f != a.family).unwrap_or(false) {
            continue;
        }
        rows.push(vec![
            a.family.clone(),
            a.arch.clone(),
            a.role.clone(),
            a.n_param_arrays.to_string(),
            a.inputs.len().to_string(),
        ]);
    }
    print_table(
        &format!("artifacts in {}", dir.display()),
        &["family", "arch", "role", "param_arrays", "inputs"],
        &rows,
    );
    Ok(())
}

fn cmd_speedup(args: &Args) -> Result<()> {
    let steps = args.usize_or("steps", 16);
    let tasks = args.usize_or("tasks", 6);
    let n_evals = args.usize_or("evals", 50);
    let n_trials = args.usize_or("trials", 5);

    // Heterogeneous workload from the synthetic trainer's cost model.
    let space = hyppo::space::Space::new(vec![
        hyppo::space::ParamSpec::new("a", 0, 20),
        hyppo::space::ParamSpec::new("b", 0, 20),
    ]);
    let ev = SyntheticEvaluator::new(space.clone(), 1);
    let mut rng = hyppo::sampling::Rng::new(1);
    let evals: Vec<EvalCost> = (0..n_evals)
        .map(|_| {
            let theta = space.random_point(&mut rng);
            EvalCost {
                trial_costs: (0..n_trials)
                    .map(|t| ev.run_trial(&theta, t, 0).cost)
                    .collect(),
            }
        })
        .collect();
    let cfg = SimConfig::trial_parallel(Topology::new(steps, tasks));
    let r = simulate(&evals, &cfg);
    println!(
        "topology {steps}x{tasks} ({} processors): makespan {:?}, speedup vs 1x1 = {:.1}x",
        steps * tasks,
        r.makespan,
        speedup(&evals, &cfg)
    );
    Ok(())
}
