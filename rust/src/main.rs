//! `hyppo` — the Layer-3 coordinator CLI.
//!
//! Subcommands:
//!   run        run (or resume) an HPO experiment from a TOML config
//!              (synthetic or HLO backend) on the simulated cluster
//!   sweep      drive a seed × topology grid through the same executor,
//!              sharing the artifact/engine cache across experiments
//!   slurm      emit the SLURM batch script for a steps × tasks topology
//!   artifacts  inspect the AOT artifact manifest
//!   speedup    print the Fig. 8-style virtual-time speedup for a topology
//!   simulate   run the fault-injected virtual cluster (chaos testbed)
//!              over a config + fault plan, reporting queueing metrics
//!   serve      run the sharded multi-study HPO service (write-ahead
//!              logged, ask/tell wire protocol over TCP)
//!   worker     connect to a `hyppo serve` endpoint and run trials
//!
//! See README.md for a walkthrough and DESIGN.md for the architecture.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use hyppo::cluster::faults::FaultPlan;
use hyppo::cluster::sim::{
    simulate, simulate_chaos, speedup, ChaosConfig, EvalCost, SimConfig,
};
use hyppo::cluster::slurm::{render, SlurmJobConfig};
use hyppo::cluster::Topology;
use hyppo::config::RunConfig;
use hyppo::eval::hlo::MlpHloEvaluator;
use hyppo::eval::synthetic::SyntheticEvaluator;
use hyppo::eval::Evaluator;
use hyppo::exec::{
    resume_experiment, run_experiment, run_sweep, Checkpoint,
    CheckpointPolicy, ExecConfig, ExecOutcome,
};
use hyppo::optimizer::{AdaptiveTrials, History};
use hyppo::report::{print_table, write_history_csv, write_sweep_csv};
use hyppo::runtime::{artifact_dir, SharedEngine};
use hyppo::serve::{
    serve_listener, worker_loop, ErrorCode, Request, Response,
    ServeConfig, Service, ShardPool, SystemClock,
    PROTO_VERSION,
};
use hyppo::util::cli::Args;

const USAGE: &str = "\
hyppo — surrogate-based multi-level-parallelism HPO (MLHPC'21 reproduction)

USAGE:
  hyppo run --config <file.toml> [--backend synthetic|mlp] [--out out.csv]
            [--checkpoint ckpt.json] [--resume ckpt.json]
            [--max-completions N] [--time-scale S]
            [--adaptive-trials STD [--max-trials N]]
            [--scoring-threads N]
            [--max-exact-n N] [--scaling-mode subset|forest]
  hyppo sweep --config <file.toml> [--backend synthetic|mlp]
            [--seeds 0,1,2] [--topologies 1x1,4x2] [--out sweep.csv]
            [--scoring-threads N]
  hyppo slurm [--steps N] [--tasks M] [--cpu]
  hyppo artifacts [--family mlp|cnn|unet]
  hyppo speedup [--steps N] [--tasks M] [--evals E] [--trials T]
  hyppo simulate --config <file.toml> [--faults plan.toml]
            [--steps N] [--tasks M] [--max-retries R] [--json out.json]
  hyppo serve --config <serve.toml> [--listen HOST:PORT]
            [--shards N] [--wal DIR]
            [--wal-failure wedge|readonly|failover] [--wal-failover DIR]
  hyppo worker [--connect HOST:PORT] [--worker-id ID]
            [--studies a,b,c] [--retries N] [--retry-backoff-ms MS]
  hyppo help
";

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let result = match cmd {
        "run" => cmd_run(&args),
        "sweep" => cmd_sweep(&args),
        "slurm" => cmd_slurm(&args),
        "artifacts" => cmd_artifacts(&args),
        "speedup" => cmd_speedup(&args),
        "simulate" => cmd_simulate(&args),
        "serve" => cmd_serve(&args),
        "worker" => cmd_worker(&args),
        "help" | "--help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprint!("unknown command {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn summarize(history: &History, space: &hyppo::space::Space, gamma: f64) {
    let best = history.best(gamma).expect("non-empty history");
    let rows: Vec<Vec<String>> = vec![vec![
        best.id.to_string(),
        space.format_point(&best.theta),
        format!("{:.4e}", best.summary.interval.center),
        format!("{:.4e}", best.summary.interval.radius),
        best.n_params.to_string(),
    ]];
    print_table(
        "best evaluation",
        &["id", "theta", "loss", "ci_radius", "n_params"],
        &rows,
    );
    println!(
        "evaluations: {}   best objective: {:.6e}",
        history.len(),
        best.objective(gamma)
    );
}

/// Build an evaluator for `backend`, seeded with `seed`. The engine is
/// created once by the caller and shared, so every experiment (and every
/// sweep cell) reuses one PJRT compile cache.
fn make_evaluator(
    backend: &str,
    cfg: &RunConfig,
    engine: Option<&Arc<SharedEngine>>,
    seed: u64,
) -> Result<Box<dyn Evaluator>> {
    match backend {
        "synthetic" => Ok(Box::new(SyntheticEvaluator::new(
            cfg.space.clone(),
            seed,
        ))),
        "mlp" => {
            let engine = engine.expect("caller creates the engine");
            let series = hyppo::data::timeseries::generate(
                &hyppo::data::timeseries::SeriesConfig::default(),
                seed,
            );
            let ws = hyppo::data::timeseries::windowed(&series, 16);
            let split = hyppo::data::timeseries::split(&ws, 0.7, 0.15);
            let to_ds = |w: &hyppo::data::timeseries::WindowedSeries| {
                hyppo::eval::hlo::Dataset {
                    x: w.x.clone(),
                    y: w.y.iter().map(|v| vec![*v]).collect(),
                }
            };
            Ok(Box::new(MlpHloEvaluator::new(
                Arc::clone(engine),
                to_ds(&split.train),
                to_ds(&split.val),
                16,
                1,
                10,
            )))
        }
        other => bail!("unknown backend {other:?} (synthetic|mlp)"),
    }
}

/// Load the shared engine when the backend needs it.
fn engine_for(backend: &str) -> Result<Option<Arc<SharedEngine>>> {
    if backend != "mlp" {
        return Ok(None);
    }
    let dir = artifact_dir()
        .context("artifacts not found; run `make artifacts`")?;
    Ok(Some(Arc::new(SharedEngine::load(dir)?)))
}

/// Default time-scale per backend: simulated costs are compressed, real
/// training runs at genuine wall time.
fn default_time_scale(backend: &str) -> f64 {
    if backend == "mlp" {
        0.0
    } else {
        1e-5
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg_path = args
        .get("config")
        .context("--config <file.toml> is required")?;
    let cfg = hyppo::config::load(std::path::Path::new(cfg_path))?;
    let backend = args.str_or("backend", "synthetic");
    let engine = engine_for(&backend)?;
    let evaluator =
        make_evaluator(&backend, &cfg, engine.as_ref(), cfg.hpo.seed)?;

    let resume_path = args.get("resume");
    let checkpoint_path = args.get("checkpoint").or(resume_path);
    let mut exec_cfg = ExecConfig::new(
        cfg.hpo.clone(),
        cfg.topology,
        cfg.mode,
        args.f64_or("time-scale", default_time_scale(&backend)),
    );
    exec_cfg.checkpoint =
        checkpoint_path.map(CheckpointPolicy::every_completion);
    if let Some(n) = args.get("max-completions") {
        exec_cfg.max_completions =
            Some(n.parse().context("--max-completions must be a count")?);
    }
    if let Some(raw) = args.get("scoring-threads") {
        // Purely a throughput knob: proposals are bit-identical for any
        // thread count (DESIGN.md §11), so this never changes results.
        let threads: usize = raw
            .parse()
            .context("--scoring-threads must be a thread count")?;
        exec_cfg.hpo.candidates.scoring_threads = threads.max(1);
    }
    if let Some(raw) = args.get("max-exact-n") {
        // Surrogate scaling budget (DESIGN.md §14): largest training set
        // the exact O(n³) surrogate serves before the study hands off to
        // the scaled regime. Overrides the [surrogate] config section.
        let n: usize = raw
            .parse()
            .context("--max-exact-n must be an observation count")?;
        exec_cfg.hpo.scaling.max_exact_n = n.max(1);
    }
    if let Some(raw) = args.get("scaling-mode") {
        exec_cfg.hpo.scaling.mode = match raw.as_str() {
            "subset" => hyppo::optimizer::ScalingMode::Subset,
            "forest" => hyppo::optimizer::ScalingMode::Forest,
            other => bail!(
                "--scaling-mode {other:?} (expected subset|forest)"
            ),
        };
    }
    if let Some(raw) = args.get("adaptive-trials") {
        // Paper's trial-level uncertainty accounting, made adaptive:
        // rerun a θ (extra UQ replicas) while its trained-loss spread
        // exceeds this threshold, up to --max-trials per evaluation.
        let std_threshold: f64 = raw.parse().context(
            "--adaptive-trials needs a trained-loss std-dev threshold",
        )?;
        let n_trials = cfg.hpo.n_trials.max(1);
        let max_trials: usize = match args.get("max-trials") {
            Some(v) => v.parse().context("--max-trials must be a count")?,
            None => 2 * n_trials,
        };
        if max_trials < n_trials {
            bail!(
                "--max-trials {max_trials} is below n_trials {n_trials}; \
                 the cap must allow at least the base trial set"
            );
        }
        exec_cfg.hpo.adaptive_trials =
            Some(AdaptiveTrials { std_threshold, max_trials });
    }

    let out: ExecOutcome = match resume_path {
        Some(path) => {
            let ckpt = Checkpoint::load(path)?;
            println!(
                "resuming from {path}: {} recorded, {} in flight",
                ckpt.history.len(),
                ckpt.in_flight.len()
            );
            resume_experiment(evaluator.as_ref(), &exec_cfg, ckpt)?
        }
        None => run_experiment(evaluator.as_ref(), &exec_cfg)?,
    };

    summarize(&out.history, evaluator.space(), cfg.hpo.gamma);
    let s = &out.stats;
    println!(
        "refits: {} incremental / {} full   checkpoints: {}   {}",
        s.refits.incremental,
        s.refits.full,
        s.checkpoints_written,
        if out.complete {
            "status: complete"
        } else {
            "status: partial (resume with --resume)"
        },
    );
    if s.refits.exhausted_candidate_sets > 0 {
        // Aggregated once here instead of a stderr line per proposal.
        println!(
            "note: {} candidate set(s) came back short (search space \
             small or nearly exhausted)",
            s.refits.exhausted_candidate_sets
        );
    }
    if s.refits.handoffs > 0 || s.refits.evicted > 0 {
        println!(
            "scaling: {} handoff(s), {} scaled proposal(s), {} evicted \
             observation(s) (exact budget {})",
            s.refits.handoffs,
            s.refits.scaled_fits,
            s.refits.evicted,
            exec_cfg.hpo.scaling.max_exact_n,
        );
    }
    println!(
        "refit workspace growth: {} bytes (flat after warm-up = pooled)",
        s.refits.refit_alloc_bytes
    );
    if let Some(out_path) = args.get("out") {
        write_history_csv(&out.history, cfg.hpo.gamma, out_path)?;
        println!("history -> {out_path}");
    }
    Ok(())
}

/// Parse `--seeds 0,1,2` (default: the config seed).
fn parse_seeds(args: &Args, default: u64) -> Result<Vec<u64>> {
    match args.get("seeds") {
        None => Ok(vec![default]),
        Some(s) => s
            .split(',')
            .filter(|t| !t.trim().is_empty())
            .map(|t| {
                t.trim()
                    .parse::<u64>()
                    .with_context(|| format!("bad seed {t:?}"))
            })
            .collect(),
    }
}

/// Parse `--topologies 1x1,4x2` (default: the config topology).
fn parse_topologies(args: &Args, default: Topology) -> Result<Vec<Topology>> {
    match args.get("topologies") {
        None => Ok(vec![default]),
        Some(s) => s
            .split(',')
            .filter(|t| !t.trim().is_empty())
            .map(|t| {
                let (a, b) = t
                    .trim()
                    .split_once('x')
                    .with_context(|| format!("bad topology {t:?} (SxT)"))?;
                let steps: usize = a
                    .parse()
                    .with_context(|| format!("bad steps in {t:?}"))?;
                let tasks: usize = b
                    .parse()
                    .with_context(|| format!("bad tasks in {t:?}"))?;
                if steps == 0 || tasks == 0 {
                    bail!("bad topology {t:?}: steps and tasks must be > 0");
                }
                Ok(Topology::new(steps, tasks))
            })
            .collect(),
    }
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let cfg_path = args
        .get("config")
        .context("--config <file.toml> is required")?;
    let cfg = hyppo::config::load(std::path::Path::new(cfg_path))?;
    let backend = args.str_or("backend", "synthetic");
    let engine = engine_for(&backend)?;
    let seeds = parse_seeds(args, cfg.hpo.seed)?;
    let topologies = parse_topologies(args, cfg.topology)?;

    let mut base = ExecConfig::new(
        cfg.hpo.clone(),
        cfg.topology,
        cfg.mode,
        args.f64_or("time-scale", default_time_scale(&backend)),
    );
    if let Some(raw) = args.get("scoring-threads") {
        let threads: usize = raw
            .parse()
            .context("--scoring-threads must be a thread count")?;
        base.hpo.candidates.scoring_threads = threads.max(1);
    }
    let cells = run_sweep(
        |seed| make_evaluator(&backend, &cfg, engine.as_ref(), seed),
        &base,
        &seeds,
        &topologies,
    )?;

    let rows: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            vec![
                c.seed.to_string(),
                format!(
                    "{}x{}",
                    c.topology.steps, c.topology.tasks_per_step
                ),
                c.evaluations.to_string(),
                format!("{:.4e}", c.best_objective),
                hyppo::space::format_values(&c.best_theta),
                format!("{:.2}s", c.wall.as_secs_f64()),
                format!(
                    "{}/{}",
                    c.stats.refits.incremental, c.stats.refits.full
                ),
            ]
        })
        .collect();
    print_table(
        &format!(
            "sweep: {} seeds × {} topologies ({} cells)",
            seeds.len(),
            topologies.len(),
            cells.len()
        ),
        &[
            "seed", "topology", "evals", "best", "theta", "wall",
            "incr/full",
        ],
        &rows,
    );
    if let Some(best) = cells.iter().min_by(|a, b| {
        a.best_objective.total_cmp(&b.best_objective)
    }) {
        println!(
            "best cell: seed {} topology {}x{} objective {:.6e}",
            best.seed,
            best.topology.steps,
            best.topology.tasks_per_step,
            best.best_objective
        );
    }
    if let Some(out_path) = args.get("out") {
        write_sweep_csv(&cells, out_path)?;
        println!("sweep -> {out_path}");
    }
    Ok(())
}

fn cmd_slurm(args: &Args) -> Result<()> {
    let cfg = SlurmJobConfig {
        topology: Topology::new(
            args.usize_or("steps", 2),
            args.usize_or("tasks", 3),
        ),
        use_gpu: !args.flag("cpu"),
        ..Default::default()
    };
    print!("{}", render(&cfg));
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = artifact_dir()
        .context("artifacts not found; run `make artifacts`")?;
    let manifest = hyppo::runtime::Manifest::load(&dir)?;
    let family = args.get("family");
    let mut rows = Vec::new();
    for a in manifest.iter() {
        if family.map(|f| f != a.family).unwrap_or(false) {
            continue;
        }
        rows.push(vec![
            a.family.clone(),
            a.arch.clone(),
            a.role.clone(),
            a.n_param_arrays.to_string(),
            a.inputs.len().to_string(),
        ]);
    }
    print_table(
        &format!("artifacts in {}", dir.display()),
        &["family", "arch", "role", "param_arrays", "inputs"],
        &rows,
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg_path = args
        .get("config")
        .context("--config <file.toml> is required")?;
    let doc = hyppo::config::load_doc(std::path::Path::new(cfg_path))?;
    let cfg = hyppo::config::build(&doc)?;
    let evaluator =
        SyntheticEvaluator::new(cfg.space.clone(), cfg.hpo.seed);

    let topology = Topology::new(
        args.usize_or("steps", cfg.topology.steps),
        args.usize_or("tasks", cfg.topology.tasks_per_step),
    );
    let mut sim = SimConfig::trial_parallel(topology);
    sim.mode = cfg.mode;
    if let Some(sec) = doc.get("sim") {
        if let Some(v) = sec.get("data_efficiency").and_then(|v| v.as_f64())
        {
            sim.data_efficiency = v;
        }
        if let Some(v) =
            sec.get("sync_overhead_ms").and_then(|v| v.as_f64())
        {
            sim.sync_overhead = std::time::Duration::from_secs_f64(
                (v / 1e3).max(0.0),
            );
        }
    }

    // Fault plan: --faults <file> wins, then the run config's own
    // [faults] section, then fault-free.
    let plan = match args.get("faults") {
        Some(path) => {
            let fdoc =
                hyppo::config::load_doc(std::path::Path::new(path))?;
            let sec = fdoc.get("faults").with_context(|| {
                format!("{path} has no [faults] section")
            })?;
            FaultPlan::from_section(sec)?
        }
        None => match doc.get("faults") {
            Some(sec) => FaultPlan::from_section(sec)?,
            None => FaultPlan::default(),
        },
    };

    let mut chaos = ChaosConfig::fault_free(sim);
    chaos.plan = plan;
    chaos.max_retries = args.usize_or(
        "max-retries",
        doc.get("sim")
            .and_then(|s| s.get("max_retries"))
            .and_then(|v| v.as_i64())
            .map(|v| v.max(0) as usize)
            .unwrap_or(hyppo::exec::DEFAULT_MAX_RETRIES),
    );

    let r = simulate_chaos(&evaluator, &cfg.hpo, &chaos)?;
    summarize(&r.history, evaluator.space(), cfg.hpo.gamma);
    let m = &r.metrics;
    println!(
        "makespan: {:?}   utilization: {:.3}   wasted-work fraction: {:.3}",
        m.makespan, m.utilization, m.wasted_work_fraction
    );
    println!(
        "faults: {} crash(es), {} preemption(s), {} lost result(s), \
         {} duplicate(s) rejected, {} restart(s)",
        m.crashes,
        m.preemptions,
        m.lost_results,
        m.duplicates_rejected,
        m.restarts
    );
    println!(
        "recovery: {} requeue(s), {} straggled eval(s), \
         max queue depth {}",
        m.requeues, m.straggled_evals, m.max_queue_depth
    );
    if let Some(json) = args.get("json") {
        let mut run = hyppo::util::bench::BenchRun::to_path(
            "simulate",
            Some(json),
        );
        m.record_into(&mut run);
        run.finish()?;
        println!("metrics -> {json}");
    }
    Ok(())
}

fn cmd_speedup(args: &Args) -> Result<()> {
    let steps = args.usize_or("steps", 16);
    let tasks = args.usize_or("tasks", 6);
    let n_evals = args.usize_or("evals", 50);
    let n_trials = args.usize_or("trials", 5);

    // Heterogeneous workload from the synthetic trainer's cost model.
    let space = hyppo::space::Space::new(vec![
        hyppo::space::ParamSpec::new("a", 0, 20),
        hyppo::space::ParamSpec::new("b", 0, 20),
    ]);
    let ev = SyntheticEvaluator::new(space.clone(), 1);
    let mut rng = hyppo::sampling::Rng::new(1);
    let evals: Vec<EvalCost> = (0..n_evals)
        .map(|_| {
            let theta = space.random_point(&mut rng);
            EvalCost {
                trial_costs: (0..n_trials)
                    .map(|t| ev.run_trial(&theta, t, 0).cost)
                    .collect(),
            }
        })
        .collect();
    let cfg = SimConfig::trial_parallel(Topology::new(steps, tasks));
    let r = simulate(&evals, &cfg);
    println!(
        "topology {steps}x{tasks} ({} processors): makespan {:?}, speedup vs 1x1 = {:.1}x",
        steps * tasks,
        r.makespan,
        speedup(&evals, &cfg)
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg_path = args
        .get("config")
        .context("--config <serve.toml> is required")?;
    let doc = hyppo::config::load_doc(std::path::Path::new(cfg_path))?;
    let mut cfg = ServeConfig::from_doc(&doc)?;
    if let Some(n) = args.get("shards") {
        cfg.n_shards = n.parse().context("--shards: expected integer")?;
        if cfg.n_shards == 0 {
            bail!("--shards must be >= 1");
        }
    }
    if let Some(dir) = args.get("wal") {
        cfg.wal_dir = Some(dir.into());
    }
    if let Some(policy) = args.get("wal-failure") {
        cfg.wal_failure = hyppo::serve::WalFailure::from_str(policy)
            .context("--wal-failure")?;
    }
    if let Some(dir) = args.get("wal-failover") {
        cfg.wal_failover_dir = Some(dir.into());
    }
    if cfg.wal_failure == hyppo::serve::WalFailure::Failover
        && cfg.wal_failover_dir.is_none()
    {
        bail!("--wal-failure failover requires --wal-failover DIR");
    }
    let studies = ServeConfig::studies_from_doc(&doc)?;
    let clock = SystemClock::shared();
    let mut service = Service::open(cfg.clone(), clock)?;
    for (name, path) in &studies {
        let text = std::fs::read_to_string(path).with_context(|| {
            format!("reading study config {path} for {name:?}")
        })?;
        let resp = service.handle(&Request::CreateStudy {
            study: name.clone(),
            config_toml: text,
        });
        match resp {
            Response::Created { .. } => println!("study {name}: created"),
            Response::Error {
                code: ErrorCode::DuplicateStudy, ..
            } => println!("study {name}: recovered from WAL"),
            Response::Error { code, message } => bail!(
                "creating study {name:?} failed: {}: {message}",
                code.as_str()
            ),
            other => bail!("unexpected create reply: {other:?}"),
        }
    }
    let listen = args.str_or("listen", "127.0.0.1:7077");
    // Quarter-lease ticks keep expiry resolution well under the lease.
    let tick_ms = (cfg.lease_ms / 4).max(1);
    let pool = Arc::new(ShardPool::new(service, tick_ms));
    let listener = std::net::TcpListener::bind(&listen)
        .with_context(|| format!("binding {listen}"))?;
    println!(
        "hyppo serve: {} shard(s), {} stud(ies), listening on {listen} \
         [{PROTO_VERSION}]",
        pool.n_shards(),
        studies.len(),
    );
    serve_listener(listener, pool)
}

fn cmd_worker(args: &Args) -> Result<()> {
    let addr = args.str_or("connect", "127.0.0.1:7077");
    let worker = args.str_or("worker-id", "w0");
    let mut policy = hyppo::serve::RetryPolicy::default();
    if let Some(n) = args.get("retries") {
        policy.max_attempts = n
            .parse::<u32>()
            .context("--retries: expected integer")?
            .max(1);
    }
    if let Some(ms) = args.get("retry-backoff-ms") {
        policy.backoff_base_ms = ms
            .parse::<u64>()
            .context("--retry-backoff-ms: expected integer")?
            .max(1);
    }
    // Resends are idempotent: each request carries a sequence number
    // and the service answers replays from its dedup window.
    let mut client = hyppo::serve::RetryClient::tcp(addr, policy);
    let studies: Vec<String> = match args.get("studies") {
        Some(list) => list
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
        None => {
            match hyppo::serve::Client::call(
                &mut client,
                &Request::ListStudies,
            )? {
                Response::Studies { studies } => studies,
                other => bail!("unexpected list reply: {other:?}"),
            }
        }
    };
    if studies.is_empty() {
        bail!(
            "no studies to drive; pass --studies or add [studies] to \
             the serve config"
        );
    }
    println!("worker {worker}: driving {}", studies.join(", "));
    let report = worker_loop(&mut client, &worker, &studies)?;
    println!(
        "worker {}: {} evaluations leased, {} outcomes delivered, \
         {} studies completed",
        report.worker,
        report.asks,
        report.tells,
        report.studies_done.len()
    );
    Ok(())
}
