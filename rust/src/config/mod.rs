//! Configuration system: a minimal TOML-subset parser + typed HPO run
//! configuration, so experiments are driven by declarative files the way
//! the paper's input configuration file drives HYPPO.
//!
//! Supported grammar: `[section]` headers, `key = value` with string,
//! integer, float, boolean, homogeneous inline arrays, and inline tables
//! (`{ k = v, ... }`, used by the typed `[space]` grammar) — the subset
//! our configs need (no serde offline). Comment stripping and
//! array/table splitting are quote-aware: `#` and `,` inside string
//! literals are data, not syntax. Strings are basic double-quoted
//! literals without escape sequences. A value whose brackets are still
//! open at end of line continues on the next line, so arrays of inline
//! tables (the `[faults]` event grammar) can be written one entry per
//! line like real TOML.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::cluster::{ParallelMode, Topology};
use crate::optimizer::candidates::CandidateConfig;
use crate::optimizer::{
    HpoConfig, InitDesign, ScalingConfig, ScalingMode, SurrogateKind,
};
use crate::space::{ParamSpec, Space};
use crate::uq::UqWeights;

/// A parsed config value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
    Table(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(v) => Some(*v),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_table(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Table(t) => Some(t),
            _ => None,
        }
    }
}

/// section -> key -> value.
pub type Doc = BTreeMap<String, BTreeMap<String, Value>>;

/// Strip a trailing `# comment`, ignoring `#` inside string literals
/// (the old `line.split('#')` corrupted quoted values like `"a#b"`).
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Split `inner` on top-level `,` — commas inside string literals or
/// nested `[...]` / `{...}` are data (the old `inner.split(',')`
/// corrupted both).
fn split_top_level(inner: &str) -> Result<Vec<&str>> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in inner.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' | '{' if !in_str => depth += 1,
            ']' | '}' if !in_str => {
                depth = depth
                    .checked_sub(1)
                    .ok_or_else(|| anyhow!("unbalanced brackets"))?;
            }
            ',' if !in_str && depth == 0 => {
                parts.push(&inner[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if in_str {
        bail!("unterminated string literal");
    }
    if depth != 0 {
        bail!("unbalanced brackets");
    }
    parts.push(&inner[start..]);
    Ok(parts)
}

fn parse_value(raw: &str) -> Result<Value> {
    let t = raw.trim();
    if t.starts_with('"') {
        if t.len() < 2 || !t.ends_with('"') || t[1..t.len() - 1].contains('"')
        {
            bail!("bad string literal: {t}");
        }
        return Ok(Value::Str(t[1..t.len() - 1].to_string()));
    }
    if t == "true" {
        return Ok(Value::Bool(true));
    }
    if t == "false" {
        return Ok(Value::Bool(false));
    }
    if t.starts_with('[') && t.ends_with(']') {
        let items: Result<Vec<Value>> = split_top_level(&t[1..t.len() - 1])?
            .into_iter()
            .filter(|s| !s.trim().is_empty())
            .map(parse_value)
            .collect();
        return Ok(Value::Arr(items?));
    }
    if t.starts_with('{') && t.ends_with('}') {
        let mut table = BTreeMap::new();
        for entry in split_top_level(&t[1..t.len() - 1])? {
            if entry.trim().is_empty() {
                continue;
            }
            let (k, v) = entry.split_once('=').ok_or_else(|| {
                anyhow!("inline table entry {entry:?} needs key = value")
            })?;
            table.insert(k.trim().to_string(), parse_value(v)?);
        }
        return Ok(Value::Table(table));
    }
    if let Ok(i) = t.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = t.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("unparseable value: {t:?}")
}

/// Net bracket depth of `line` starting from `depth`, ignoring brackets
/// inside string literals. Errors on a close without an open or on a
/// string literal left open at end of line (strings don't span lines).
fn open_depth(line: &str, depth: usize) -> Result<usize> {
    let mut depth = depth;
    let mut in_str = false;
    for c in line.chars() {
        match c {
            '"' => in_str = !in_str,
            '[' | '{' if !in_str => depth += 1,
            ']' | '}' if !in_str => {
                depth = depth
                    .checked_sub(1)
                    .ok_or_else(|| anyhow!("unbalanced brackets"))?;
            }
            _ => {}
        }
    }
    if in_str {
        bail!("unterminated string literal");
    }
    Ok(depth)
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Doc> {
    let mut doc: Doc = BTreeMap::new();
    let mut section = String::new();
    let mut lines = text.lines().enumerate();
    while let Some((lineno, raw)) = lines.next() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') && line.ends_with(']') {
            section = line[1..line.len() - 1].trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        // A value whose brackets stay open continues on following lines
        // (arrays of inline tables written one entry per line).
        let mut value_src = v.trim().to_string();
        let mut depth = open_depth(&value_src, 0)
            .with_context(|| format!("line {}", lineno + 1))?;
        while depth > 0 {
            let Some((contno, cont_raw)) = lines.next() else {
                bail!("line {}: value is missing a closing bracket", lineno + 1);
            };
            let cont = strip_comment(cont_raw).trim();
            value_src.push(' ');
            value_src.push_str(cont);
            depth = open_depth(cont, depth)
                .with_context(|| format!("line {}", contno + 1))?;
        }
        let value = parse_value(&value_src)
            .with_context(|| format!("line {}", lineno + 1))?;
        doc.entry(section.clone())
            .or_default()
            .insert(k.trim().to_string(), value);
    }
    Ok(doc)
}

/// A full experiment configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub space: Space,
    pub hpo: HpoConfig,
    pub topology: Topology,
    pub mode: ParallelMode,
}

/// Build one typed [`ParamSpec`] from its `[space]` entry.
///
/// Two syntaxes coexist:
///
/// * `name = [lo, hi]` — v1 sugar for an integer range (both bounds
///   must be integers).
/// * `name = { kind = "...", ... }` — the typed grammar:
///   - `{ kind = "int", lo = 1, hi = 8 }`
///   - `{ kind = "continuous", lo = 0.0, hi = 0.5 }`
///   - `{ kind = "continuous", lo = 1e-5, hi = 1e-1, log = true }`
///   - `{ kind = "categorical", choices = ["sgd", "adam"] }`
///   - `{ kind = "ordinal", levels = [16, 32, 64, 128] }`
fn build_param(name: &str, v: &Value) -> Result<ParamSpec> {
    if let Some(arr) = v.as_arr() {
        if arr.len() != 2 {
            bail!("space.{name}: [lo, hi] needs exactly two entries");
        }
        let lo = arr[0]
            .as_i64()
            .with_context(|| format!("space.{name}: lo must be an int"))?;
        let hi = arr[1]
            .as_i64()
            .with_context(|| format!("space.{name}: hi must be an int"))?;
        if lo > hi {
            bail!("space.{name}: empty range [{lo}, {hi}]");
        }
        return Ok(ParamSpec::int(name, lo, hi));
    }
    let table = v.as_table().ok_or_else(|| {
        anyhow!(
            "space.{name} must be [lo, hi] (int sugar) or a \
             {{ kind = \"...\", ... }} table"
        )
    })?;
    let kind = table
        .get("kind")
        .and_then(Value::as_str)
        .ok_or_else(|| anyhow!("space.{name}: missing kind"))?;
    let getf = |k: &str| -> Result<f64> {
        table.get(k).and_then(Value::as_f64).ok_or_else(|| {
            anyhow!("space.{name}: {kind} needs a numeric {k}")
        })
    };
    match kind {
        "int" => {
            // Like the [lo, hi] sugar, bounds must be genuine integers
            // (silently truncating 1.9 → 1 would mask config typos).
            let geti = |k: &str| -> Result<i64> {
                table.get(k).and_then(Value::as_i64).ok_or_else(|| {
                    anyhow!("space.{name}: int needs an integer {k}")
                })
            };
            let (lo, hi) = (geti("lo")?, geti("hi")?);
            if lo > hi {
                bail!("space.{name}: empty range [{lo}, {hi}]");
            }
            Ok(ParamSpec::int(name, lo, hi))
        }
        "continuous" | "float" => {
            let (lo, hi) = (getf("lo")?, getf("hi")?);
            let log = table
                .get("log")
                .map(|b| {
                    b.as_bool().ok_or_else(|| {
                        anyhow!("space.{name}: log must be a bool")
                    })
                })
                .transpose()?
                .unwrap_or(false);
            // Finiteness first: NaN bounds would slip through a plain
            // `lo > hi` comparison and panic in the ParamSpec asserts.
            if !lo.is_finite() || !hi.is_finite() || lo > hi {
                bail!("space.{name}: bad range [{lo}, {hi}]");
            }
            if log {
                if lo <= 0.0 {
                    bail!("space.{name}: log scale needs lo > 0, got {lo}");
                }
                Ok(ParamSpec::log_continuous(name, lo, hi))
            } else {
                Ok(ParamSpec::continuous(name, lo, hi))
            }
        }
        "categorical" => {
            let choices: Vec<&str> = table
                .get("choices")
                .and_then(Value::as_arr)
                .ok_or_else(|| {
                    anyhow!("space.{name}: categorical needs choices = [..]")
                })?
                .iter()
                .map(|c| {
                    c.as_str().ok_or_else(|| {
                        anyhow!("space.{name}: choices must be strings")
                    })
                })
                .collect::<Result<_>>()?;
            if choices.is_empty() {
                bail!("space.{name}: choices must be non-empty");
            }
            let mut dedup = choices.clone();
            dedup.sort_unstable();
            dedup.dedup();
            if dedup.len() != choices.len() {
                bail!("space.{name}: duplicate choices");
            }
            Ok(ParamSpec::categorical(name, &choices))
        }
        "ordinal" => {
            let levels: Vec<f64> = table
                .get("levels")
                .and_then(Value::as_arr)
                .ok_or_else(|| {
                    anyhow!("space.{name}: ordinal needs levels = [..]")
                })?
                .iter()
                .map(|c| {
                    c.as_f64().ok_or_else(|| {
                        anyhow!("space.{name}: levels must be numeric")
                    })
                })
                .collect::<Result<_>>()?;
            if levels.is_empty()
                || levels.iter().any(|l| !l.is_finite())
                || levels.windows(2).any(|w| w[0] >= w[1])
            {
                bail!(
                    "space.{name}: levels must be non-empty, finite, and \
                     strictly increasing"
                );
            }
            Ok(ParamSpec::ordinal(name, &levels))
        }
        other => bail!(
            "space.{name}: unknown kind {other:?} \
             (int | continuous | categorical | ordinal)"
        ),
    }
}

/// Build a `RunConfig` from a parsed document. Layout:
///
/// ```toml
/// [hpo]
/// max_evaluations = 50
/// n_init = 10
/// n_trials = 3
/// surrogate = "rbf"        # rbf | gp | ensemble
/// alpha = 1.0              # ensemble only
/// gamma = 0.0
/// seed = 0
/// init_design = "random"   # random | lhs | halton
/// w_trained = 0.5
/// n_candidates = 200       # candidate-set size per proposal
/// scoring_threads = 1      # parallel proposal scoring (bit-identical)
///
/// [surrogate]
/// max_exact_n = 1024       # exact-surrogate observation budget
/// scaling = "subset"       # subset | forest (regime past the budget)
/// max_history = 8192       # surrogate mirror cap (clamped ≥ max_exact_n)
///
/// [cluster]
/// steps = 4
/// tasks_per_step = 2
/// mode = "trial"           # trial | data
///
/// [space]
/// layers = [1, 3]                                    # v1 Int sugar
/// lr = { kind = "continuous", lo = 1e-5, hi = 1e-1, log = true }
/// optimizer = { kind = "categorical", choices = ["sgd", "adam"] }
/// batch = { kind = "ordinal", levels = [16, 32, 64] }
/// ```
pub fn build(doc: &Doc) -> Result<RunConfig> {
    let space_sec = doc
        .get("space")
        .ok_or_else(|| anyhow!("missing [space] section"))?;
    let mut params = Vec::new();
    for (name, v) in space_sec {
        params.push(build_param(name, v)?);
    }
    if params.is_empty() {
        bail!("[space] section defines no parameters");
    }
    let space = Space::new(params);

    let empty = BTreeMap::new();
    let h = doc.get("hpo").unwrap_or(&empty);
    let geti = |k: &str, d: i64| {
        h.get(k).and_then(Value::as_i64).unwrap_or(d)
    };
    let getf = |k: &str, d: f64| {
        h.get(k).and_then(Value::as_f64).unwrap_or(d)
    };
    let surrogate = match h
        .get("surrogate")
        .and_then(Value::as_str)
        .unwrap_or("rbf")
    {
        "rbf" => SurrogateKind::Rbf,
        "gp" => SurrogateKind::Gp,
        "ensemble" => SurrogateKind::RbfEnsemble {
            alpha: getf("alpha", 1.0),
            members: geti("members", 8) as usize,
        },
        other => bail!("unknown surrogate {other:?}"),
    };
    let init_design = match h
        .get("init_design")
        .and_then(Value::as_str)
        .unwrap_or("random")
    {
        "random" => InitDesign::Random,
        "lhs" => InitDesign::Lhs,
        "halton" => InitDesign::Halton,
        other => bail!("unknown init_design {other:?}"),
    };
    let w_trained = getf("w_trained", 0.5);
    let cand_defaults = CandidateConfig::default();
    let hpo = HpoConfig {
        max_evaluations: geti("max_evaluations", 50) as usize,
        n_init: geti("n_init", 10) as usize,
        n_trials: geti("n_trials", 3) as usize,
        weights: UqWeights::new(w_trained, 1.0 - w_trained),
        surrogate,
        gamma: getf("gamma", 0.0),
        seed: geti("seed", 0) as u64,
        init_design,
        candidates: CandidateConfig {
            n_candidates: geti(
                "n_candidates",
                cand_defaults.n_candidates as i64,
            )
            .max(1) as usize,
            scoring_threads: geti("scoring_threads", 1).max(1) as usize,
            ..cand_defaults
        },
        ..Default::default()
    };

    // [surrogate]: observation budgets for the scaling policy
    // (DESIGN.md §14). Absent section ⇒ defaults (exact path for every
    // paper-scale study).
    let s = doc.get("surrogate").unwrap_or(&empty);
    let scaling_defaults = ScalingConfig::default();
    let mode = match s
        .get("scaling")
        .and_then(Value::as_str)
        .unwrap_or("subset")
    {
        "subset" => ScalingMode::Subset,
        "forest" => ScalingMode::Forest,
        other => bail!("unknown surrogate scaling mode {other:?}"),
    };
    let hpo = HpoConfig {
        scaling: ScalingConfig {
            max_exact_n: s
                .get("max_exact_n")
                .and_then(Value::as_i64)
                .unwrap_or(scaling_defaults.max_exact_n as i64)
                .max(1) as usize,
            mode,
            max_history: s
                .get("max_history")
                .and_then(Value::as_i64)
                .unwrap_or(scaling_defaults.max_history as i64)
                .max(1) as usize,
        },
        ..hpo
    };

    let c = doc.get("cluster").unwrap_or(&empty);
    let steps = c.get("steps").and_then(Value::as_i64).unwrap_or(1) as usize;
    let tasks = c
        .get("tasks_per_step")
        .and_then(Value::as_i64)
        .unwrap_or(1) as usize;
    let mode = match c.get("mode").and_then(Value::as_str).unwrap_or("trial")
    {
        "trial" => ParallelMode::TrialParallel,
        "data" => ParallelMode::DataParallel,
        other => bail!("unknown cluster mode {other:?}"),
    };

    Ok(RunConfig {
        space,
        hpo,
        topology: Topology::new(steps.max(1), tasks.max(1)),
        mode,
    })
}

/// Parse a file into a raw [`Doc`] — for callers that read extra
/// sections (e.g. `[faults]`, `[sim]`) beyond what [`build`] consumes.
pub fn load_doc(path: &std::path::Path) -> Result<Doc> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse(&text).with_context(|| format!("parsing {}", path.display()))
}

/// Parse + build from a file path.
pub fn load(path: &std::path::Path) -> Result<RunConfig> {
    build(&load_doc(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
[hpo]
max_evaluations = 30
n_trials = 5
surrogate = "ensemble"
alpha = -1.5
seed = 42
init_design = "lhs"
w_trained = 0.3
n_candidates = 120
scoring_threads = 4

[cluster]
steps = 4
tasks_per_step = 2
mode = "data"

[space]
layers = [1, 3]
width_idx = [0, 2]
"#;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(SAMPLE).unwrap();
        assert_eq!(
            doc["hpo"]["max_evaluations"],
            Value::Int(30)
        );
        assert_eq!(doc["hpo"]["alpha"], Value::Float(-1.5));
        assert_eq!(
            doc["hpo"]["surrogate"],
            Value::Str("ensemble".into())
        );
    }

    #[test]
    fn builds_full_config() {
        let cfg = build(&parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(cfg.space.dim(), 2);
        assert_eq!(cfg.hpo.max_evaluations, 30);
        assert_eq!(cfg.hpo.n_trials, 5);
        assert_eq!(
            cfg.hpo.surrogate,
            SurrogateKind::RbfEnsemble { alpha: -1.5, members: 8 }
        );
        assert_eq!(cfg.topology, Topology::new(4, 2));
        assert_eq!(cfg.mode, ParallelMode::DataParallel);
        assert!((cfg.hpo.weights.w_trained - 0.3).abs() < 1e-12);
        assert_eq!(cfg.hpo.candidates.n_candidates, 120);
        assert_eq!(cfg.hpo.candidates.scoring_threads, 4);
    }

    #[test]
    fn candidate_knobs_default_and_clamp() {
        let minimal = "[space]\na = [0, 3]\n";
        let cfg = build(&parse(minimal).unwrap()).unwrap();
        assert_eq!(cfg.hpo.candidates.n_candidates, 200);
        assert_eq!(cfg.hpo.candidates.scoring_threads, 1);
        // Zero / negative thread counts clamp to sequential.
        let zero = "[hpo]\nscoring_threads = 0\n[space]\na = [0, 3]\n";
        let cfg = build(&parse(zero).unwrap()).unwrap();
        assert_eq!(cfg.hpo.candidates.scoring_threads, 1);
    }

    #[test]
    fn surrogate_scaling_section_parses_and_defaults() {
        // Absent section: inert defaults (exact path).
        let minimal = "[space]\na = [0, 3]\n";
        let cfg = build(&parse(minimal).unwrap()).unwrap();
        assert_eq!(cfg.hpo.scaling, ScalingConfig::default());
        // Explicit budgets.
        let tuned = "[surrogate]\n\
                     max_exact_n = 64\n\
                     scaling = \"forest\"\n\
                     max_history = 256\n\
                     [space]\na = [0, 3]\n";
        let cfg = build(&parse(tuned).unwrap()).unwrap();
        assert_eq!(cfg.hpo.scaling.max_exact_n, 64);
        assert_eq!(cfg.hpo.scaling.mode, ScalingMode::Forest);
        assert_eq!(cfg.hpo.scaling.max_history, 256);
        // Unknown mode is an error, zero budgets clamp to 1.
        let bad = "[surrogate]\nscaling = \"magic\"\n[space]\na = [0, 3]\n";
        assert!(build(&parse(bad).unwrap()).is_err());
        let zero = "[surrogate]\nmax_exact_n = 0\n[space]\na = [0, 3]\n";
        let cfg = build(&parse(zero).unwrap()).unwrap();
        assert_eq!(cfg.hpo.scaling.max_exact_n, 1);
    }

    #[test]
    fn rejects_bad_surrogate_and_space() {
        let bad = SAMPLE.replace("\"ensemble\"", "\"magic\"");
        assert!(build(&parse(&bad).unwrap()).is_err());
        let no_space = "[hpo]\nseed = 1\n";
        assert!(build(&parse(no_space).unwrap()).is_err());
        let empty_space = "[space]\n";
        assert!(build(&parse(empty_space).unwrap()).is_err());
    }

    #[test]
    fn quoted_strings_keep_hash_and_comma() {
        // Regression: comment stripping via split('#') and array
        // splitting via split(',') both corrupted quoted strings.
        let doc = parse(
            "[s]\n\
             tag = \"a#b\"      # real comment\n\
             csv = \"x,y\"\n\
             arr = [\"p,q\", \"r#s\", \"t\"]\n",
        )
        .unwrap();
        assert_eq!(doc["s"]["tag"], Value::Str("a#b".into()));
        assert_eq!(doc["s"]["csv"], Value::Str("x,y".into()));
        assert_eq!(
            doc["s"]["arr"],
            Value::Arr(vec![
                Value::Str("p,q".into()),
                Value::Str("r#s".into()),
                Value::Str("t".into()),
            ])
        );
    }

    #[test]
    fn unterminated_strings_are_errors_not_corruption() {
        assert!(parse("[s]\nx = [\"a,b]\n").is_err());
        assert!(parse_value("\"half").is_err());
        assert!(parse_value("\"a\"b\"").is_err());
    }

    #[test]
    fn inline_tables_parse_with_nesting_and_comments() {
        let doc = parse(
            "[space]\n\
             lr = { kind = \"continuous\", lo = 1e-5, hi = 0.1, \
             log = true }  # log decade sweep\n\
             opt = { kind = \"categorical\", choices = [\"sgd,momentum\", \
             \"adam\"] }\n",
        )
        .unwrap();
        let lr = doc["space"]["lr"].as_table().unwrap();
        assert_eq!(lr["kind"], Value::Str("continuous".into()));
        assert_eq!(lr["log"], Value::Bool(true));
        assert_eq!(lr["lo"], Value::Float(1e-5));
        let opt = doc["space"]["opt"].as_table().unwrap();
        // The comma inside the quoted choice is data.
        assert_eq!(
            opt["choices"],
            Value::Arr(vec![
                Value::Str("sgd,momentum".into()),
                Value::Str("adam".into()),
            ])
        );
    }

    #[test]
    fn typed_space_grammar_builds_mixed_spaces() {
        use crate::space::ParamKind;
        let text = "\
[space]
layers = [1, 8]
lr = { kind = \"continuous\", lo = 1e-5, hi = 1e-1, log = true }
dropout = { kind = \"continuous\", lo = 0.0, hi = 0.5 }
optimizer = { kind = \"categorical\", choices = [\"sgd\", \"adam\", \"rmsprop\"] }
batch = { kind = \"ordinal\", levels = [16, 32, 64, 128] }
";
        let cfg = build(&parse(text).unwrap()).unwrap();
        assert_eq!(cfg.space.dim(), 5);
        // BTreeMap order: batch, dropout, layers, lr, optimizer.
        let kinds: Vec<&ParamKind> =
            cfg.space.params().iter().map(|p| &p.kind).collect();
        assert!(matches!(kinds[0], ParamKind::Ordinal { levels } if levels.len() == 4));
        assert!(matches!(
            kinds[1],
            ParamKind::Continuous { log: false, .. }
        ));
        assert!(matches!(kinds[2], ParamKind::Int { lo: 1, hi: 8 }));
        assert!(matches!(
            kinds[3],
            ParamKind::Continuous { log: true, .. }
        ));
        assert!(matches!(kinds[4], ParamKind::Categorical { choices } if choices.len() == 3));
        // Legacy sugar and the typed kind build the same Int spec.
        let sugar = build_param("layers", &Value::Arr(vec![
            Value::Int(1),
            Value::Int(8),
        ]))
        .unwrap();
        assert_eq!(sugar, crate::space::ParamSpec::int("layers", 1, 8));
    }

    #[test]
    fn typed_space_grammar_rejects_bad_tables() {
        for bad in [
            "[space]\nx = { lo = 1, hi = 2 }\n", // missing kind
            "[space]\nx = { kind = \"warp\", lo = 1, hi = 2 }\n",
            "[space]\nx = { kind = \"continuous\", lo = 0.0, hi = 1.0, \
             log = true }\n", // log needs lo > 0
            "[space]\nx = { kind = \"categorical\", choices = [] }\n",
            "[space]\nx = { kind = \"ordinal\", levels = [3, 2] }\n",
            "[space]\nx = { kind = \"int\", lo = 5, hi = 2 }\n",
            "[space]\nx = { kind = \"int\", lo = 1.9, hi = 8 }\n",
            // Malformed numerics must be clean errors, not panics.
            "[space]\nx = { kind = \"continuous\", lo = nan, hi = 1.0 }\n",
            "[space]\nx = { kind = \"continuous\", lo = 0.0, hi = inf }\n",
            "[space]\nx = { kind = \"categorical\", choices = [\"a\", \"a\"] }\n",
            "[space]\nx = { kind = \"ordinal\", levels = [nan, 1.0] }\n",
            "[space]\nx = [1, 2, 3]\n",
            "[space]\nx = [1.5, 2.5]\n", // float bounds need the table
        ] {
            assert!(
                build(&parse(bad).unwrap()).is_err(),
                "accepted: {bad}"
            );
        }
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        let err = parse("[s]\nkey value\n").unwrap_err();
        assert!(format!("{err:#}").contains("line 2"));
    }

    #[test]
    fn multiline_arrays_of_tables() {
        let doc = parse(
            "[faults]\n\
             events = [   # one entry per line, like real TOML\n\
             { kind = \"crash\", eval = 3, frac = 0.5 },\n\
             { kind = \"straggle\", worker = 1, factor = 2.0 },\n\
             ]\n\
             after = 7\n",
        )
        .unwrap();
        let events = doc["faults"]["events"].as_arr().unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(
            events[0].as_table().unwrap()["kind"],
            Value::Str("crash".into())
        );
        // Parsing resumes normally after the closing bracket.
        assert_eq!(doc["faults"]["after"], Value::Int(7));
        // A never-closed bracket is an error, not a hang.
        assert!(parse("[s]\nx = [1, 2,\n").is_err());
    }

    #[test]
    fn arrays_and_bools() {
        let doc = parse("[a]\nx = [1, 2, 3]\nb = true\n").unwrap();
        assert_eq!(
            doc["a"]["x"],
            Value::Arr(vec![Value::Int(1), Value::Int(2), Value::Int(3)])
        );
        assert_eq!(doc["a"]["b"], Value::Bool(true));
    }
}
