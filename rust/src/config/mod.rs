//! Configuration system: a minimal TOML-subset parser + typed HPO run
//! configuration, so experiments are driven by declarative files the way
//! the paper's input configuration file drives HYPPO.
//!
//! Supported grammar: `[section]` headers, `key = value` with string,
//! integer, float, boolean and homogeneous inline arrays — the subset our
//! configs need (no serde offline).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::cluster::{ParallelMode, Topology};
use crate::optimizer::{HpoConfig, InitDesign, SurrogateKind};
use crate::space::{ParamSpec, Space};
use crate::uq::UqWeights;

/// A parsed config value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(v) => Some(*v),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// section -> key -> value.
pub type Doc = BTreeMap<String, BTreeMap<String, Value>>;

fn parse_value(raw: &str) -> Result<Value> {
    let t = raw.trim();
    if t.starts_with('"') && t.ends_with('"') && t.len() >= 2 {
        return Ok(Value::Str(t[1..t.len() - 1].to_string()));
    }
    if t == "true" {
        return Ok(Value::Bool(true));
    }
    if t == "false" {
        return Ok(Value::Bool(false));
    }
    if t.starts_with('[') && t.ends_with(']') {
        let inner = &t[1..t.len() - 1];
        let items: Result<Vec<Value>> = inner
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(parse_value)
            .collect();
        return Ok(Value::Arr(items?));
    }
    if let Ok(i) = t.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = t.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("unparseable value: {t:?}")
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Doc> {
    let mut doc: Doc = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') && line.ends_with(']') {
            section = line[1..line.len() - 1].trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        let value = parse_value(v)
            .with_context(|| format!("line {}", lineno + 1))?;
        doc.entry(section.clone())
            .or_default()
            .insert(k.trim().to_string(), value);
    }
    Ok(doc)
}

/// A full experiment configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub space: Space,
    pub hpo: HpoConfig,
    pub topology: Topology,
    pub mode: ParallelMode,
}

/// Build a `RunConfig` from a parsed document. Layout:
///
/// ```toml
/// [hpo]
/// max_evaluations = 50
/// n_init = 10
/// n_trials = 3
/// surrogate = "rbf"        # rbf | gp | ensemble
/// alpha = 1.0              # ensemble only
/// gamma = 0.0
/// seed = 0
/// init_design = "random"   # random | lhs | halton
/// w_trained = 0.5
///
/// [cluster]
/// steps = 4
/// tasks_per_step = 2
/// mode = "trial"           # trial | data
///
/// [space]
/// layers = [1, 3]
/// width_idx = [0, 2]
/// ```
pub fn build(doc: &Doc) -> Result<RunConfig> {
    let space_sec = doc
        .get("space")
        .ok_or_else(|| anyhow!("missing [space] section"))?;
    let mut params = Vec::new();
    for (name, v) in space_sec {
        let arr = match v {
            Value::Arr(a) if a.len() == 2 => a,
            _ => bail!("space.{name} must be [lo, hi]"),
        };
        let lo = arr[0].as_i64().context("lo must be int")?;
        let hi = arr[1].as_i64().context("hi must be int")?;
        params.push(ParamSpec::new(name, lo, hi));
    }
    let space = Space::new(params);

    let empty = BTreeMap::new();
    let h = doc.get("hpo").unwrap_or(&empty);
    let geti = |k: &str, d: i64| {
        h.get(k).and_then(Value::as_i64).unwrap_or(d)
    };
    let getf = |k: &str, d: f64| {
        h.get(k).and_then(Value::as_f64).unwrap_or(d)
    };
    let surrogate = match h
        .get("surrogate")
        .and_then(Value::as_str)
        .unwrap_or("rbf")
    {
        "rbf" => SurrogateKind::Rbf,
        "gp" => SurrogateKind::Gp,
        "ensemble" => SurrogateKind::RbfEnsemble {
            alpha: getf("alpha", 1.0),
            members: geti("members", 8) as usize,
        },
        other => bail!("unknown surrogate {other:?}"),
    };
    let init_design = match h
        .get("init_design")
        .and_then(Value::as_str)
        .unwrap_or("random")
    {
        "random" => InitDesign::Random,
        "lhs" => InitDesign::Lhs,
        "halton" => InitDesign::Halton,
        other => bail!("unknown init_design {other:?}"),
    };
    let w_trained = getf("w_trained", 0.5);
    let hpo = HpoConfig {
        max_evaluations: geti("max_evaluations", 50) as usize,
        n_init: geti("n_init", 10) as usize,
        n_trials: geti("n_trials", 3) as usize,
        weights: UqWeights::new(w_trained, 1.0 - w_trained),
        surrogate,
        gamma: getf("gamma", 0.0),
        seed: geti("seed", 0) as u64,
        init_design,
        ..Default::default()
    };

    let c = doc.get("cluster").unwrap_or(&empty);
    let steps = c.get("steps").and_then(Value::as_i64).unwrap_or(1) as usize;
    let tasks = c
        .get("tasks_per_step")
        .and_then(Value::as_i64)
        .unwrap_or(1) as usize;
    let mode = match c.get("mode").and_then(Value::as_str).unwrap_or("trial")
    {
        "trial" => ParallelMode::TrialParallel,
        "data" => ParallelMode::DataParallel,
        other => bail!("unknown cluster mode {other:?}"),
    };

    Ok(RunConfig {
        space,
        hpo,
        topology: Topology::new(steps.max(1), tasks.max(1)),
        mode,
    })
}

/// Parse + build from a file path.
pub fn load(path: &std::path::Path) -> Result<RunConfig> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    build(&parse(&text)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
[hpo]
max_evaluations = 30
n_trials = 5
surrogate = "ensemble"
alpha = -1.5
seed = 42
init_design = "lhs"
w_trained = 0.3

[cluster]
steps = 4
tasks_per_step = 2
mode = "data"

[space]
layers = [1, 3]
width_idx = [0, 2]
"#;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(SAMPLE).unwrap();
        assert_eq!(
            doc["hpo"]["max_evaluations"],
            Value::Int(30)
        );
        assert_eq!(doc["hpo"]["alpha"], Value::Float(-1.5));
        assert_eq!(
            doc["hpo"]["surrogate"],
            Value::Str("ensemble".into())
        );
    }

    #[test]
    fn builds_full_config() {
        let cfg = build(&parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(cfg.space.dim(), 2);
        assert_eq!(cfg.hpo.max_evaluations, 30);
        assert_eq!(cfg.hpo.n_trials, 5);
        assert_eq!(
            cfg.hpo.surrogate,
            SurrogateKind::RbfEnsemble { alpha: -1.5, members: 8 }
        );
        assert_eq!(cfg.topology, Topology::new(4, 2));
        assert_eq!(cfg.mode, ParallelMode::DataParallel);
        assert!((cfg.hpo.weights.w_trained - 0.3).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_surrogate_and_space() {
        let bad = SAMPLE.replace("\"ensemble\"", "\"magic\"");
        assert!(build(&parse(&bad).unwrap()).is_err());
        let no_space = "[hpo]\nseed = 1\n";
        assert!(build(&parse(no_space).unwrap()).is_err());
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        let err = parse("[s]\nkey value\n").unwrap_err();
        assert!(format!("{err:#}").contains("line 2"));
    }

    #[test]
    fn arrays_and_bools() {
        let doc = parse("[a]\nx = [1, 2, 3]\nb = true\n").unwrap();
        assert_eq!(
            doc["a"]["x"],
            Value::Arr(vec![Value::Int(1), Value::Int(2), Value::Int(3)])
        );
        assert_eq!(doc["a"]["b"], Value::Bool(true));
    }
}
