//! Real-training evaluation backend: every trial trains an AOT-compiled
//! MLP through the PJRT runtime (Layers 1+2), with the full SGD loop,
//! MC-dropout passes, and validation driven from Rust. This is the
//! end-to-end path — the same `Evaluator` interface the synthetic backend
//! implements, but with nothing simulated.
//!
//! Hyperparameter space (search-space v2, typed — the v1 lattice forced
//! everything through scaled integers):
//!   layers  ∈ Int [1, 3]                       (artifact grid axis)
//!   width   ∈ Ordinal {16, 32, 64}             (artifact grid axis)
//!   lr      ∈ Continuous [10⁻²·⁹, 10⁻⁰·⁷] log  (was lr_idx ∈ [0, 11])
//!   dropout ∈ Continuous [0.0, 0.4]            (was dropout_idx ∈ [0, 8])
//!   epochs  ∈ Int [1, E_max]                   (runtime loop length)
//!   batch   ∈ Int [4, 32]      (effective rows via the weight vector)

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::eval::{Evaluator, TrialOutcome};
use crate::runtime::{make_batch, Model, SharedEngine};
use crate::sampling::rng::Rng;
use crate::space::{ParamSpec, Space, Value};

pub const WIDTHS: [usize; 3] = [16, 32, 64];
pub const COMPILED_BATCH: usize = 32;

/// The v1 lattice's learning-rate index mapping, kept for manually
/// migrating old integer-encoded configs/results:
/// `lr = 10^(-(0.7 + 0.2·idx))`.
///
/// Note that checkpoints written against the *old all-integer
/// `mlp_space`* are not resumable against the new mixed space — the
/// space definition itself changed, so `Session::restore` rejects them
/// with a clean error. Convert old θ by hand via [`lr_of`] /
/// [`dropout_of`] if an old run must be continued.
pub fn lr_of(idx: i64) -> f32 {
    10f32.powf(-(0.7 + 0.2 * idx as f32))
}

/// The v1 lattice's dropout index mapping (`p = 0.05·idx`), kept for
/// manually migrating old integer-encoded configs/results (see
/// [`lr_of`] for the checkpoint-migration caveat).
pub fn dropout_of(idx: i64) -> f32 {
    0.05 * idx as f32
}

/// The standard MLP search space used by the time-series and polyfit
/// studies (6 hyperparameters, like the Fig. 4 comparison). Since
/// search-space v2 this is a genuinely mixed space: the learning rate is
/// a first-class log-continuous parameter spanning the same decades the
/// v1 `lr_idx` lattice quantized, dropout is continuous, and the width
/// is an ordinal over the compiled artifact grid.
pub fn mlp_space(e_max: i64) -> Space {
    // One source of truth for the width axis: the same WIDTHS table
    // that arch_name/n_params index with the ordinal level index.
    let widths: Vec<f64> = WIDTHS.iter().map(|w| *w as f64).collect();
    Space::new(vec![
        ParamSpec::int("layers", 1, 3),
        ParamSpec::ordinal("width", &widths),
        // Exactly the v1 index range's endpoints, so every lattice
        // point of the old lr_idx encoding is inside the new interval.
        ParamSpec::log_continuous("lr", lr_of(11) as f64, lr_of(0) as f64),
        ParamSpec::continuous("dropout", 0.0, 0.4),
        ParamSpec::int("epochs", 1, e_max),
        ParamSpec::int("batch", 4, 32),
    ])
}

/// Supervised dataset in row-major form.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    pub x: Vec<Vec<f32>>,
    pub y: Vec<Vec<f32>>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.x.len()
    }
    pub fn is_empty(&self) -> bool {
        self.x.is_empty()
    }
}

pub struct MlpHloEvaluator {
    engine: Arc<SharedEngine>,
    space: Space,
    pub train: Dataset,
    pub val: Dataset,
    pub in_dim: usize,
    pub out_dim: usize,
    /// T MC-dropout passes per trained model (paper default 30).
    pub t_dropout: usize,
    /// Cap on gradient steps per epoch (keeps trials bounded on CPU).
    pub max_steps_per_epoch: usize,
    /// Rows of the validation set actually used (first `val_rows`).
    pub val_rows: usize,
}

impl MlpHloEvaluator {
    pub fn new(
        engine: Arc<SharedEngine>,
        train: Dataset,
        val: Dataset,
        in_dim: usize,
        out_dim: usize,
        e_max: i64,
    ) -> Self {
        assert!(!train.is_empty() && !val.is_empty());
        let val_rows = val.len().min(64);
        MlpHloEvaluator {
            engine,
            space: mlp_space(e_max),
            train,
            val,
            in_dim,
            out_dim,
            t_dropout: 10,
            max_steps_per_epoch: 16,
            val_rows,
        }
    }

    pub fn arch_name(&self, theta: &[Value]) -> String {
        format!(
            "mlp_i{}_o{}_l{}_w{}_b{}",
            self.in_dim,
            self.out_dim,
            theta[0].as_i64(),
            WIDTHS[theta[1].as_i64() as usize],
            COMPILED_BATCH
        )
    }

    /// Validation targets flattened in evaluation order.
    fn val_targets(&self) -> Vec<f64> {
        self.val.y[..self.val_rows]
            .iter()
            .flat_map(|r| r.iter().map(|v| *v as f64))
            .collect()
    }

    /// Run the deterministic or dropout forward pass over the validation
    /// rows, returning flattened predictions.
    fn val_predictions(
        &self,
        model: &Model,
        dropout: Option<(f32, i32)>,
    ) -> anyhow::Result<Vec<f64>> {
        let mut preds = Vec::with_capacity(self.val_rows * self.out_dim);
        let mut row = 0;
        while row < self.val_rows {
            let hi = (row + COMPILED_BATCH).min(self.val_rows);
            let n = hi - row;
            let mut x = vec![0.0f32; COMPILED_BATCH * self.in_dim];
            for (i, r) in self.val.x[row..hi].iter().enumerate() {
                x[i * self.in_dim..(i + 1) * self.in_dim]
                    .copy_from_slice(r);
            }
            let out = match dropout {
                None => model.predict(&x)?,
                Some((p, seed)) => {
                    model.predict_dropout(&x, p, seed)?
                }
            };
            preds.extend(
                out[..n * self.out_dim].iter().map(|v| *v as f64),
            );
            row = hi;
        }
        Ok(preds)
    }

    fn mse_vs_targets(&self, preds: &[f64]) -> f64 {
        let targets = self.val_targets();
        assert_eq!(preds.len(), targets.len());
        preds
            .iter()
            .zip(&targets)
            .map(|(p, t)| (p - t) * (p - t))
            .sum::<f64>()
            / preds.len() as f64
    }
}

impl Evaluator for MlpHloEvaluator {
    fn space(&self) -> &Space {
        &self.space
    }

    fn run_trial(
        &self,
        theta: &[Value],
        trial: usize,
        seed: u64,
    ) -> TrialOutcome {
        assert!(self.space.contains(theta), "theta out of space: {theta:?}");
        let start = Instant::now();
        let arch = self.arch_name(theta);
        // Typed access: lr and dropout arrive as real values now — no
        // index decoding in the evaluator (`contains` above guarantees
        // the variants match the space).
        let lr = theta[2].as_f64() as f32;
        let p = theta[3].as_f64() as f32;
        let epochs = theta[4].as_i64() as usize;
        let eff_batch = (theta[5].as_i64() as usize).min(COMPILED_BATCH);

        let mut rng = Rng::new(
            seed ^ (trial as u64).wrapping_mul(0x9E3779B97F4A7C15),
        );
        let init_seed = rng.next_u64() as i32;
        let mut model = Model::init(&self.engine, &arch, init_seed)
            .expect("artifact for arch must exist (run `make artifacts`)");

        // --- inner problem (Eq. 3): SGD over the train split -------------
        let steps = self
            .train
            .len()
            .div_ceil(eff_batch)
            .min(self.max_steps_per_epoch);
        let mut step_seed = rng.next_u64() as i32;
        for _epoch in 0..epochs {
            for _s in 0..steps {
                let idx: Vec<usize> = (0..eff_batch)
                    .map(|_| rng.usize_below(self.train.len()))
                    .collect();
                let xs: Vec<&[f32]> =
                    idx.iter().map(|i| self.train.x[*i].as_slice()).collect();
                let ys: Vec<&[f32]> =
                    idx.iter().map(|i| self.train.y[*i].as_slice()).collect();
                let batch = make_batch(&xs, &ys, COMPILED_BATCH)
                    .expect("batch construction");
                step_seed = step_seed.wrapping_add(1);
                model
                    .train_step(&batch, lr, p, step_seed)
                    .expect("train_step");
            }
        }

        // --- outer loss ℓ₁ sample + T MC-dropout passes -------------------
        let preds = self
            .val_predictions(&model, None)
            .expect("val predict");
        let loss = self.mse_vs_targets(&preds);
        let mc_p = if p > 0.0 { p } else { 0.1 }; // UQ needs dropout active
        let mut dropout_losses = Vec::with_capacity(self.t_dropout);
        let mut dropout_predictions = Vec::with_capacity(self.t_dropout);
        for t in 0..self.t_dropout {
            let dp = self
                .val_predictions(
                    &model,
                    Some((mc_p, rng.next_u64() as i32 ^ t as i32)),
                )
                .expect("dropout predict");
            dropout_losses.push(self.mse_vs_targets(&dp));
            dropout_predictions.push(dp);
        }

        TrialOutcome {
            loss,
            dropout_losses,
            predictions: Some(preds),
            dropout_predictions,
            cost: start.elapsed().max(Duration::from_micros(1)),
        }
    }

    fn n_params(&self, theta: &[Value]) -> u64 {
        // in*w + w + (layers-1)*(w*w + w) + w*out + out
        let w = WIDTHS[theta[1].as_i64() as usize] as u64;
        let l = theta[0].as_i64() as u64;
        let (i, o) = (self.in_dim as u64, self.out_dim as u64);
        i * w + w + (l - 1) * (w * w + w) + w * o + o
    }

    fn loss_of_mean_prediction(
        &self,
        _theta: &[Value],
        mu: &[f64],
    ) -> Option<f64> {
        Some(self.mse_vs_targets(mu))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encodings_monotone() {
        assert!(lr_of(0) > lr_of(11));
        assert_eq!(dropout_of(0), 0.0);
        assert!((dropout_of(8) - 0.4).abs() < 1e-6);
    }

    #[test]
    fn space_has_six_hyperparameters() {
        let s = mlp_space(20);
        assert_eq!(s.dim(), 6);
        let lo = vec![
            Value::Int(1),
            Value::Int(0),
            Value::Float(lr_of(11) as f64),
            Value::Float(0.0),
            Value::Int(1),
            Value::Int(4),
        ];
        let hi = vec![
            Value::Int(3),
            Value::Int(2),
            Value::Float(lr_of(0) as f64),
            Value::Float(0.4),
            Value::Int(20),
            Value::Int(32),
        ];
        assert!(s.contains(&lo), "{lo:?}");
        assert!(s.contains(&hi), "{hi:?}");
        // The v1 lr_idx decades sit strictly inside the continuous range.
        for idx in 1..11 {
            let mut p = lo.clone();
            p[2] = Value::Float(lr_of(idx) as f64);
            assert!(s.contains(&p), "lr_idx {idx}");
        }
    }
}
