//! The polynomial-fit problem of the DeepHyper comparison (paper Fig. 4).
//!
//! DeepHyper's HPS tutorial fits a noisy cubic with a small network; the
//! paper extends it to six hyperparameters (nodes/layer, layers, dropout,
//! learning rate, epochs, batch size) and reports R². We reproduce that
//! problem: data y = x³ − 0.5x + ε on [−1, 1], trained through the AOT MLP
//! family (in_dim = 1), with R² derived from the validation MSE.

use std::sync::Arc;

use crate::eval::hlo::{Dataset, MlpHloEvaluator};
use crate::runtime::SharedEngine;
use crate::sampling::rng::Rng;

/// The ground-truth polynomial.
pub fn poly(x: f64) -> f64 {
    x * x * x - 0.5 * x
}

/// Sample the noisy supervised dataset.
pub fn polyfit_dataset(n: usize, noise: f64, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let xi = -1.0 + 2.0 * rng.f64();
        x.push(vec![xi as f32]);
        y.push(vec![(poly(xi) + noise * rng.normal()) as f32]);
    }
    Dataset { x, y }
}

/// Variance of the validation targets (denominator of R²).
pub fn target_variance(d: &Dataset) -> f64 {
    let ys: Vec<f64> = d.y.iter().map(|r| r[0] as f64).collect();
    let m = ys.iter().sum::<f64>() / ys.len() as f64;
    ys.iter().map(|y| (y - m) * (y - m)).sum::<f64>() / ys.len() as f64
}

/// R² from an MSE given the target variance: R² = 1 − MSE/Var(y).
pub fn r2_from_mse(mse: f64, var_y: f64) -> f64 {
    1.0 - mse / var_y.max(1e-12)
}

/// Build the Fig. 4 problem: the evaluator minimizes validation MSE, the
/// report converts to R² (monotone, so argmin MSE == argmax R²).
pub fn polyfit_problem(
    engine: Arc<SharedEngine>,
    seed: u64,
) -> (MlpHloEvaluator, f64) {
    let train = polyfit_dataset(256, 0.05, seed);
    let val = polyfit_dataset(64, 0.05, seed ^ 0xBADC0FFE);
    let var_y = target_variance(&val);
    let mut ev = MlpHloEvaluator::new(engine, train, val, 1, 1, 20);
    ev.t_dropout = 5; // Fig. 4 compares convergence, not UQ depth
    (ev, var_y)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_matches_polynomial_up_to_noise() {
        let d = polyfit_dataset(500, 0.0, 1);
        for (x, y) in d.x.iter().zip(&d.y) {
            let want = poly(x[0] as f64);
            assert!((y[0] as f64 - want).abs() < 1e-6);
        }
        let noisy = polyfit_dataset(500, 0.1, 1);
        let mean_dev: f64 = noisy
            .x
            .iter()
            .zip(&noisy.y)
            .map(|(x, y)| (y[0] as f64 - poly(x[0] as f64)).abs())
            .sum::<f64>()
            / 500.0;
        assert!(mean_dev > 0.02, "noise must be present");
    }

    #[test]
    fn r2_semantics() {
        let d = polyfit_dataset(200, 0.05, 2);
        let var = target_variance(&d);
        assert!(var > 0.0);
        assert_eq!(r2_from_mse(0.0, var), 1.0);
        assert!(r2_from_mse(var, var).abs() < 1e-12); // predicting mean
        assert!(r2_from_mse(2.0 * var, var) < 0.0); // worse than mean
    }
}
