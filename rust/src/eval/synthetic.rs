//! Calibrated synthetic trainer — the paper-scale sweep backend.
//!
//! The paper's large experiments (825 MLP models for Figs. 2-3, 50x50
//! trials for Figs. 8-9) ran for GPU-days on Cori. This backend replays
//! the *statistical shape* of those sweeps through the very same
//! coordinator code paths: a deterministic multi-modal loss landscape
//! over the integer lattice, trial-to-trial stochastic noise that grows
//! with the loss level (matching Fig. 2's "complex architectures are
//! noisy" structure), MC-dropout pass noise, and a heterogeneous duration
//! model (cost grows with parameter count; Figs. 6/8 rely on uneven
//! evaluation times). Calibration against real HLO-trained models is
//! recorded in EXPERIMENTS.md.

use std::time::Duration;

use crate::eval::{Evaluator, TrialOutcome};
use crate::sampling::rng::Rng;
use crate::space::{ParamKind, Space, Value};

type ParamFn = Box<dyn Fn(&[Value]) -> u64 + Send + Sync>;

pub struct SyntheticEvaluator {
    space: Space,
    pub base_seed: u64,
    /// Relative trial-to-trial noise at loss level L: std = noise * L.
    pub noise: f64,
    /// Extra relative spread of MC-dropout passes around the trial loss.
    pub dropout_noise: f64,
    /// Number of dropout passes reported per trial (paper T, default 30).
    pub t_dropout: usize,
    /// Fixed + per-parameter training cost (virtual).
    pub base_cost: Duration,
    pub ns_per_param: f64,
    /// Best achievable loss and curvature of the landscape.
    pub loss_floor: f64,
    pub curvature: f64,
    n_params_fn: ParamFn,
    optimum: Vec<f64>,
}

impl SyntheticEvaluator {
    /// Landscape with the optimum at a fixed interior lattice point.
    pub fn new(space: Space, base_seed: u64) -> Self {
        let dim = space.dim();
        // A deterministic, seed-dependent interior optimum.
        let mut rng = Rng::new(base_seed ^ 0x5EED);
        let optimum: Vec<f64> =
            (0..dim).map(|_| 0.2 + 0.6 * rng.f64()).collect();
        let space_for_params = space.clone();
        SyntheticEvaluator {
            space,
            base_seed,
            noise: 0.08,
            dropout_noise: 0.05,
            t_dropout: 30,
            base_cost: Duration::from_millis(40),
            ns_per_param: 50.0,
            loss_floor: 0.02,
            curvature: 1.6,
            n_params_fn: Box::new(move |theta| {
                default_n_params(&space_for_params, theta)
            }),
            optimum,
        }
    }

    /// Override the parameter-count model (e.g. the true MLP formula when
    /// emulating the Fig. 2 sweep).
    pub fn with_n_params(mut self, f: ParamFn) -> Self {
        self.n_params_fn = f;
        self
    }

    /// Deterministic noise-free loss at θ — the "true" landscape used by
    /// tests and by convergence-quality assertions.
    pub fn true_loss(&self, theta: &[Value]) -> f64 {
        let u = self.space.to_unit(theta);
        let mut bowl = 0.0;
        let mut ripple = 0.0;
        for (ui, oi) in u.iter().zip(&self.optimum) {
            let d = ui - oi;
            bowl += d * d;
            ripple += (3.0 * std::f64::consts::PI * d).sin().powi(2);
        }
        self.loss_floor
            + self.curvature * bowl
            + 0.05 * ripple / u.len() as f64
    }

    fn theta_hash(&self, theta: &[Value]) -> u64 {
        let mut h = 0xcbf29ce484222325u64 ^ self.base_seed;
        for v in theta {
            // Canonical 64-bit reading per kind. `Int` hashes its raw
            // value — identical to the pre-v2 lattice hash, so all-Int
            // landscapes are bit-compatible.
            h ^= match v {
                Value::Int(v) => *v as u64,
                Value::Float(f) => f.to_bits(),
                Value::Cat(i) => *i as u64,
            };
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }
}

/// Default synthetic parameter count: grows geometrically with each
/// coordinate's offset from its lower end.
fn default_n_params(space: &Space, theta: &[Value]) -> u64 {
    let mut p = 64.0f64;
    for (v, spec) in theta.iter().zip(space.params()) {
        // `Int` keeps the historical (v - lo) / size ratio bit-exactly;
        // the other kinds use the analogous fraction of their domain.
        let rel = match (&spec.kind, v) {
            (ParamKind::Int { lo, hi }, Value::Int(v)) => {
                (v - lo) as f64 / ((hi - lo) as u64 + 1) as f64
            }
            (ParamKind::Ordinal { levels }, Value::Int(i)) => {
                *i as f64 / levels.len() as f64
            }
            (ParamKind::Categorical { choices }, Value::Cat(i)) => {
                *i as f64 / choices.len() as f64
            }
            (ParamKind::Continuous { lo, hi, .. }, Value::Float(f)) => {
                if lo == hi {
                    0.0
                } else {
                    (f - lo) / (hi - lo)
                }
            }
            _ => 0.0,
        };
        p *= 1.0 + 3.0 * rel;
    }
    p as u64
}

impl Evaluator for SyntheticEvaluator {
    fn space(&self) -> &Space {
        &self.space
    }

    fn run_trial(
        &self,
        theta: &[Value],
        trial: usize,
        seed: u64,
    ) -> TrialOutcome {
        assert!(self.space.contains(theta), "theta out of space: {theta:?}");
        let mut rng = Rng::new(
            self.theta_hash(theta)
                ^ (trial as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ seed.wrapping_mul(0x2545F4914F6CDD1D),
        );
        let base = self.true_loss(theta);
        // Trial noise: lognormal-ish multiplicative, scaled by loss level,
        // i.e. poor architectures are also the erratic ones (Fig. 2).
        let level = 1.0 + 4.0 * (base - self.loss_floor);
        let loss =
            (base * (1.0 + self.noise * level * rng.normal())).max(1e-6);
        let dropout_losses: Vec<f64> = (0..self.t_dropout)
            .map(|_| {
                (loss * (1.0 + self.dropout_noise * level * rng.normal()))
                    .max(1e-6)
            })
            .collect();

        // Heterogeneous cost: parameter count plus a per-θ jitter factor.
        let n_params = (self.n_params_fn)(theta) as f64;
        let jitter = 0.75 + 0.5 * ((self.theta_hash(theta) >> 17) % 1000) as f64 / 1000.0;
        let nanos = self.base_cost.as_nanos() as f64
            + self.ns_per_param * n_params * jitter;
        TrialOutcome {
            loss,
            dropout_losses,
            predictions: None,
            dropout_predictions: vec![],
            cost: Duration::from_nanos(nanos as u64),
        }
    }

    fn n_params(&self, theta: &[Value]) -> u64 {
        (self.n_params_fn)(theta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::space::{ints, ParamSpec, Point};
    use crate::util::prop::forall;

    fn space() -> Space {
        Space::new(vec![
            ParamSpec::new("a", 0, 20),
            ParamSpec::new("b", 1, 8),
            ParamSpec::new("c", 0, 11),
        ])
    }

    #[test]
    fn deterministic_per_trial_seed() {
        let ev = SyntheticEvaluator::new(space(), 9);
        let theta = ints(&[3, 4, 5]);
        let a = ev.run_trial(&theta, 0, 1);
        let b = ev.run_trial(&theta, 0, 1);
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.dropout_losses, b.dropout_losses);
        let c = ev.run_trial(&theta, 1, 1);
        assert_ne!(a.loss, c.loss, "different trials must differ");
    }

    #[test]
    fn mixed_typed_space_is_deterministic_and_sane() {
        let sp = Space::new(vec![
            ParamSpec::int("layers", 1, 4),
            ParamSpec::log_continuous("lr", 1e-5, 1e-1),
            ParamSpec::categorical("opt", &["sgd", "adam"]),
            ParamSpec::ordinal("batch", &[16.0, 32.0, 64.0]),
        ]);
        let ev = SyntheticEvaluator::new(sp, 4);
        forall("mixed synthetic", 100, |rng| {
            let theta = ev.space().random_point(rng);
            let a = ev.run_trial(&theta, 0, 7);
            let b = ev.run_trial(&theta, 0, 7);
            prop_assert!(a.loss == b.loss, "nondeterministic");
            prop_assert!(a.loss > 0.0, "loss {}", a.loss);
            prop_assert!(ev.n_params(&theta) >= 64, "n_params");
            Ok(())
        });
    }

    #[test]
    fn losses_positive_and_near_truth() {
        let ev = SyntheticEvaluator::new(space(), 2);
        forall("synthetic losses sane", 100, |rng| {
            let theta = ev.space().random_point(rng);
            let t = ev.true_loss(&theta);
            let o = ev.run_trial(&theta, 0, rng.next_u64());
            prop_assert!(o.loss > 0.0, "loss {}", o.loss);
            prop_assert!(
                (o.loss - t).abs() < t * 3.0 + 0.5,
                "loss {} too far from truth {t}",
                o.loss
            );
            prop_assert!(o.dropout_losses.len() == 30, "T wrong");
            Ok(())
        });
    }

    #[test]
    fn noise_grows_with_loss_level() {
        let ev = SyntheticEvaluator::new(space(), 3);
        // Find a good and a bad point by true loss.
        let mut rng = Rng::new(0);
        let pts: Vec<Point> =
            (0..200).map(|_| ev.space().random_point(&mut rng)).collect();
        let best = pts
            .iter()
            .min_by(|a, b| {
                ev.true_loss(a).total_cmp(&ev.true_loss(b))
            })
            .unwrap();
        let worst = pts
            .iter()
            .max_by(|a, b| {
                ev.true_loss(a).total_cmp(&ev.true_loss(b))
            })
            .unwrap();
        let spread = |theta: &[Value]| {
            let ls: Vec<f64> = (0..40)
                .map(|t| ev.run_trial(theta, t, 7).loss)
                .collect();
            crate::uq::stddev(&ls)
        };
        assert!(
            spread(worst) > spread(best),
            "worse architectures must be noisier (Fig. 2 shape)"
        );
    }

    #[test]
    fn cost_grows_with_param_count() {
        let sp = space();
        let ev = SyntheticEvaluator::new(sp.clone(), 4);
        let small = ev.run_trial(&ints(&[0, 1, 0]), 0, 0).cost;
        let large = ev.run_trial(&ints(&[20, 8, 11]), 0, 0).cost;
        assert!(
            large > small,
            "cost must grow with architecture size ({small:?} vs {large:?})"
        );
    }

    #[test]
    fn custom_n_params_used() {
        let ev = SyntheticEvaluator::new(space(), 5).with_n_params(
            Box::new(|t| (t[1].as_i64() * t[1].as_i64()) as u64 * 100),
        );
        assert_eq!(ev.n_params(&ints(&[0, 4, 0])), 1600);
    }
}
