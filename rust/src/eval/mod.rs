//! Evaluation backends: the expensive stochastic black box of Eq. (3).
//!
//! One *trial* = train one model for hyperparameters θ and report its
//! validation loss plus T MC-dropout losses (and optionally the raw
//! prediction vectors so the coordinator can compute μ_pred / V_model via
//! Eqs. 6-7). The HPO engine and the cluster scheduler only see this
//! trait, so real AOT-compiled training (`hlo`) and the calibrated
//! synthetic landscape (`synthetic`) are interchangeable (DESIGN.md §7).

pub mod hlo;
pub mod polyfit;
pub mod synthetic;

use std::time::Duration;

use crate::space::{Space, Value};
use crate::uq::{loss_interval, LossInterval, PredictionSet, UqWeights};

/// Result of training one model (one trial) at θ.
#[derive(Debug, Clone)]
pub struct TrialOutcome {
    /// Validation loss of the trained model without dropout (one ℓ₁
    /// member sample).
    pub loss: f64,
    /// Validation losses of the T MC-dropout passes.
    pub dropout_losses: Vec<f64>,
    /// Flattened validation predictions (no dropout), if the backend
    /// exposes them.
    pub predictions: Option<Vec<f64>>,
    /// Per-pass dropout predictions.
    pub dropout_predictions: Vec<Vec<f64>>,
    /// Wall-clock the trial consumed (simulated backends report virtual
    /// cost; the cluster's speedup accounting uses this).
    pub cost: Duration,
}

/// The black-box interface (paper Eq. 3). θ is a typed point of the
/// evaluator's [`Space`] (search-space v2): integers, continuous values,
/// categorical choices, and ordinal levels arrive as [`Value`]s in
/// parameter order — no more evaluator-specific integer scaling.
pub trait Evaluator: Send + Sync {
    fn space(&self) -> &Space;

    /// Train the `trial`-th model for θ. `seed` controls all stochasticity
    /// so results are replayable.
    fn run_trial(&self, theta: &[Value], trial: usize, seed: u64)
        -> TrialOutcome;

    /// Number of trainable parameters of the θ architecture (Fig. 2 / 9).
    fn n_params(&self, theta: &[Value]) -> u64;

    /// ℓ₁ evaluated at a mean prediction μ_pred, when the backend can
    /// (requires knowing the validation targets).
    fn loss_of_mean_prediction(
        &self,
        _theta: &[Value],
        _mu: &[f64],
    ) -> Option<f64> {
        None
    }
}

/// A shared reference to an evaluator is itself an evaluator (delegating
/// every method), so `Box<&dyn Evaluator>` coerces to `Box<dyn Evaluator>`
/// and callers that only hold a borrow can feed APIs that want ownership
/// (`exec::Session::new` boxes the borrowed evaluator through exactly
/// this impl; the `serve` shards pass genuinely owned boxes instead).
impl<T: Evaluator + ?Sized> Evaluator for &T {
    fn space(&self) -> &Space {
        (**self).space()
    }
    fn run_trial(&self, theta: &[Value], trial: usize, seed: u64)
        -> TrialOutcome {
        (**self).run_trial(theta, trial, seed)
    }
    fn n_params(&self, theta: &[Value]) -> u64 {
        (**self).n_params(theta)
    }
    fn loss_of_mean_prediction(
        &self,
        theta: &[Value],
        mu: &[f64],
    ) -> Option<f64> {
        (**self).loss_of_mean_prediction(theta, mu)
    }
}

/// Aggregated evaluation of one θ (paper Feature 1): CI over the outer
/// loss plus the variability measures driving Eq. (8)/(9).
#[derive(Debug, Clone)]
pub struct EvalSummary {
    /// CI center: ℓ₁ at μ_pred when predictions are available, otherwise
    /// the (w_T, w_D)-weighted mean of member losses.
    pub interval: LossInterval,
    /// Plain mean/std over the N trained-model losses (Fig. 2's axes).
    pub trained_mean: f64,
    pub trained_std: f64,
    /// Σ_d g(V_model(x^d)) for the Eq. (9) regularizer (0 when the backend
    /// exposes no predictions).
    pub v_model_g: f64,
    /// Total simulated/measured cost of all member computations.
    pub total_cost: Duration,
}

/// Combine N trial outcomes into the paper's evaluation summary.
pub fn aggregate(
    evaluator: &dyn Evaluator,
    theta: &[Value],
    outcomes: &[TrialOutcome],
    weights: UqWeights,
) -> EvalSummary {
    assert!(!outcomes.is_empty());
    let trained: Vec<f64> = outcomes.iter().map(|o| o.loss).collect();
    let mut members = trained.clone();
    for o in outcomes {
        members.extend_from_slice(&o.dropout_losses);
    }

    // Weighted-mean center (fallback), Eq. 6 applied to scalar losses.
    let n = trained.len() as f64;
    let nt: usize = outcomes.iter().map(|o| o.dropout_losses.len()).sum();
    let dropout_mean = if nt > 0 {
        outcomes
            .iter()
            .flat_map(|o| &o.dropout_losses)
            .sum::<f64>()
            / nt as f64
    } else {
        trained.iter().sum::<f64>() / n
    };
    let fallback_center = if nt > 0 {
        weights.w_trained * trained.iter().sum::<f64>() / n
            + weights.w_dropout * dropout_mean
    } else {
        trained.iter().sum::<f64>() / n
    };

    // Preferred center: ℓ₁(μ_pred) via Eqs. (6).
    let have_preds = outcomes.iter().all(|o| o.predictions.is_some());
    let (center, v_model_g) = if have_preds {
        let set = PredictionSet {
            trained: outcomes
                .iter()
                .map(|o| o.predictions.clone().unwrap())
                .collect(),
            dropout: outcomes
                .iter()
                .map(|o| o.dropout_predictions.clone())
                .collect(),
        };
        let mu = set.mu_pred(weights);
        let v = set.v_model(weights);
        let g = crate::uq::g_norm_relu(&v);
        match evaluator.loss_of_mean_prediction(theta, &mu) {
            Some(l) => (l, g),
            None => (fallback_center, g),
        }
    } else {
        (fallback_center, 0.0)
    };

    EvalSummary {
        interval: loss_interval(center, &members),
        trained_mean: trained.iter().sum::<f64>() / n,
        trained_std: crate::uq::stddev(&trained),
        v_model_g,
        total_cost: outcomes.iter().map(|o| o.cost).sum(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{ints, ParamSpec, Space};

    struct Dummy {
        space: Space,
    }

    impl Evaluator for Dummy {
        fn space(&self) -> &Space {
            &self.space
        }
        fn run_trial(
            &self,
            _t: &[Value],
            _i: usize,
            _s: u64,
        ) -> TrialOutcome {
            unreachable!()
        }
        fn n_params(&self, _t: &[Value]) -> u64 {
            0
        }
    }

    fn outcome(loss: f64, dl: &[f64]) -> TrialOutcome {
        TrialOutcome {
            loss,
            dropout_losses: dl.to_vec(),
            predictions: None,
            dropout_predictions: vec![],
            cost: Duration::from_millis(10),
        }
    }

    #[test]
    fn aggregate_weighted_center() {
        let d = Dummy { space: Space::new(vec![ParamSpec::new("x", 0, 1)]) };
        let outs = vec![
            outcome(1.0, &[2.0, 2.0]),
            outcome(3.0, &[4.0, 4.0]),
        ];
        let s = aggregate(&d, &ints(&[0]), &outs, UqWeights::default_paper());
        // trained mean 2, dropout mean 3 -> center 2.5
        assert!((s.interval.center - 2.5).abs() < 1e-12);
        assert!(s.interval.radius > 0.0);
        assert_eq!(s.trained_mean, 2.0);
        assert_eq!(s.total_cost, Duration::from_millis(20));
    }

    #[test]
    fn aggregate_no_dropout_uses_plain_mean() {
        let d = Dummy { space: Space::new(vec![ParamSpec::new("x", 0, 1)]) };
        let outs = vec![outcome(1.0, &[]), outcome(2.0, &[])];
        let s = aggregate(&d, &ints(&[0]), &outs, UqWeights::default_paper());
        assert!((s.interval.center - 1.5).abs() < 1e-12);
    }

    #[test]
    fn aggregate_fallback_center_ignores_weights_without_dropout() {
        // nt == 0: applying the (w_T, w_D) weighting literally would
        // scale the trained mean by w_T; the fallback must fall back to
        // the *plain* mean regardless of the weights.
        let d = Dummy { space: Space::new(vec![ParamSpec::new("x", 0, 1)]) };
        let outs = vec![outcome(1.0, &[]), outcome(3.0, &[])];
        let s = aggregate(&d, &ints(&[0]), &outs, UqWeights::new(0.2, 0.8));
        assert!((s.interval.center - 2.0).abs() < 1e-12);
        // The CI radius is the member-loss spread: members = trained
        // losses only here, population σ of {1, 3} = 1.
        assert!((s.interval.radius - 1.0).abs() < 1e-12);
        assert!((s.trained_std - 1.0).abs() < 1e-12);
        assert_eq!(s.v_model_g, 0.0);
    }

    #[test]
    fn aggregate_single_trial_without_dropout() {
        // N == 1, nt == 0 — the degenerate cheapest evaluation. Center
        // is the lone loss; a single member has no spread, so both the
        // CI radius and the trained std collapse to 0.
        let d = Dummy { space: Space::new(vec![ParamSpec::new("x", 0, 1)]) };
        let outs = vec![outcome(2.5, &[])];
        let s = aggregate(&d, &ints(&[0]), &outs, UqWeights::default_paper());
        assert_eq!(s.interval.center, 2.5);
        assert_eq!(s.interval.radius, 0.0);
        assert_eq!(s.trained_mean, 2.5);
        assert_eq!(s.trained_std, 0.0);
        assert_eq!(s.v_model_g, 0.0);
        assert_eq!(s.total_cost, Duration::from_millis(10));
    }

    #[test]
    fn aggregate_single_trial_with_dropout_weights_the_center() {
        // N == 1 with dropout passes: the weighted Eq. (6) center blends
        // the lone trained loss with the dropout mean, the members
        // {1, 2, 4} give a positive radius, but the *trained* spread is
        // still 0 (one trained model) — exactly the signal the adaptive
        // replica policy keys on.
        let d = Dummy { space: Space::new(vec![ParamSpec::new("x", 0, 1)]) };
        let outs = vec![outcome(1.0, &[2.0, 4.0])];
        let s = aggregate(&d, &ints(&[0]), &outs, UqWeights::default_paper());
        // trained mean 1, dropout mean 3 → 0.5·1 + 0.5·3 = 2.
        assert!((s.interval.center - 2.0).abs() < 1e-12);
        assert!(s.interval.radius > 0.0);
        assert_eq!(s.trained_std, 0.0);
    }
}
