//! SLURM batch-script generator (paper §IV, Feature 3, "The program can
//! automatically generate a SLURM script…").
//!
//! Reproduces the directives the paper shows — `--ntasks = steps × tasks`,
//! `--gpus-per-task 1`, GNU parallel with `--jobs steps`, and `srun
//! --exclusive` so job steps never share processors. On this testbed the
//! script is documentation/portability output (the simulated cluster in
//! `sim`/`workers` executes the same schedule in-process).

use crate::cluster::Topology;

/// Everything the generated `#SBATCH` script is parameterized on.
#[derive(Debug, Clone)]
pub struct SlurmJobConfig {
    /// `--job-name`.
    pub job_name: String,
    /// steps × tasks layout; `--ntasks` is its processor product.
    pub topology: Topology,
    /// Request one GPU per task (`--gpus-per-task 1`) vs CPU-only.
    pub use_gpu: bool,
    /// `--time` wall-clock limit.
    pub time_limit: String,
    /// Command each SLURM step executes (receives the step id as `{}`).
    pub step_command: String,
}

impl Default for SlurmJobConfig {
    fn default() -> Self {
        SlurmJobConfig {
            job_name: "hyppo".into(),
            topology: Topology::new(2, 3),
            use_gpu: true,
            time_limit: "04:00:00".into(),
            step_command: "hyppo run --step {}".into(),
        }
    }
}

/// Render the batch script.
pub fn render(cfg: &SlurmJobConfig) -> String {
    let t = cfg.topology;
    let proc_line = if cfg.use_gpu {
        "#SBATCH --gpus-per-task 1"
    } else {
        "#SBATCH --cpus-per-task 1"
    };
    format!(
        "#!/bin/bash\n\
         #SBATCH --job-name {name}\n\
         #SBATCH --ntasks {ntasks}\n\
         {proc_line}\n\
         #SBATCH --time {time}\n\
         \n\
         # {steps} parallel job steps x {tasks} tasks each; GNU parallel\n\
         # launches the steps, srun --exclusive pins disjoint processors\n\
         # to every step (paper Sec. IV, Feature 3).\n\
         seq 0 {last_step} | parallel --jobs {steps} \\\n\
         \x20 srun --exclusive --ntasks {tasks} {cmd}\n",
        name = cfg.job_name,
        ntasks = t.processors(),
        proc_line = proc_line,
        time = cfg.time_limit,
        steps = t.steps,
        tasks = t.tasks_per_step,
        last_step = t.steps - 1,
        cmd = cfg.step_command,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_paper_example_directives() {
        // Paper: 2 srun instances x 3 GPUs -> --ntasks 6, --gpus-per-task 1.
        let cfg = SlurmJobConfig::default();
        let s = render(&cfg);
        assert!(s.contains("#SBATCH --ntasks 6"));
        assert!(s.contains("#SBATCH --gpus-per-task 1"));
        assert!(s.contains("parallel --jobs 2"));
        assert!(s.contains("srun --exclusive --ntasks 3"));
    }

    #[test]
    fn cpu_variant() {
        let cfg = SlurmJobConfig {
            use_gpu: false,
            topology: Topology::new(16, 6),
            ..Default::default()
        };
        let s = render(&cfg);
        assert!(s.contains("#SBATCH --ntasks 96"));
        assert!(s.contains("--cpus-per-task 1"));
        assert!(s.contains("seq 0 15"));
    }
}
