//! Simulated SLURM cluster — the paper's Feature 3 (asynchronous nested
//! parallelism), rebuilt on threads instead of SLURM/GNU-parallel
//! (DESIGN.md §Hardware adaptation).
//!
//! Four pieces:
//!   * `sim`     — deterministic event-driven *virtual-time* simulator of a
//!                 steps × tasks job. Regenerates the Fig. 8 speedup grid
//!                 exactly (no sleeps, replayable), and doubles as the
//!                 chaos testbed (fault-injected virtual clusters,
//!                 DESIGN.md §12).
//!   * `faults`  — declarative, seedable `FaultPlan`s (crashes,
//!                 stragglers, preemptions, lost/duplicate results,
//!                 restarts) the simulator injects.
//!   * `workers` — the real asynchronous HPO loop: a pool of step-workers,
//!                 per-completion surrogate refits, provenance tracking
//!                 (Fig. 6 semantics), nested trial-/data-parallel tasks.
//!   * `slurm`   — emits the `#SBATCH` + GNU-parallel launcher the paper
//!                 shows, for documentation/portability parity.

pub mod faults;
pub mod sim;
pub mod slurm;
pub mod workers;

/// Inner (per-step) parallelization mode of §IV-2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParallelMode {
    /// SLURM tasks parallelize the N training trials of one θ.
    TrialParallel,
    /// SLURM tasks shard the training data of each trial.
    DataParallel,
}

/// steps × tasks topology (one processor per task; `--exclusive`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    /// Outer parallelism: concurrent hyperparameter evaluations.
    pub steps: usize,
    /// Inner parallelism: tasks per evaluation (trial or data parallel).
    pub tasks_per_step: usize,
}

impl Topology {
    pub fn new(steps: usize, tasks_per_step: usize) -> Self {
        assert!(steps > 0 && tasks_per_step > 0);
        Topology { steps, tasks_per_step }
    }

    /// Total processors = SLURM `--ntasks`.
    pub fn processors(&self) -> usize {
        self.steps * self.tasks_per_step
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn processors_product() {
        assert_eq!(Topology::new(2, 3).processors(), 6);
        assert_eq!(Topology::new(16, 6).processors(), 96);
    }

    #[test]
    #[should_panic]
    fn zero_steps_rejected() {
        let _ = Topology::new(0, 1);
    }
}
