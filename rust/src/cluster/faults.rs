//! Seeded fault plans for the chaos simulator (DESIGN.md §12).
//!
//! A [`FaultPlan`] is a declarative list of [`Fault`] events — worker
//! crashes, stragglers, preemptions, lost/duplicated results, cluster
//! restarts — injected into `cluster::sim::simulate_chaos` at chosen
//! virtual times. Plans are plain data: they can be written in a config
//! file (`[faults]` section, see [`FaultPlan::from_section`]), generated
//! from a seed ([`FaultPlan::random`]), or built directly in tests.
//!
//! Compilation (`FaultPlan::compile`, crate-private) canonicalizes the
//! event list so that two plans containing the same events in any order
//! inject identically — the simulation is a function of the *set* of
//! faults, not of the order they were written down in:
//!
//! * per-evaluation crash fractions merge by minimum (earliest kill wins),
//! * lost-result counts for the same evaluation add up,
//! * duplicate deliveries collapse to one per evaluation,
//! * timed faults sort by (time, kind, worker, downtime),
//! * straggler windows sort by (worker, window, factor).

use std::collections::{BTreeMap, BTreeSet};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::Value;
use crate::sampling::rng::Rng;

/// One fault to inject into a simulated run.
#[derive(Debug, Clone, PartialEq)]
pub enum Fault {
    /// Kill evaluation `eval` once, after fraction `frac ∈ [0, 1]` of
    /// its execution has elapsed. The partial work is wasted and the
    /// evaluation is requeued (consuming retry budget).
    CrashEval { eval: usize, frac: f64 },
    /// Kill *every* evaluation once, each at fraction `frac` of its own
    /// execution — the crash-inject-everything plan of the headline
    /// equivalence test.
    CrashAll { frac: f64 },
    /// Kill whatever is running on `worker` at virtual time `at`
    /// (a no-op if the worker is idle or down at that moment).
    CrashWorkerAt { worker: usize, at: Duration },
    /// Preempt `worker` at `at`: its running evaluation is requeued
    /// *without* consuming retry budget (preemption is the scheduler's
    /// fault, not the job's) and the worker stays down for `down`.
    Preempt { worker: usize, at: Duration, down: Duration },
    /// Multiply the duration of work *started* on `worker` within
    /// `[from, until)` by `factor` (> 1 slows the worker down).
    Straggle { worker: usize, factor: f64, from: Duration, until: Duration },
    /// Drop the result of evaluation `eval` the first `times` times it
    /// completes: the work is wasted and the evaluation is requeued
    /// (consuming retry budget), exactly as if the worker's channel
    /// died after training finished.
    LoseResult { eval: usize, times: usize },
    /// Re-deliver the first trial outcome of `eval` after the evaluation
    /// completes; the session must reject the duplicate.
    DuplicateResult { eval: usize },
    /// Cluster-wide restart at `at`: every running evaluation is killed,
    /// the session passes through its real snapshot → JSON → restore
    /// wire, and all workers stay down for `down`.
    Restart { at: Duration, down: Duration },
}

/// A full fault schedule (empty = fault-free).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<Fault>,
}

/// Shape of a randomly generated plan (see [`FaultPlan::random`]).
#[derive(Debug, Clone)]
pub struct RandomFaultSpec {
    /// Single-evaluation crash faults to draw.
    pub crashes: usize,
    /// Per-worker straggler windows to draw.
    pub stragglers: usize,
    /// Worker preemptions to draw.
    pub preemptions: usize,
    /// Lost-result faults to draw.
    pub lost: usize,
    /// Evaluation-id universe crash/lose targets are drawn from.
    pub evals: usize,
    /// Worker-id universe straggler/preemption targets are drawn from.
    pub workers: usize,
    /// Virtual-time horizon timed faults are drawn from.
    pub horizon: Duration,
}

impl Default for RandomFaultSpec {
    fn default() -> Self {
        RandomFaultSpec {
            crashes: 0,
            stragglers: 0,
            preemptions: 0,
            lost: 0,
            evals: 64,
            workers: 8,
            horizon: Duration::from_secs(1),
        }
    }
}

/// Timed cluster-level faults in canonical firing order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum TimedKind {
    Restart { down: Duration },
    CrashWorker { worker: usize },
    Preempt { worker: usize, down: Duration },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct TimedFault {
    pub(crate) at: Duration,
    pub(crate) kind: TimedKind,
}

impl TimedFault {
    /// Total order: (time, kind class, worker, downtime). Restarts fire
    /// before worker crashes before preemptions at equal times.
    fn sort_key(&self) -> (Duration, u8, usize, Duration) {
        match self.kind {
            TimedKind::Restart { down } => (self.at, 0, 0, down),
            TimedKind::CrashWorker { worker } => {
                (self.at, 1, worker, Duration::ZERO)
            }
            TimedKind::Preempt { worker, down } => {
                (self.at, 2, worker, down)
            }
        }
    }
}

/// A slowdown window: work started on `worker` in `[from, until)` takes
/// `factor` times as long.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct StraggleWindow {
    pub(crate) worker: usize,
    pub(crate) factor: f64,
    pub(crate) from: Duration,
    pub(crate) until: Duration,
}

/// The canonical, order-independent form of a plan that the simulator
/// consumes (see the module docs for the merge rules).
#[derive(Debug, Clone, Default, PartialEq)]
pub(crate) struct CompiledPlan {
    pub(crate) crash_all: Option<f64>,
    pub(crate) crash_eval: BTreeMap<usize, f64>,
    pub(crate) timed: Vec<TimedFault>,
    pub(crate) straggle: Vec<StraggleWindow>,
    pub(crate) lose: BTreeMap<usize, usize>,
    pub(crate) duplicate: BTreeSet<usize>,
}

fn check_frac(frac: f64, what: &str) -> Result<()> {
    if !frac.is_finite() || !(0.0..=1.0).contains(&frac) {
        bail!("{what}: crash fraction {frac} must be in [0, 1]");
    }
    Ok(())
}

impl FaultPlan {
    /// Canonicalize into the form the simulator consumes; validates
    /// every event. Plans that contain the same events in a different
    /// order compile to the same `CompiledPlan`.
    pub(crate) fn compile(&self) -> Result<CompiledPlan> {
        let mut c = CompiledPlan::default();
        for f in &self.events {
            match *f {
                Fault::CrashEval { eval, frac } => {
                    check_frac(frac, "crash")?;
                    let e = c.crash_eval.entry(eval).or_insert(frac);
                    *e = e.min(frac);
                }
                Fault::CrashAll { frac } => {
                    check_frac(frac, "crash_all")?;
                    c.crash_all = Some(match c.crash_all {
                        Some(prev) => prev.min(frac),
                        None => frac,
                    });
                }
                Fault::CrashWorkerAt { worker, at } => {
                    c.timed.push(TimedFault {
                        at,
                        kind: TimedKind::CrashWorker { worker },
                    });
                }
                Fault::Preempt { worker, at, down } => {
                    c.timed.push(TimedFault {
                        at,
                        kind: TimedKind::Preempt { worker, down },
                    });
                }
                Fault::Straggle { worker, factor, from, until } => {
                    if !factor.is_finite() || factor <= 0.0 {
                        bail!(
                            "straggle: factor {factor} must be finite \
                             and > 0"
                        );
                    }
                    if from > until {
                        bail!(
                            "straggle: window [{from:?}, {until:?}) is \
                             empty"
                        );
                    }
                    c.straggle.push(StraggleWindow {
                        worker,
                        factor,
                        from,
                        until,
                    });
                }
                Fault::LoseResult { eval, times } => {
                    *c.lose.entry(eval).or_insert(0) += times;
                }
                Fault::DuplicateResult { eval } => {
                    c.duplicate.insert(eval);
                }
                Fault::Restart { at, down } => {
                    c.timed.push(TimedFault {
                        at,
                        kind: TimedKind::Restart { down },
                    });
                }
            }
        }
        c.timed.sort_by_key(TimedFault::sort_key);
        c.straggle.sort_by_key(|s| {
            (s.worker, s.from, s.until, s.factor.to_bits())
        });
        c.lose.retain(|_, times| *times > 0);
        Ok(c)
    }

    /// Draw a plan from a seed — the same (seed, spec) pair always
    /// yields the same plan, so a whole chaos run is reproducible from
    /// its two seeds (experiment seed + fault seed).
    pub fn random(seed: u64, spec: &RandomFaultSpec) -> FaultPlan {
        let mut rng = Rng::new(seed);
        let mut events = Vec::new();
        let evals = spec.evals.max(1);
        let workers = spec.workers.max(1);
        for _ in 0..spec.crashes {
            events.push(Fault::CrashEval {
                eval: rng.usize_below(evals),
                frac: 0.05 + 0.9 * rng.f64(),
            });
        }
        for _ in 0..spec.stragglers {
            let from = spec.horizon.mul_f64(rng.f64());
            let len = spec.horizon.mul_f64(0.1 + 0.4 * rng.f64());
            events.push(Fault::Straggle {
                worker: rng.usize_below(workers),
                factor: 1.5 + 2.5 * rng.f64(),
                from,
                until: from + len,
            });
        }
        for _ in 0..spec.preemptions {
            events.push(Fault::Preempt {
                worker: rng.usize_below(workers),
                at: spec.horizon.mul_f64(rng.f64()),
                down: spec.horizon.mul_f64(0.05 * rng.f64()),
            });
        }
        for _ in 0..spec.lost {
            events.push(Fault::LoseResult {
                eval: rng.usize_below(evals),
                times: 1,
            });
        }
        FaultPlan { events }
    }

    /// Parse a `[faults]` config section. Grammar (all durations in
    /// virtual milliseconds):
    ///
    /// ```toml
    /// [faults]
    /// events = [
    ///     { kind = "crash", eval = 3, frac = 0.5 },
    ///     { kind = "crash_all", frac = 0.3 },
    ///     { kind = "crash_worker", worker = 1, at_ms = 120 },
    ///     { kind = "preempt", worker = 0, at_ms = 200, down_ms = 50 },
    ///     { kind = "straggle", worker = 2, factor = 3.0,
    ///       from_ms = 0, until_ms = 400 },
    ///     { kind = "lose", eval = 4, times = 1 },
    ///     { kind = "duplicate", eval = 1 },
    ///     { kind = "restart", at_ms = 300, down_ms = 10 },
    /// ]
    /// # optionally, seeded random faults on top:
    /// random = { seed = 7, crashes = 4, stragglers = 2, preemptions = 1,
    ///            lost = 2, evals = 24, workers = 4, horizon_ms = 2000 }
    /// ```
    pub fn from_section(sec: &BTreeMap<String, Value>) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        if let Some(v) = sec.get("events") {
            let arr = v
                .as_arr()
                .ok_or_else(|| anyhow!("faults.events must be an array"))?;
            for (i, e) in arr.iter().enumerate() {
                plan.events.push(
                    parse_event(e)
                        .with_context(|| format!("faults.events[{i}]"))?,
                );
            }
        }
        if let Some(v) = sec.get("random") {
            let t = v.as_table().ok_or_else(|| {
                anyhow!("faults.random must be an inline table")
            })?;
            let count = |k: &str| -> Result<usize> {
                match t.get(k) {
                    None => Ok(0),
                    Some(v) => v
                        .as_i64()
                        .filter(|n| *n >= 0)
                        .map(|n| n as usize)
                        .ok_or_else(|| {
                            anyhow!("faults.random.{k} must be a count")
                        }),
                }
            };
            let defaults = RandomFaultSpec::default();
            let spec = RandomFaultSpec {
                crashes: count("crashes")?,
                stragglers: count("stragglers")?,
                preemptions: count("preemptions")?,
                lost: count("lost")?,
                evals: match count("evals")? {
                    0 => defaults.evals,
                    n => n,
                },
                workers: match count("workers")? {
                    0 => defaults.workers,
                    n => n,
                },
                horizon: t
                    .get("horizon_ms")
                    .and_then(Value::as_f64)
                    .map(|ms| Duration::from_secs_f64(ms.max(0.0) / 1e3))
                    .unwrap_or(defaults.horizon),
            };
            let seed = t
                .get("seed")
                .map(|v| {
                    v.as_i64()
                        .filter(|s| *s >= 0)
                        .map(|s| s as u64)
                        .ok_or_else(|| {
                            anyhow!("faults.random.seed must be a u64")
                        })
                })
                .transpose()?
                .unwrap_or(0);
            plan.events.extend(FaultPlan::random(seed, &spec).events);
        }
        // Validate eagerly so config errors surface at load time, not
        // mid-simulation.
        plan.compile()?;
        Ok(plan)
    }
}

fn parse_event(v: &Value) -> Result<Fault> {
    let t = v
        .as_table()
        .ok_or_else(|| anyhow!("fault event must be an inline table"))?;
    let kind = t
        .get("kind")
        .and_then(Value::as_str)
        .ok_or_else(|| anyhow!("fault event needs kind = \"...\""))?;
    let num = |k: &str| -> Result<f64> {
        t.get(k).and_then(Value::as_f64).ok_or_else(|| {
            anyhow!("{kind} fault needs a numeric {k}")
        })
    };
    let idx = |k: &str| -> Result<usize> {
        t.get(k)
            .and_then(Value::as_i64)
            .filter(|n| *n >= 0)
            .map(|n| n as usize)
            .ok_or_else(|| anyhow!("{kind} fault needs an index {k}"))
    };
    let ms = |k: &str| -> Result<Duration> {
        let v = num(k)?;
        if !v.is_finite() || v < 0.0 {
            bail!("{kind} fault: {k} = {v} must be a non-negative time");
        }
        Ok(Duration::from_secs_f64(v / 1e3))
    };
    let ms_or = |k: &str, d: Duration| -> Result<Duration> {
        if t.contains_key(k) {
            ms(k)
        } else {
            Ok(d)
        }
    };
    match kind {
        "crash" => Ok(Fault::CrashEval {
            eval: idx("eval")?,
            frac: num("frac")?,
        }),
        "crash_all" => Ok(Fault::CrashAll { frac: num("frac")? }),
        "crash_worker" => Ok(Fault::CrashWorkerAt {
            worker: idx("worker")?,
            at: ms("at_ms")?,
        }),
        "preempt" => Ok(Fault::Preempt {
            worker: idx("worker")?,
            at: ms("at_ms")?,
            down: ms_or("down_ms", Duration::ZERO)?,
        }),
        "straggle" => Ok(Fault::Straggle {
            worker: idx("worker")?,
            factor: num("factor")?,
            from: ms_or("from_ms", Duration::ZERO)?,
            until: ms_or("until_ms", Duration::MAX)?,
        }),
        "lose" => Ok(Fault::LoseResult {
            eval: idx("eval")?,
            times: if t.contains_key("times") { idx("times")? } else { 1 },
        }),
        "duplicate" => Ok(Fault::DuplicateResult { eval: idx("eval")? }),
        "restart" => Ok(Fault::Restart {
            at: ms("at_ms")?,
            down: ms_or("down_ms", Duration::ZERO)?,
        }),
        other => bail!(
            "unknown fault kind {other:?} (crash | crash_all | \
             crash_worker | preempt | straggle | lose | duplicate | \
             restart)"
        ),
    }
}

// ===== serve-stack fault injection (DESIGN.md §16) ==================
//
// The simulator faults above act on *virtual cluster* runs; the types
// below act on the *serve stack*: scripted disk misbehaviour beneath a
// shard WAL ([`FaultyWalIo`]) and scripted connection misbehaviour
// beneath the line protocol ([`ChaosConnector`]). Both are plans over
// operation indices, so a chaos test is a pure function of its script —
// no timing races, no flaky sleeps.

/// One scripted disk fault, firing at a 0-based append index.
#[derive(Debug, Clone, PartialEq)]
pub enum DiskFault {
    /// Append `at_append` fails outright; nothing reaches the file.
    WalAppendError { at_append: usize },
    /// Append `at_append` writes only the first `keep` bytes, then
    /// errors — the torn tail a power cut leaves behind.
    WalTornTail { at_append: usize, keep: usize },
    /// Append `at_append` succeeds but stalls the disk: the attached
    /// virtual clock jumps `delay_ms` first (lease expiry sees the
    /// stall; the data is fine).
    SlowFsync { at_append: usize, delay_ms: u64 },
}

impl DiskFault {
    fn at_append(&self) -> usize {
        match *self {
            DiskFault::WalAppendError { at_append }
            | DiskFault::WalTornTail { at_append, .. }
            | DiskFault::SlowFsync { at_append, .. } => at_append,
        }
    }
}

/// A [`WalIo`] wrapper that injects a [`DiskFault`] plan over an inner
/// implementation. Append indices count *attempts* on this instance,
/// across every path it is asked to write (primary and failover), so a
/// script addresses "the third write this disk sees".
#[derive(Debug)]
pub struct FaultyWalIo {
    inner: Box<dyn crate::serve::wal::WalIo>,
    plan: Vec<DiskFault>,
    appends: usize,
    clock: Option<std::sync::Arc<crate::serve::clock::VirtualClock>>,
}

impl FaultyWalIo {
    /// Wrap `inner` with a fault script.
    pub fn new(
        inner: Box<dyn crate::serve::wal::WalIo>,
        plan: Vec<DiskFault>,
    ) -> FaultyWalIo {
        FaultyWalIo { inner, plan, appends: 0, clock: None }
    }

    /// Attach a virtual clock for [`DiskFault::SlowFsync`] stalls.
    pub fn with_clock(
        mut self,
        clock: std::sync::Arc<crate::serve::clock::VirtualClock>,
    ) -> FaultyWalIo {
        self.clock = Some(clock);
        self
    }

    /// Append attempts seen so far.
    pub fn appends(&self) -> usize {
        self.appends
    }
}

impl crate::serve::wal::WalIo for FaultyWalIo {
    fn append(
        &mut self,
        path: &std::path::Path,
        bytes: &[u8],
    ) -> Result<()> {
        let idx = self.appends;
        self.appends += 1;
        let fault =
            self.plan.iter().find(|f| f.at_append() == idx).cloned();
        match fault {
            Some(DiskFault::WalAppendError { .. }) => {
                bail!("injected WAL append error at append {idx}")
            }
            Some(DiskFault::WalTornTail { keep, .. }) => {
                let head = bytes.get(..keep.min(bytes.len()));
                if let Some(head) = head {
                    if !head.is_empty() {
                        self.inner.append(path, head)?;
                    }
                }
                bail!("injected torn tail at append {idx}")
            }
            Some(DiskFault::SlowFsync { delay_ms, .. }) => {
                if let Some(clock) = &self.clock {
                    clock.advance(delay_ms);
                }
                self.inner.append(path, bytes)
            }
            None => self.inner.append(path, bytes),
        }
    }

    fn atomic_write(
        &mut self,
        path: &std::path::Path,
        bytes: &[u8],
    ) -> Result<()> {
        // Snapshots are atomic-rename writes; the faults above model
        // append-path failures only.
        self.inner.atomic_write(path, bytes)
    }
}

/// A cloneable [`WalIo`] sharing one [`FaultyWalIo`] behind a mutex, so
/// a supervisor restart (which opens a fresh WAL through the pool's IO
/// factory) keeps talking to the *same* scripted disk — a disk that
/// "stays broken" keeps failing the rebuilt shard.
#[derive(Debug, Clone)]
pub struct SharedWalIo(std::sync::Arc<std::sync::Mutex<FaultyWalIo>>);

impl SharedWalIo {
    /// Share `io` across clones.
    pub fn new(io: FaultyWalIo) -> SharedWalIo {
        SharedWalIo(std::sync::Arc::new(std::sync::Mutex::new(io)))
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, FaultyWalIo> {
        match self.0.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Append attempts the shared disk has seen.
    pub fn appends(&self) -> usize {
        self.lock().appends()
    }
}

impl crate::serve::wal::WalIo for SharedWalIo {
    fn append(
        &mut self,
        path: &std::path::Path,
        bytes: &[u8],
    ) -> Result<()> {
        self.lock().append(path, bytes)
    }

    fn atomic_write(
        &mut self,
        path: &std::path::Path,
        bytes: &[u8],
    ) -> Result<()> {
        self.lock().atomic_write(path, bytes)
    }
}

/// One scripted connection fault, firing at a 0-based send index
/// (counted across reconnects — the script addresses "the third send
/// this client ever makes").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportFault {
    /// The request never reaches the service; the client notices only
    /// when the read fails.
    DropRequest { at_send: usize },
    /// The request *executes* but its response is lost — the lost-ack
    /// case the dedup window exists for.
    DropResponse { at_send: usize },
    /// The request is delivered twice (both responses queue; the
    /// duplicate must be a typed no-op server-side).
    DuplicateRequest { at_send: usize },
    /// The request is delivered twice and the responses queue in
    /// reverse order, leaving a stale line for the client to skip.
    ReorderResponses { at_send: usize },
    /// The connection drops at send time; the client must reconnect.
    Disconnect { at_send: usize },
}

impl TransportFault {
    fn at_send(&self) -> usize {
        match *self {
            TransportFault::DropRequest { at_send }
            | TransportFault::DropResponse { at_send }
            | TransportFault::DuplicateRequest { at_send }
            | TransportFault::ReorderResponses { at_send }
            | TransportFault::Disconnect { at_send } => at_send,
        }
    }
}

/// Shared state behind a chaos connection: the in-process endpoint
/// (usually `LineServer::serve`), the fault script, and the simulated
/// socket (pending responses + broken flag).
struct ChaosState {
    endpoint: Box<dyn FnMut(&str) -> String + Send>,
    plan: Vec<TransportFault>,
    sends: usize,
    pending: std::collections::VecDeque<String>,
    broken: bool,
}

/// A [`Connector`] whose connections run a [`TransportFault`] script
/// against an in-process endpoint. Reconnecting clears the simulated
/// socket (pending lines are gone, the broken flag resets) but the
/// send counter persists — exactly TCP's semantics, where a new
/// connection starts clean but the world has still seen your traffic.
///
/// Clones share the scripted state, so a test can keep a probe handle
/// on the send counter after moving the connector into a client.
///
/// [`Connector`]: crate::serve::net::Connector
#[derive(Clone)]
pub struct ChaosConnector(
    std::sync::Arc<std::sync::Mutex<ChaosState>>,
);

impl ChaosConnector {
    /// A chaos connector over `endpoint` running `plan`.
    pub fn new(
        endpoint: impl FnMut(&str) -> String + Send + 'static,
        plan: Vec<TransportFault>,
    ) -> ChaosConnector {
        ChaosConnector(std::sync::Arc::new(std::sync::Mutex::new(
            ChaosState {
                endpoint: Box::new(endpoint),
                plan,
                sends: 0,
                pending: std::collections::VecDeque::new(),
                broken: false,
            },
        )))
    }

    /// Sends the script has seen so far (including dropped ones).
    pub fn sends(&self) -> usize {
        lock_chaos(&self.0).sends
    }
}

fn lock_chaos(
    m: &std::sync::Mutex<ChaosState>,
) -> std::sync::MutexGuard<'_, ChaosState> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

impl crate::serve::net::Connector for ChaosConnector {
    fn connect(
        &mut self,
    ) -> Result<Box<dyn crate::serve::net::Transport>> {
        let mut st = lock_chaos(&self.0);
        st.broken = false;
        st.pending.clear();
        Ok(Box::new(ChaosTransport(std::sync::Arc::clone(&self.0))))
    }
}

/// One live chaos connection (see [`ChaosConnector`]).
pub struct ChaosTransport(
    std::sync::Arc<std::sync::Mutex<ChaosState>>,
);

impl crate::serve::net::Transport for ChaosTransport {
    fn send_line(&mut self, line: &str) -> Result<()> {
        let mut st = lock_chaos(&self.0);
        if st.broken {
            bail!("chaos connection is broken");
        }
        let idx = st.sends;
        st.sends += 1;
        let fault =
            st.plan.iter().find(|f| f.at_send() == idx).copied();
        match fault {
            Some(TransportFault::DropRequest { .. }) => {
                // Lost on the wire: nothing executes, nothing comes
                // back; the client's next read fails.
                st.broken = true;
                Ok(())
            }
            Some(TransportFault::DropResponse { .. }) => {
                let resp = (st.endpoint)(line);
                drop(resp);
                st.broken = true;
                Ok(())
            }
            Some(TransportFault::DuplicateRequest { .. }) => {
                let first = (st.endpoint)(line);
                let second = (st.endpoint)(line);
                st.pending.push_back(first);
                st.pending.push_back(second);
                Ok(())
            }
            Some(TransportFault::ReorderResponses { .. }) => {
                let first = (st.endpoint)(line);
                let second = (st.endpoint)(line);
                st.pending.push_back(second);
                st.pending.push_back(first);
                Ok(())
            }
            Some(TransportFault::Disconnect { .. }) => {
                st.broken = true;
                bail!("injected disconnect at send {idx}")
            }
            None => {
                let resp = (st.endpoint)(line);
                st.pending.push_back(resp);
                Ok(())
            }
        }
    }

    fn recv_line(&mut self) -> Result<String> {
        let mut st = lock_chaos(&self.0);
        if let Some(line) = st.pending.pop_front() {
            return Ok(line);
        }
        if st.broken {
            bail!("chaos connection reset");
        }
        bail!("no response pending (script/read mismatch)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    #[test]
    fn compile_is_order_invariant() {
        let events = vec![
            Fault::Restart { at: ms(300), down: ms(10) },
            Fault::CrashEval { eval: 2, frac: 0.7 },
            Fault::Straggle {
                worker: 1,
                factor: 2.0,
                from: ms(0),
                until: ms(100),
            },
            Fault::CrashEval { eval: 2, frac: 0.3 },
            Fault::LoseResult { eval: 4, times: 1 },
            Fault::Preempt { worker: 0, at: ms(50), down: ms(5) },
            Fault::LoseResult { eval: 4, times: 2 },
            Fault::DuplicateResult { eval: 1 },
            Fault::CrashAll { frac: 0.9 },
            Fault::CrashAll { frac: 0.4 },
        ];
        let fwd = FaultPlan { events: events.clone() }.compile().unwrap();
        let mut rev = events;
        rev.reverse();
        let bwd = FaultPlan { events: rev }.compile().unwrap();
        assert_eq!(fwd, bwd);
        // Merge rules: min frac, summed lose counts.
        assert_eq!(fwd.crash_eval[&2], 0.3);
        assert_eq!(fwd.crash_all, Some(0.4));
        assert_eq!(fwd.lose[&4], 3);
        assert!(fwd.duplicate.contains(&1));
        // Timed order: preempt@50 before restart@300.
        assert_eq!(fwd.timed[0].at, ms(50));
        assert_eq!(fwd.timed[1].at, ms(300));
    }

    #[test]
    fn compile_rejects_bad_events() {
        for bad in [
            Fault::CrashEval { eval: 0, frac: 1.5 },
            Fault::CrashAll { frac: -0.1 },
            Fault::CrashAll { frac: f64::NAN },
            Fault::Straggle {
                worker: 0,
                factor: 0.0,
                from: ms(0),
                until: ms(1),
            },
            Fault::Straggle {
                worker: 0,
                factor: 2.0,
                from: ms(5),
                until: ms(1),
            },
        ] {
            let plan = FaultPlan { events: vec![bad.clone()] };
            assert!(plan.compile().is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn random_plans_are_seed_deterministic() {
        let spec = RandomFaultSpec {
            crashes: 5,
            stragglers: 3,
            preemptions: 2,
            lost: 2,
            evals: 24,
            workers: 4,
            horizon: Duration::from_secs(2),
        };
        let a = FaultPlan::random(9, &spec);
        let b = FaultPlan::random(9, &spec);
        assert_eq!(a, b);
        assert_eq!(a.events.len(), 12);
        assert_ne!(a, FaultPlan::random(10, &spec));
        // Every drawn event passes validation.
        a.compile().unwrap();
    }

    #[test]
    fn from_section_parses_every_kind() {
        let text = r#"
[faults]
events = [
    { kind = "crash", eval = 3, frac = 0.5 },
    { kind = "crash_all", frac = 0.3 },
    { kind = "crash_worker", worker = 1, at_ms = 120 },
    { kind = "preempt", worker = 0, at_ms = 200, down_ms = 50 },
    { kind = "straggle", worker = 2, factor = 3.0, from_ms = 0, until_ms = 400 },
    { kind = "lose", eval = 4 },
    { kind = "duplicate", eval = 1 },
    { kind = "restart", at_ms = 300, down_ms = 10 },
]
"#;
        let doc = crate::config::parse(text).unwrap();
        let plan = FaultPlan::from_section(&doc["faults"]).unwrap();
        assert_eq!(plan.events.len(), 8);
        assert_eq!(
            plan.events[0],
            Fault::CrashEval { eval: 3, frac: 0.5 }
        );
        assert_eq!(
            plan.events[3],
            Fault::Preempt { worker: 0, at: ms(200), down: ms(50) }
        );
        assert_eq!(
            plan.events[5],
            Fault::LoseResult { eval: 4, times: 1 }
        );
        let c = plan.compile().unwrap();
        assert_eq!(c.timed.len(), 3);
        assert_eq!(c.straggle.len(), 1);
    }

    #[test]
    fn from_section_draws_random_faults() {
        let text = "[faults]\nrandom = { seed = 7, crashes = 4, \
                    stragglers = 2, evals = 24, workers = 4, \
                    horizon_ms = 2000 }\n";
        let doc = crate::config::parse(text).unwrap();
        let plan = FaultPlan::from_section(&doc["faults"]).unwrap();
        assert_eq!(plan.events.len(), 6);
        // Same seed, same section: same plan.
        let again = FaultPlan::from_section(&doc["faults"]).unwrap();
        assert_eq!(plan, again);
    }

    #[test]
    fn from_section_rejects_garbage() {
        for bad in [
            "[faults]\nevents = [ { kind = \"warp\" } ]\n",
            "[faults]\nevents = [ { eval = 1 } ]\n",
            "[faults]\nevents = [ { kind = \"crash\", eval = 1, \
             frac = 2.0 } ]\n",
            "[faults]\nevents = [ { kind = \"restart\", at_ms = -5 } ]\n",
            "[faults]\nevents = 3\n",
        ] {
            let doc = crate::config::parse(bad).unwrap();
            assert!(
                FaultPlan::from_section(&doc["faults"]).is_err(),
                "accepted {bad}"
            );
        }
    }
}
