//! Deterministic virtual-time simulator of one HPO job on a steps × tasks
//! topology — regenerates Fig. 8 without wall-clock sleeps.
//!
//! Two entry points share the cluster model: [`simulate`] replays a
//! fixed, pre-generated workload (the paper's static slicing), and
//! [`simulate_hpo`] drives a live `exec::Session` ask → tell loop in
//! virtual time — asynchronous surrogate dynamics with deterministic
//! replay and zero sleeps.
//!
//! Semantics follow §IV (Feature 3) exactly:
//!   * Hyperparameter evaluations are assigned to steps by Python-style
//!     slicing: step `s` executes evaluations `s, s+steps, s+2·steps, ...`
//!     (the paper's static slicing of the randomly generated sets).
//!   * Trial-parallel: within a step, trial `t` of an evaluation runs on
//!     task `t mod tasks`; tasks run their trial slices sequentially, the
//!     evaluation completes when the slowest task finishes.
//!   * Data-parallel: all tasks cooperate on each trial; the trial's cost
//!     divides by an efficiency-discounted task count plus a per-trial
//!     synchronization overhead, and trials run sequentially.
//!   * Exclusive processors: a step's tasks are dedicated; steps never
//!     share processors (asserted by construction, tested).

use std::time::Duration;

use crate::cluster::{ParallelMode, Topology};
use crate::eval::Evaluator;
use crate::exec::session::{EvalJob, Session};
use crate::optimizer::{History, HpoConfig};

/// Per-evaluation input: the simulated durations of its N trials.
#[derive(Debug, Clone)]
pub struct EvalCost {
    /// One entry per trial (trial index = position).
    pub trial_costs: Vec<Duration>,
}

/// Simulated-cluster parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// steps × tasks layout being simulated.
    pub topology: Topology,
    /// Inner (per-step) parallelization mode.
    pub mode: ParallelMode,
    /// Parallel efficiency of data-parallel scaling (1.0 = perfect).
    pub data_efficiency: f64,
    /// Fixed per-trial synchronization overhead in data-parallel mode.
    pub sync_overhead: Duration,
}

impl SimConfig {
    /// Trial-parallel configuration with the paper's default efficiency
    /// and synchronization constants.
    pub fn trial_parallel(topology: Topology) -> Self {
        SimConfig {
            topology,
            mode: ParallelMode::TrialParallel,
            data_efficiency: 0.85,
            sync_overhead: Duration::from_millis(5),
        }
    }
}

/// One simulated evaluation completion.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimEvent {
    /// Index of the evaluation in the submitted workload.
    pub eval_index: usize,
    /// Step (outer worker) that executed it.
    pub step: usize,
    /// Virtual start time.
    pub start: Duration,
    /// Virtual completion time.
    pub end: Duration,
}

/// Outcome of simulating one whole job.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Job makespan (max step completion time).
    pub makespan: Duration,
    /// Busy time per step (for utilization analysis).
    pub step_busy: Vec<Duration>,
    /// Completion events sorted by end time.
    pub timeline: Vec<SimEvent>,
}

/// Duration of one evaluation on one step under the given inner mode.
pub fn eval_duration(cost: &EvalCost, cfg: &SimConfig) -> Duration {
    let tasks = cfg.topology.tasks_per_step;
    match cfg.mode {
        ParallelMode::TrialParallel => {
            // Slice trials over tasks; slowest task bounds the evaluation.
            let mut per_task = vec![Duration::ZERO; tasks];
            for (t, c) in cost.trial_costs.iter().enumerate() {
                per_task[t % tasks] += *c;
            }
            per_task.into_iter().max().unwrap_or(Duration::ZERO)
        }
        ParallelMode::DataParallel => {
            let scale = if tasks == 1 {
                1.0
            } else {
                1.0 / (tasks as f64 * cfg.data_efficiency)
            };
            cost.trial_costs
                .iter()
                .map(|c| {
                    let scaled = c.mul_f64(scale);
                    let overhead = if tasks > 1 {
                        cfg.sync_overhead
                    } else {
                        Duration::ZERO
                    };
                    scaled + overhead
                })
                .sum()
        }
    }
}

/// Simulate a whole job over `evals` (ordered as generated).
pub fn simulate(evals: &[EvalCost], cfg: &SimConfig) -> SimResult {
    let steps = cfg.topology.steps;
    let mut clock = vec![Duration::ZERO; steps];
    let mut timeline = Vec::with_capacity(evals.len());
    for (i, ev) in evals.iter().enumerate() {
        let step = i % steps; // paper's slicing by step id
        let d = eval_duration(ev, cfg);
        let start = clock[step];
        clock[step] += d;
        timeline.push(SimEvent { eval_index: i, step, start, end: clock[step] });
    }
    timeline.sort_by_key(|e| e.end);
    SimResult {
        makespan: clock.iter().copied().max().unwrap_or(Duration::ZERO),
        step_busy: clock,
        timeline,
    }
}

/// Outcome of a virtual-time HPO experiment ([`simulate_hpo`]).
#[derive(Debug, Clone)]
pub struct HpoSimResult {
    /// Evaluations recorded, in (virtual) completion order.
    pub history: History,
    /// Virtual makespan of the whole experiment.
    pub makespan: Duration,
    /// Busy time per step.
    pub step_busy: Vec<Duration>,
    /// Completion events sorted by end time (`eval_index` = eval id).
    pub timeline: Vec<SimEvent>,
}

/// One job executing on a simulated step, with its (deterministic)
/// outcomes precomputed; `tell` happens at virtual completion time.
struct RunningJob {
    job: EvalJob,
    outcomes: Vec<crate::eval::TrialOutcome>,
    start: Duration,
    end: Duration,
}

/// Drive a full HPO experiment through the sans-IO [`Session`] in
/// *virtual time*: the same steps × tasks cluster model as [`simulate`],
/// but the workload is generated online by `ask` and consumed by `tell`
/// — the paper's asynchronous dynamics (heterogeneous durations reorder
/// completions, the surrogate sees results out of submission order)
/// with no wall-clock sleeps and fully deterministic replay.
///
/// Scheduling: each free step greedily takes the next evaluation-granular
/// job; ties in completion time break by step index. With a 1×1 topology
/// this reduces to the sequential loop, so the history matches the
/// threaded driver's single-worker run bit-for-bit.
pub fn simulate_hpo(
    evaluator: &dyn Evaluator,
    hpo: &HpoConfig,
    cfg: &SimConfig,
) -> HpoSimResult {
    let steps = cfg.topology.steps;
    let mut session = Session::new(evaluator, hpo);
    let mut running: Vec<Option<RunningJob>> = Vec::new();
    running.resize_with(steps, || None);
    let mut free_at = vec![Duration::ZERO; steps];
    let mut step_busy = vec![Duration::ZERO; steps];
    let mut timeline = Vec::new();
    // Virtual clock: advances to each completion as it is consumed.
    let mut now = Duration::ZERO;

    loop {
        // Fill every idle step (in index order) with the next job. A
        // step freed in the past can only pick up work created *now*.
        for s in 0..steps {
            if running[s].is_some() {
                continue;
            }
            let Some(job) = session.ask_eval() else { break };
            // Outcomes are deterministic per (θ, trial, seed): compute
            // them at placement, deliver them at completion time.
            let outcomes: Vec<_> = job
                .trials
                .iter()
                .map(|&t| evaluator.run_trial(&job.theta, t, job.seed))
                .collect();
            let cost = EvalCost {
                trial_costs: outcomes.iter().map(|o| o.cost).collect(),
            };
            let d = eval_duration(&cost, cfg);
            let start = free_at[s].max(now);
            step_busy[s] += d;
            running[s] =
                Some(RunningJob { job, outcomes, start, end: start + d });
        }
        // Complete the earliest-finishing job (ties: lowest step).
        let Some(s) = earliest_running(&running) else { break };
        let rj = running[s].take().expect("selected step is running");
        now = rj.end;
        free_at[s] = rj.end;
        for (&t, o) in rj.job.trials.iter().zip(rj.outcomes) {
            session
                .tell(rj.job.id, t, o)
                .expect("simulated outcomes match asked trials");
        }
        timeline.push(SimEvent {
            eval_index: rj.job.id,
            step: s,
            start: rj.start,
            end: rj.end,
        });
    }

    timeline.sort_by_key(|e| (e.end, e.step, e.eval_index));
    HpoSimResult {
        history: session.into_history(),
        makespan: free_at.iter().copied().max().unwrap_or(Duration::ZERO),
        step_busy,
        timeline,
    }
}

/// Index of the running job with the earliest end (ties: lowest step).
fn earliest_running(running: &[Option<RunningJob>]) -> Option<usize> {
    running
        .iter()
        .enumerate()
        .filter_map(|(s, r)| r.as_ref().map(|r| (r.end, s)))
        .min()
        .map(|(_, s)| s)
}

/// Speedup of a topology vs the serial 1×1 baseline on the same workload.
pub fn speedup(evals: &[EvalCost], cfg: &SimConfig) -> f64 {
    let base_cfg = SimConfig {
        topology: Topology::new(1, 1),
        ..cfg.clone()
    };
    let base = simulate(evals, &base_cfg).makespan;
    let this = simulate(evals, cfg).makespan;
    base.as_secs_f64() / this.as_secs_f64().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    fn uniform_evals(n: usize, trials: usize, each_ms: u64) -> Vec<EvalCost> {
        (0..n)
            .map(|_| EvalCost {
                trial_costs: vec![ms(each_ms); trials],
            })
            .collect()
    }

    #[test]
    fn serial_makespan_is_total_work() {
        let evals = uniform_evals(10, 5, 100);
        let cfg = SimConfig::trial_parallel(Topology::new(1, 1));
        let r = simulate(&evals, &cfg);
        assert_eq!(r.makespan, ms(10 * 5 * 100));
    }

    #[test]
    fn trial_parallel_divides_by_tasks_when_divisible() {
        let evals = uniform_evals(4, 6, 100);
        let cfg = SimConfig::trial_parallel(Topology::new(1, 3));
        // 6 trials over 3 tasks = 2 rounds of 100ms per evaluation.
        assert_eq!(simulate(&evals, &cfg).makespan, ms(4 * 200));
    }

    #[test]
    fn trial_parallel_ceils_on_remainder() {
        let evals = uniform_evals(1, 5, 100);
        let cfg = SimConfig::trial_parallel(Topology::new(1, 3));
        // task 0 gets trials 0,3 -> 200ms; others 100-200ms.
        assert_eq!(simulate(&evals, &cfg).makespan, ms(200));
    }

    #[test]
    fn steps_share_nothing_and_slice_statically() {
        let evals = uniform_evals(6, 1, 100);
        let cfg = SimConfig::trial_parallel(Topology::new(2, 1));
        let r = simulate(&evals, &cfg);
        // Step 0 gets evals 0,2,4; step 1 gets 1,3,5.
        for e in &r.timeline {
            assert_eq!(e.step, e.eval_index % 2);
        }
        assert_eq!(r.makespan, ms(300));
        assert_eq!(r.step_busy, vec![ms(300), ms(300)]);
    }

    #[test]
    fn full_grid_speedup_reaches_two_orders_of_magnitude() {
        // Paper Fig. 8: 50 evaluations x 5 trials, 1x1 vs 16x6 = 96 procs
        // improves throughput by ~two orders of magnitude.
        let evals = uniform_evals(48, 5, 200); // 48 divisible by 16
        let cfg = SimConfig::trial_parallel(Topology::new(16, 6));
        let s = speedup(&evals, &cfg);
        assert!(s >= 45.0, "speedup {s}");
        // Perfect slicing bound: steps*ceil-trials effect caps at 16*3=48.
        assert!(s <= 96.0 + 1e-9);
    }

    #[test]
    fn data_parallel_scales_with_efficiency_discount() {
        let evals = uniform_evals(1, 1, 1000);
        let mk = |tasks| SimConfig {
            topology: Topology::new(1, tasks),
            mode: ParallelMode::DataParallel,
            data_efficiency: 0.8,
            sync_overhead: ms(10),
        };
        let t1 = simulate(&evals, &mk(1)).makespan;
        let t4 = simulate(&evals, &mk(4)).makespan;
        assert_eq!(t1, ms(1000));
        // 1000/(4*0.8) + 10 = 322.5ms
        assert!((t4.as_secs_f64() - 0.3225).abs() < 1e-6, "{t4:?}");
    }

    #[test]
    fn heterogeneous_costs_make_stragglers() {
        // One huge evaluation dominates its step; other steps idle.
        let mut evals = uniform_evals(8, 1, 10);
        evals[3].trial_costs = vec![ms(1000)];
        let cfg = SimConfig::trial_parallel(Topology::new(4, 1));
        let r = simulate(&evals, &cfg);
        // Step 3 holds eval 3 and 7 -> 1010ms; makespan bound by it.
        assert_eq!(r.makespan, ms(1010));
        let min_busy = r.step_busy.iter().min().unwrap();
        assert!(min_busy < &ms(1010));
    }

    #[test]
    fn virtual_time_hpo_completes_and_respects_causality() {
        use crate::eval::synthetic::SyntheticEvaluator;
        use crate::space::{ParamSpec, Space};

        let space = Space::new(vec![
            ParamSpec::new("a", 0, 24),
            ParamSpec::new("b", 0, 24),
        ]);
        let ev = SyntheticEvaluator::new(space, 11);
        let hpo = crate::optimizer::HpoConfig {
            max_evaluations: 20,
            n_init: 6,
            n_trials: 3,
            seed: 4,
            ..Default::default()
        };
        let cfg = SimConfig::trial_parallel(Topology::new(3, 2));
        let r = simulate_hpo(&ev, &hpo, &cfg);
        assert_eq!(r.history.len(), 20);
        assert_eq!(r.timeline.len(), 20);
        assert!(r.makespan > Duration::ZERO);
        // Busy time never exceeds the makespan, steps share nothing.
        for b in &r.step_busy {
            assert!(*b <= r.makespan);
        }
        // Provenance causality: everything a proposal saw completed
        // earlier in the recorded history.
        let pos: std::collections::HashMap<usize, usize> = r
            .history
            .records
            .iter()
            .enumerate()
            .map(|(i, rec)| (rec.id, i))
            .collect();
        for (i, rec) in r.history.records.iter().enumerate() {
            for p in &rec.provenance {
                assert!(pos[p] < i);
            }
        }
    }

    #[test]
    fn virtual_time_hpo_on_1x1_matches_serial_session() {
        use crate::eval::synthetic::SyntheticEvaluator;
        use crate::exec::session::{Ask, Session};
        use crate::eval::Evaluator;
        use crate::space::{ParamSpec, Space};

        let space = Space::new(vec![
            ParamSpec::new("a", 0, 20),
            ParamSpec::new("b", 0, 20),
        ]);
        let ev = SyntheticEvaluator::new(space, 3);
        let hpo = crate::optimizer::HpoConfig {
            max_evaluations: 14,
            n_init: 5,
            n_trials: 2,
            seed: 9,
            ..Default::default()
        };
        let sim = simulate_hpo(
            &ev,
            &hpo,
            &SimConfig::trial_parallel(Topology::new(1, 1)),
        );
        // Hand-rolled sequential ask/tell loop: identical decisions.
        let mut s = Session::new(&ev, &hpo);
        loop {
            match s.ask() {
                Ask::Trial(t) => {
                    let o = ev.run_trial(&t.theta, t.trial, t.seed);
                    s.tell(t.eval_id, t.trial, o).unwrap();
                }
                Ask::Done => break,
                Ask::Wait => unreachable!(),
            }
        }
        let h = s.into_history();
        assert_eq!(sim.history.len(), h.len());
        for (a, b) in sim.history.records.iter().zip(&h.records) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.theta, b.theta);
            assert_eq!(a.provenance, b.provenance);
            assert_eq!(
                a.summary.interval.center,
                b.summary.interval.center
            );
        }
    }

    #[test]
    fn timeline_sorted_by_completion() {
        let evals = uniform_evals(10, 2, 37);
        let cfg = SimConfig::trial_parallel(Topology::new(3, 2));
        let r = simulate(&evals, &cfg);
        for w in r.timeline.windows(2) {
            assert!(w[0].end <= w[1].end);
        }
        assert_eq!(r.timeline.len(), 10);
    }
}
