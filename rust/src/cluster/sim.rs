//! Deterministic virtual-time simulator of one HPO job on a steps × tasks
//! topology — regenerates Fig. 8 without wall-clock sleeps.
//!
//! Three entry points share the cluster model: [`simulate`] replays a
//! fixed, pre-generated workload (the paper's static slicing),
//! [`simulate_hpo`] drives a live `exec::Session` ask → tell loop in
//! virtual time — asynchronous surrogate dynamics with deterministic
//! replay and zero sleeps — and [`simulate_chaos`] is the fault-injected
//! generalization (DESIGN.md §12): the same event loop with a
//! [`FaultPlan`] killing, slowing, preempting, and restarting virtual
//! workers at chosen virtual times, recovering through the *real*
//! machinery ([`Session::requeue`] and the checkpoint JSON wire), and
//! emitting queueing metrics ([`SimMetrics`]).
//!
//! [`simulate_hpo`] is literally `simulate_chaos` with an empty plan, so
//! the chaos path is exercised by every existing speedup/causality test.
//!
//! Semantics follow §IV (Feature 3) exactly:
//!   * Hyperparameter evaluations are assigned to steps by Python-style
//!     slicing: step `s` executes evaluations `s, s+steps, s+2·steps, ...`
//!     (the paper's static slicing of the randomly generated sets).
//!   * Trial-parallel: within a step, trial `t` of an evaluation runs on
//!     task `t mod tasks`; tasks run their trial slices sequentially, the
//!     evaluation completes when the slowest task finishes.
//!   * Data-parallel: all tasks cooperate on each trial; the trial's cost
//!     divides by an efficiency-discounted task count plus a per-trial
//!     synchronization overhead, and trials run sequentially.
//!   * Exclusive processors: a step's tasks are dedicated; steps never
//!     share processors (asserted by construction, tested).

use std::collections::{BTreeMap, BTreeSet};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::cluster::faults::{FaultPlan, TimedKind};
use crate::cluster::{ParallelMode, Topology};
use crate::eval::{Evaluator, TrialOutcome};
use crate::exec::driver::DEFAULT_MAX_RETRIES;
use crate::exec::session::{EvalJob, Session};
use crate::optimizer::{History, HpoConfig, RefitStats};
use crate::util::bench::BenchRun;

/// Per-evaluation input: the simulated durations of its N trials.
#[derive(Debug, Clone)]
pub struct EvalCost {
    /// One entry per trial (trial index = position).
    pub trial_costs: Vec<Duration>,
}

/// Simulated-cluster parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// steps × tasks layout being simulated.
    pub topology: Topology,
    /// Inner (per-step) parallelization mode.
    pub mode: ParallelMode,
    /// Parallel efficiency of data-parallel scaling (1.0 = perfect).
    pub data_efficiency: f64,
    /// Fixed per-trial synchronization overhead in data-parallel mode.
    pub sync_overhead: Duration,
}

impl SimConfig {
    /// Trial-parallel configuration with the paper's default efficiency
    /// and synchronization constants.
    pub fn trial_parallel(topology: Topology) -> Self {
        SimConfig {
            topology,
            mode: ParallelMode::TrialParallel,
            data_efficiency: 0.85,
            sync_overhead: Duration::from_millis(5),
        }
    }
}

/// One simulated evaluation completion.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimEvent {
    /// Index of the evaluation in the submitted workload.
    pub eval_index: usize,
    /// Step (outer worker) that executed it.
    pub step: usize,
    /// Virtual start time.
    pub start: Duration,
    /// Virtual completion time.
    pub end: Duration,
}

/// Outcome of simulating one whole job.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Job makespan (max step completion time).
    pub makespan: Duration,
    /// Busy time per step (for utilization analysis).
    pub step_busy: Vec<Duration>,
    /// Completion events sorted by end time.
    pub timeline: Vec<SimEvent>,
}

/// Duration of one evaluation on one step under the given inner mode.
pub fn eval_duration(cost: &EvalCost, cfg: &SimConfig) -> Duration {
    let tasks = cfg.topology.tasks_per_step;
    match cfg.mode {
        ParallelMode::TrialParallel => {
            // Slice trials over tasks; slowest task bounds the evaluation.
            let mut per_task = vec![Duration::ZERO; tasks];
            for (t, c) in cost.trial_costs.iter().enumerate() {
                per_task[t % tasks] += *c;
            }
            per_task.into_iter().max().unwrap_or(Duration::ZERO)
        }
        ParallelMode::DataParallel => {
            let scale = if tasks == 1 {
                1.0
            } else {
                1.0 / (tasks as f64 * cfg.data_efficiency)
            };
            cost.trial_costs
                .iter()
                .map(|c| {
                    let scaled = c.mul_f64(scale);
                    let overhead = if tasks > 1 {
                        cfg.sync_overhead
                    } else {
                        Duration::ZERO
                    };
                    scaled + overhead
                })
                .sum()
        }
    }
}

/// Simulate a whole job over `evals` (ordered as generated).
pub fn simulate(evals: &[EvalCost], cfg: &SimConfig) -> SimResult {
    let steps = cfg.topology.steps;
    let mut clock = vec![Duration::ZERO; steps];
    let mut timeline = Vec::with_capacity(evals.len());
    for (i, ev) in evals.iter().enumerate() {
        let step = i % steps; // paper's slicing by step id
        let d = eval_duration(ev, cfg);
        let start = clock[step];
        clock[step] += d;
        timeline.push(SimEvent { eval_index: i, step, start, end: clock[step] });
    }
    timeline.sort_by_key(|e| e.end);
    SimResult {
        makespan: clock.iter().copied().max().unwrap_or(Duration::ZERO),
        step_busy: clock,
        timeline,
    }
}

/// Outcome of a virtual-time HPO experiment ([`simulate_hpo`]).
#[derive(Debug, Clone)]
pub struct HpoSimResult {
    /// Evaluations recorded, in (virtual) completion order.
    pub history: History,
    /// Virtual makespan of the whole experiment.
    pub makespan: Duration,
    /// Busy time per step.
    pub step_busy: Vec<Duration>,
    /// Completion events sorted by end time (`eval_index` = eval id).
    pub timeline: Vec<SimEvent>,
}

/// Drive a full HPO experiment through the sans-IO [`Session`] in
/// *virtual time*: the same steps × tasks cluster model as [`simulate`],
/// but the workload is generated online by `ask` and consumed by `tell`
/// — the paper's asynchronous dynamics (heterogeneous durations reorder
/// completions, the surrogate sees results out of submission order)
/// with no wall-clock sleeps and fully deterministic replay.
///
/// Scheduling: each free step greedily takes the next evaluation-granular
/// job; ties in completion time break by step index. With a 1×1 topology
/// this reduces to the sequential loop, so the history matches the
/// threaded driver's single-worker run bit-for-bit.
///
/// This is [`simulate_chaos`] with an empty [`FaultPlan`].
pub fn simulate_hpo(
    evaluator: &dyn Evaluator,
    hpo: &HpoConfig,
    cfg: &SimConfig,
) -> HpoSimResult {
    let r =
        simulate_chaos(evaluator, hpo, &ChaosConfig::fault_free(cfg.clone()))
            .expect("a fault-free simulation cannot fail");
    let mut timeline: Vec<SimEvent> = r
        .events
        .iter()
        .filter(|e| e.kind == ChaosEventKind::Finish)
        .map(|e| SimEvent {
            eval_index: e.eval.expect("finish events carry an eval id"),
            step: e.worker.expect("finish events carry a worker"),
            start: e.since,
            end: e.at,
        })
        .collect();
    timeline.sort_by_key(|e| (e.end, e.step, e.eval_index));
    HpoSimResult {
        history: r.history,
        makespan: r.metrics.makespan,
        step_busy: r.metrics.worker_busy,
        timeline,
    }
}

/// Configuration of a fault-injected simulation ([`simulate_chaos`]).
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    /// The cluster timing model (topology, inner mode, constants).
    pub sim: SimConfig,
    /// The fault schedule to inject (empty = fault-free).
    pub plan: FaultPlan,
    /// Crashes + lost results tolerated per evaluation before the run
    /// fails (preemptions and restarts are free — they are the
    /// scheduler's fault, not the job's).
    pub max_retries: usize,
}

impl ChaosConfig {
    /// A chaos config that injects nothing — [`simulate_hpo`]'s path.
    pub fn fault_free(sim: SimConfig) -> Self {
        ChaosConfig {
            sim,
            plan: FaultPlan::default(),
            max_retries: DEFAULT_MAX_RETRIES,
        }
    }
}

/// What happened at one point of a chaos simulation's event log.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChaosEventKind {
    /// A worker started (or re-started) executing an evaluation.
    Start,
    /// An evaluation completed and its outcomes were told.
    Finish,
    /// A running evaluation was killed (fraction-crash or worker crash).
    Crash,
    /// A worker was preempted (running work requeued for free).
    Preempt,
    /// An evaluation completed but its result was dropped in transit.
    Lost,
    /// A duplicated result delivery was rejected by the session.
    DuplicateRejected,
    /// Cluster-wide restart through the checkpoint JSON wire.
    Restart,
}

/// One entry of the (deterministic, bit-reproducible) chaos event log.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChaosEvent {
    /// Virtual time the event fired.
    pub at: Duration,
    /// Start of the execution segment the event ends (== `at` for
    /// events that don't end a segment: `Start`, idle `Preempt`,
    /// `DuplicateRejected`, `Restart`).
    pub since: Duration,
    /// Worker involved (`None` for cluster-wide restarts).
    pub worker: Option<usize>,
    /// Evaluation involved, if any.
    pub eval: Option<usize>,
    /// What happened.
    pub kind: ChaosEventKind,
}

/// Queueing + fault metrics of one chaos run, in the shape the
/// `hyppo-bench-v1` JSON pipe publishes (see [`SimMetrics::record_into`]).
#[derive(Debug, Clone, PartialEq)]
pub struct SimMetrics {
    /// Virtual time of the last event.
    pub makespan: Duration,
    /// Worker-time of execution segments whose results were recorded.
    pub useful_work: Duration,
    /// Worker-time thrown away by crashes, preemptions, lost results,
    /// and restarts.
    pub wasted_work: Duration,
    /// `wasted / (useful + wasted)` (0 when no work ran).
    pub wasted_work_fraction: f64,
    /// `(useful + wasted) / (workers · makespan)`.
    pub utilization: f64,
    /// `makespan · workers / useful` — 1.0 means perfectly packed
    /// fault-free execution, higher means idle or wasted capacity.
    pub makespan_over_ideal: f64,
    /// Busy (executing) time per worker, useful or not.
    pub worker_busy: Vec<Duration>,
    /// Queue depth over virtual time, recorded when it changes: number
    /// of evaluations materialized by the session but neither running
    /// nor finished-and-buffered behind the init barrier.
    pub queue_depth: Vec<(Duration, usize)>,
    /// Max of `queue_depth`.
    pub max_queue_depth: usize,
    /// Fraction-scheduled + timed worker crashes that fired.
    pub crashes: usize,
    /// Preemption faults that fired.
    pub preemptions: usize,
    /// Placements slowed by a straggler window.
    pub straggled_evals: usize,
    /// Completions whose results were dropped in transit.
    pub lost_results: usize,
    /// Duplicate deliveries rejected by the session.
    pub duplicates_rejected: usize,
    /// `Session::requeue` calls (crashes + preemptions + losses).
    pub requeues: usize,
    /// Cluster-wide restarts executed.
    pub restarts: usize,
}

impl SimMetrics {
    fn new(workers: usize) -> Self {
        SimMetrics {
            makespan: Duration::ZERO,
            useful_work: Duration::ZERO,
            wasted_work: Duration::ZERO,
            wasted_work_fraction: 0.0,
            utilization: 0.0,
            makespan_over_ideal: 0.0,
            worker_busy: vec![Duration::ZERO; workers],
            queue_depth: Vec::new(),
            max_queue_depth: 0,
            crashes: 0,
            preemptions: 0,
            straggled_evals: 0,
            lost_results: 0,
            duplicates_rejected: 0,
            requeues: 0,
            restarts: 0,
        }
    }

    fn finalize(&mut self) {
        let useful = self.useful_work.as_secs_f64();
        let wasted = self.wasted_work.as_secs_f64();
        let busy = useful + wasted;
        self.wasted_work_fraction =
            if busy > 0.0 { wasted / busy } else { 0.0 };
        let capacity =
            self.makespan.as_secs_f64() * self.worker_busy.len() as f64;
        self.utilization = if capacity > 0.0 { busy / capacity } else { 0.0 };
        self.makespan_over_ideal =
            if useful > 0.0 { capacity / useful } else { 0.0 };
    }

    /// Publish every metric into a [`BenchRun`]'s `derived` map (the
    /// `hyppo-bench-v1` schema; `hyppo simulate --json` and `bench_sim`
    /// both go through here, and CI gates on `wasted_work_fraction`).
    pub fn record_into(&self, run: &mut BenchRun) {
        run.metric("makespan_ms", self.makespan.as_secs_f64() * 1e3);
        run.metric("useful_work_ms", self.useful_work.as_secs_f64() * 1e3);
        run.metric("wasted_work_ms", self.wasted_work.as_secs_f64() * 1e3);
        run.metric("wasted_work_fraction", self.wasted_work_fraction);
        run.metric("utilization", self.utilization);
        run.metric("makespan_over_ideal", self.makespan_over_ideal);
        run.metric("max_queue_depth", self.max_queue_depth as f64);
        run.metric("crashes", self.crashes as f64);
        run.metric("preemptions", self.preemptions as f64);
        run.metric("straggled_evals", self.straggled_evals as f64);
        run.metric("lost_results", self.lost_results as f64);
        run.metric(
            "duplicates_rejected",
            self.duplicates_rejected as f64,
        );
        run.metric("requeues", self.requeues as f64);
        run.metric("restarts", self.restarts as f64);
    }
}

/// Outcome of a fault-injected virtual-time run.
#[derive(Debug, Clone)]
pub struct ChaosResult {
    /// Evaluations recorded, in (virtual) completion order.
    pub history: History,
    /// Surrogate refit counters (bit-compared against fault-free runs
    /// by the equivalence tests).
    pub refits: RefitStats,
    /// The full event log, in firing order — bit-reproducible from
    /// (config seed, fault plan, topology).
    pub events: Vec<ChaosEvent>,
    /// Queueing + fault metrics.
    pub metrics: SimMetrics,
}

/// A virtual worker's state between events.
enum WorkerState {
    Idle,
    Down { until: Duration },
    Busy(RunningEval),
}

/// One evaluation executing on a virtual worker, outcomes precomputed
/// (deterministic per (θ, trial, seed)), delivered at completion time.
struct RunningEval {
    job: EvalJob,
    outcomes: Vec<TrialOutcome>,
    start: Duration,
    end: Duration,
    /// Scheduled fraction-crash time (`start + frac·duration`), if any.
    crash_at: Option<Duration>,
}

/// Count a consumed retry for `id`; fail the run past the budget.
fn bump_retry(
    retries: &mut BTreeMap<usize, usize>,
    id: usize,
    max: usize,
) -> Result<()> {
    let n = retries.entry(id).or_insert(0);
    *n += 1;
    if *n > max {
        bail!(
            "evaluation {id} lost {n} attempt(s), exceeding \
             max_retries = {max}"
        );
    }
    Ok(())
}

/// Drive a full HPO experiment through the sans-IO [`Session`] on a
/// virtual cluster while injecting a [`FaultPlan`] (DESIGN.md §12).
///
/// Recovery is real, not mocked: killed evaluations go through
/// [`Session::requeue`] (FIFO hand-out re-issues them before new
/// proposals, usually to the worker that just freed), and cluster-wide
/// restarts pass the session through the actual checkpoint JSON wire
/// (`snapshot → to_json_string → from_json_str → restore`).
///
/// Event ordering is total and deterministic: the next event is the
/// lexicographic minimum of `(time, class, worker)` where class ranks
/// timed faults < fraction-crashes < completions < down-worker wakes;
/// idle workers refill in index order after every event. Hence the
/// whole run — event log, history, metrics — is bit-reproducible from
/// (HpoConfig seed, fault plan, topology).
pub fn simulate_chaos(
    evaluator: &dyn Evaluator,
    hpo: &HpoConfig,
    cfg: &ChaosConfig,
) -> Result<ChaosResult> {
    let plan = cfg.plan.compile()?;
    let steps = cfg.sim.topology.steps;
    let mut session = Session::new(evaluator, hpo);
    let mut workers: Vec<WorkerState> =
        (0..steps).map(|_| WorkerState::Idle).collect();
    let mut events: Vec<ChaosEvent> = Vec::new();
    let mut m = SimMetrics::new(steps);
    let mut now = Duration::ZERO;
    let mut timed_idx = 0usize;
    // Crash-once bookkeeping: an evaluation gets at most one scheduled
    // fraction-crash, marked at placement (it survives restarts).
    let mut crashed: BTreeSet<usize> = BTreeSet::new();
    let mut dup_fired: BTreeSet<usize> = BTreeSet::new();
    let mut lose_left: BTreeMap<usize, usize> = plan.lose.clone();
    let mut retries: BTreeMap<usize, usize> = BTreeMap::new();
    // Completed-but-unrecorded evaluations (init barrier), tracked for
    // the queue-depth metric only.
    let mut buffered = 0usize;
    let mut last_depth = usize::MAX;

    loop {
        // 1. Fill idle workers in index order with evaluation-granular
        //    jobs. A requeued evaluation re-emerges here first (FIFO).
        let mut busy = workers
            .iter()
            .filter(|w| matches!(w, WorkerState::Busy(_)))
            .count();
        for s in 0..steps {
            if !matches!(workers[s], WorkerState::Idle) {
                continue;
            }
            let Some(job) = session.ask_eval() else { break };
            let outcomes: Vec<TrialOutcome> = job
                .trials
                .iter()
                .map(|&t| evaluator.run_trial(&job.theta, t, job.seed))
                .collect();
            let cost = EvalCost {
                trial_costs: outcomes.iter().map(|o| o.cost).collect(),
            };
            let mut d = eval_duration(&cost, &cfg.sim);
            // Straggler windows matching (worker, start time) multiply
            // the duration of work *started* inside them.
            let factor: f64 = plan
                .straggle
                .iter()
                .filter(|w| w.worker == s && now >= w.from && now < w.until)
                .map(|w| w.factor)
                .product();
            if factor != 1.0 {
                d = d.mul_f64(factor);
                m.straggled_evals += 1;
            }
            let crash_frac =
                plan.crash_eval.get(&job.id).copied().or(plan.crash_all);
            let crash_at = match crash_frac {
                Some(frac) if crashed.insert(job.id) => {
                    Some(now + d.mul_f64(frac))
                }
                _ => None,
            };
            events.push(ChaosEvent {
                at: now,
                since: now,
                worker: Some(s),
                eval: Some(job.id),
                kind: ChaosEventKind::Start,
            });
            workers[s] = WorkerState::Busy(RunningEval {
                job,
                outcomes,
                start: now,
                end: now + d,
                crash_at,
            });
            busy += 1;
        }
        // 2. Sample queue depth (recorded on change).
        let depth = session.in_flight().saturating_sub(busy + buffered);
        if depth != last_depth {
            m.queue_depth.push((now, depth));
            m.max_queue_depth = m.max_queue_depth.max(depth);
            last_depth = depth;
        }
        // 3. Done when the budget is recorded and nothing is running
        //    (unconsumed timed faults past the end are ignored).
        if busy == 0 && session.is_complete() {
            break;
        }
        // 4. Next event: lexicographic min of (time, class, worker).
        let mut cands: Vec<(Duration, u8, usize)> = Vec::new();
        if let Some(tf) = plan.timed.get(timed_idx) {
            cands.push((tf.at.max(now), 0, 0));
        }
        for (s, w) in workers.iter().enumerate() {
            match w {
                WorkerState::Busy(r) => {
                    if let Some(c) = r.crash_at {
                        cands.push((c, 1, s));
                    }
                    cands.push((r.end, 2, s));
                }
                WorkerState::Down { until } => cands.push((*until, 3, s)),
                WorkerState::Idle => {}
            }
        }
        let Some(&(t, class, s)) = cands.iter().min() else {
            bail!(
                "chaos simulation starved: no running work, no pending \
                 faults, and the session is not complete"
            );
        };
        now = t;
        match class {
            // A timed cluster-level fault fires.
            0 => {
                let tf = plan.timed[timed_idx];
                timed_idx += 1;
                match tf.kind {
                    TimedKind::CrashWorker { worker } => {
                        if worker < steps
                            && matches!(
                                workers[worker],
                                WorkerState::Busy(_)
                            )
                        {
                            let WorkerState::Busy(r) = std::mem::replace(
                                &mut workers[worker],
                                WorkerState::Idle,
                            ) else {
                                unreachable!()
                            };
                            m.wasted_work += now - r.start;
                            m.worker_busy[worker] += now - r.start;
                            m.crashes += 1;
                            bump_retry(
                                &mut retries,
                                r.job.id,
                                cfg.max_retries,
                            )?;
                            session.requeue(r.job.id)?;
                            m.requeues += 1;
                            events.push(ChaosEvent {
                                at: now,
                                since: r.start,
                                worker: Some(worker),
                                eval: Some(r.job.id),
                                kind: ChaosEventKind::Crash,
                            });
                        }
                    }
                    TimedKind::Preempt { worker, down } => {
                        if worker < steps {
                            let prev = std::mem::replace(
                                &mut workers[worker],
                                WorkerState::Down { until: now + down },
                            );
                            if let WorkerState::Busy(r) = prev {
                                m.wasted_work += now - r.start;
                                m.worker_busy[worker] += now - r.start;
                                // Preemption is free: no retry consumed.
                                session.requeue(r.job.id)?;
                                m.requeues += 1;
                                events.push(ChaosEvent {
                                    at: now,
                                    since: r.start,
                                    worker: Some(worker),
                                    eval: Some(r.job.id),
                                    kind: ChaosEventKind::Preempt,
                                });
                            } else {
                                events.push(ChaosEvent {
                                    at: now,
                                    since: now,
                                    worker: Some(worker),
                                    eval: None,
                                    kind: ChaosEventKind::Preempt,
                                });
                            }
                            m.preemptions += 1;
                        }
                    }
                    TimedKind::Restart { down } => {
                        for (w_idx, w) in workers.iter_mut().enumerate() {
                            let prev = std::mem::replace(
                                w,
                                WorkerState::Down { until: now + down },
                            );
                            if let WorkerState::Busy(r) = prev {
                                m.wasted_work += now - r.start;
                                m.worker_busy[w_idx] += now - r.start;
                            }
                        }
                        // The real recovery path: snapshot → JSON wire →
                        // restore. Un-recorded tells are lost; restored
                        // in-flight evaluations re-run from trial 0.
                        let ckpt = session.snapshot().wire_roundtrip()?;
                        session =
                            Session::restore(evaluator, hpo, ckpt)?;
                        buffered = 0;
                        m.restarts += 1;
                        events.push(ChaosEvent {
                            at: now,
                            since: now,
                            worker: None,
                            eval: None,
                            kind: ChaosEventKind::Restart,
                        });
                    }
                }
            }
            // A scheduled fraction-crash kills a running evaluation.
            1 => {
                let WorkerState::Busy(r) = std::mem::replace(
                    &mut workers[s],
                    WorkerState::Idle,
                ) else {
                    unreachable!()
                };
                m.wasted_work += now - r.start;
                m.worker_busy[s] += now - r.start;
                m.crashes += 1;
                bump_retry(&mut retries, r.job.id, cfg.max_retries)?;
                session.requeue(r.job.id)?;
                m.requeues += 1;
                events.push(ChaosEvent {
                    at: now,
                    since: r.start,
                    worker: Some(s),
                    eval: Some(r.job.id),
                    kind: ChaosEventKind::Crash,
                });
            }
            // An evaluation completes (or its result is lost in transit).
            2 => {
                let WorkerState::Busy(r) = std::mem::replace(
                    &mut workers[s],
                    WorkerState::Idle,
                ) else {
                    unreachable!()
                };
                let d = now - r.start;
                m.worker_busy[s] += d;
                let lost = lose_left
                    .get_mut(&r.job.id)
                    .filter(|n| **n > 0)
                    .map(|n| *n -= 1)
                    .is_some();
                if lost {
                    m.wasted_work += d;
                    m.lost_results += 1;
                    bump_retry(&mut retries, r.job.id, cfg.max_retries)?;
                    session.requeue(r.job.id)?;
                    m.requeues += 1;
                    events.push(ChaosEvent {
                        at: now,
                        since: r.start,
                        worker: Some(s),
                        eval: Some(r.job.id),
                        kind: ChaosEventKind::Lost,
                    });
                } else {
                    m.useful_work += d;
                    let mut recorded = 0usize;
                    let mut extended = 0usize;
                    for (&t, o) in r.job.trials.iter().zip(&r.outcomes) {
                        let told = session
                            .tell(r.job.id, t, o.clone())
                            .expect(
                                "simulated outcomes match asked trials",
                            );
                        recorded += told.recorded;
                        extended += told.extended;
                    }
                    // Init-barrier buffer tracking (queue-depth metric):
                    // a flush empties the buffer; a complete-but-silent
                    // evaluation joined it.
                    if recorded > 1 {
                        buffered = 0;
                    } else if recorded == 0 && extended == 0 {
                        buffered += 1;
                    }
                    events.push(ChaosEvent {
                        at: now,
                        since: r.start,
                        worker: Some(s),
                        eval: Some(r.job.id),
                        kind: ChaosEventKind::Finish,
                    });
                    if plan.duplicate.contains(&r.job.id)
                        && dup_fired.insert(r.job.id)
                    {
                        // Deliver the first trial outcome again; the
                        // session must reject it (duplicate-or-unknown).
                        let dup = session.tell(
                            r.job.id,
                            r.job.trials[0],
                            r.outcomes[0].clone(),
                        );
                        if dup.is_ok() {
                            bail!(
                                "duplicate outcome for evaluation {} \
                                 was accepted",
                                r.job.id
                            );
                        }
                        m.duplicates_rejected += 1;
                        events.push(ChaosEvent {
                            at: now,
                            since: now,
                            worker: Some(s),
                            eval: Some(r.job.id),
                            kind: ChaosEventKind::DuplicateRejected,
                        });
                    }
                }
            }
            // A down worker comes back.
            _ => workers[s] = WorkerState::Idle,
        }
    }

    m.makespan = now;
    m.finalize();
    let refits = session.stats();
    Ok(ChaosResult {
        history: session.into_history(),
        refits,
        events,
        metrics: m,
    })
}

/// Speedup of a topology vs the serial 1×1 baseline on the same workload.
pub fn speedup(evals: &[EvalCost], cfg: &SimConfig) -> f64 {
    let base_cfg = SimConfig {
        topology: Topology::new(1, 1),
        ..cfg.clone()
    };
    let base = simulate(evals, &base_cfg).makespan;
    let this = simulate(evals, cfg).makespan;
    base.as_secs_f64() / this.as_secs_f64().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    fn uniform_evals(n: usize, trials: usize, each_ms: u64) -> Vec<EvalCost> {
        (0..n)
            .map(|_| EvalCost {
                trial_costs: vec![ms(each_ms); trials],
            })
            .collect()
    }

    #[test]
    fn serial_makespan_is_total_work() {
        let evals = uniform_evals(10, 5, 100);
        let cfg = SimConfig::trial_parallel(Topology::new(1, 1));
        let r = simulate(&evals, &cfg);
        assert_eq!(r.makespan, ms(10 * 5 * 100));
    }

    #[test]
    fn trial_parallel_divides_by_tasks_when_divisible() {
        let evals = uniform_evals(4, 6, 100);
        let cfg = SimConfig::trial_parallel(Topology::new(1, 3));
        // 6 trials over 3 tasks = 2 rounds of 100ms per evaluation.
        assert_eq!(simulate(&evals, &cfg).makespan, ms(4 * 200));
    }

    #[test]
    fn trial_parallel_ceils_on_remainder() {
        let evals = uniform_evals(1, 5, 100);
        let cfg = SimConfig::trial_parallel(Topology::new(1, 3));
        // task 0 gets trials 0,3 -> 200ms; others 100-200ms.
        assert_eq!(simulate(&evals, &cfg).makespan, ms(200));
    }

    #[test]
    fn steps_share_nothing_and_slice_statically() {
        let evals = uniform_evals(6, 1, 100);
        let cfg = SimConfig::trial_parallel(Topology::new(2, 1));
        let r = simulate(&evals, &cfg);
        // Step 0 gets evals 0,2,4; step 1 gets 1,3,5.
        for e in &r.timeline {
            assert_eq!(e.step, e.eval_index % 2);
        }
        assert_eq!(r.makespan, ms(300));
        assert_eq!(r.step_busy, vec![ms(300), ms(300)]);
    }

    #[test]
    fn full_grid_speedup_reaches_two_orders_of_magnitude() {
        // Paper Fig. 8: 50 evaluations x 5 trials, 1x1 vs 16x6 = 96 procs
        // improves throughput by ~two orders of magnitude.
        let evals = uniform_evals(48, 5, 200); // 48 divisible by 16
        let cfg = SimConfig::trial_parallel(Topology::new(16, 6));
        let s = speedup(&evals, &cfg);
        assert!(s >= 45.0, "speedup {s}");
        // Perfect slicing bound: steps*ceil-trials effect caps at 16*3=48.
        assert!(s <= 96.0 + 1e-9);
    }

    #[test]
    fn data_parallel_scales_with_efficiency_discount() {
        let evals = uniform_evals(1, 1, 1000);
        let mk = |tasks| SimConfig {
            topology: Topology::new(1, tasks),
            mode: ParallelMode::DataParallel,
            data_efficiency: 0.8,
            sync_overhead: ms(10),
        };
        let t1 = simulate(&evals, &mk(1)).makespan;
        let t4 = simulate(&evals, &mk(4)).makespan;
        assert_eq!(t1, ms(1000));
        // 1000/(4*0.8) + 10 = 322.5ms
        assert!((t4.as_secs_f64() - 0.3225).abs() < 1e-6, "{t4:?}");
    }

    #[test]
    fn heterogeneous_costs_make_stragglers() {
        // One huge evaluation dominates its step; other steps idle.
        let mut evals = uniform_evals(8, 1, 10);
        evals[3].trial_costs = vec![ms(1000)];
        let cfg = SimConfig::trial_parallel(Topology::new(4, 1));
        let r = simulate(&evals, &cfg);
        // Step 3 holds eval 3 and 7 -> 1010ms; makespan bound by it.
        assert_eq!(r.makespan, ms(1010));
        let min_busy = r.step_busy.iter().min().unwrap();
        assert!(min_busy < &ms(1010));
    }

    #[test]
    fn virtual_time_hpo_completes_and_respects_causality() {
        use crate::eval::synthetic::SyntheticEvaluator;
        use crate::space::{ParamSpec, Space};

        let space = Space::new(vec![
            ParamSpec::new("a", 0, 24),
            ParamSpec::new("b", 0, 24),
        ]);
        let ev = SyntheticEvaluator::new(space, 11);
        let hpo = crate::optimizer::HpoConfig {
            max_evaluations: 20,
            n_init: 6,
            n_trials: 3,
            seed: 4,
            ..Default::default()
        };
        let cfg = SimConfig::trial_parallel(Topology::new(3, 2));
        let r = simulate_hpo(&ev, &hpo, &cfg);
        assert_eq!(r.history.len(), 20);
        assert_eq!(r.timeline.len(), 20);
        assert!(r.makespan > Duration::ZERO);
        // Busy time never exceeds the makespan, steps share nothing.
        for b in &r.step_busy {
            assert!(*b <= r.makespan);
        }
        // Provenance causality: everything a proposal saw completed
        // earlier in the recorded history.
        let pos: std::collections::HashMap<usize, usize> = r
            .history
            .records
            .iter()
            .enumerate()
            .map(|(i, rec)| (rec.id, i))
            .collect();
        for (i, rec) in r.history.records.iter().enumerate() {
            for p in &rec.provenance {
                assert!(pos[p] < i);
            }
        }
    }

    #[test]
    fn virtual_time_hpo_on_1x1_matches_serial_session() {
        use crate::eval::synthetic::SyntheticEvaluator;
        use crate::exec::session::{Ask, Session};
        use crate::eval::Evaluator;
        use crate::space::{ParamSpec, Space};

        let space = Space::new(vec![
            ParamSpec::new("a", 0, 20),
            ParamSpec::new("b", 0, 20),
        ]);
        let ev = SyntheticEvaluator::new(space, 3);
        let hpo = crate::optimizer::HpoConfig {
            max_evaluations: 14,
            n_init: 5,
            n_trials: 2,
            seed: 9,
            ..Default::default()
        };
        let sim = simulate_hpo(
            &ev,
            &hpo,
            &SimConfig::trial_parallel(Topology::new(1, 1)),
        );
        // Hand-rolled sequential ask/tell loop: identical decisions.
        let mut s = Session::new(&ev, &hpo);
        loop {
            match s.ask() {
                Ask::Trial(t) => {
                    let o = ev.run_trial(&t.theta, t.trial, t.seed);
                    s.tell(t.eval_id, t.trial, o).unwrap();
                }
                Ask::Done => break,
                Ask::Wait => unreachable!(),
            }
        }
        let h = s.into_history();
        assert_eq!(sim.history.len(), h.len());
        for (a, b) in sim.history.records.iter().zip(&h.records) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.theta, b.theta);
            assert_eq!(a.provenance, b.provenance);
            assert_eq!(
                a.summary.interval.center,
                b.summary.interval.center
            );
        }
    }

    #[test]
    fn timeline_sorted_by_completion() {
        let evals = uniform_evals(10, 2, 37);
        let cfg = SimConfig::trial_parallel(Topology::new(3, 2));
        let r = simulate(&evals, &cfg);
        for w in r.timeline.windows(2) {
            assert!(w[0].end <= w[1].end);
        }
        assert_eq!(r.timeline.len(), 10);
    }
}
