//! Asynchronous nested-parallel HPO (paper Feature 3, Figs. 5-6).
//!
//! A pool of `steps` worker threads evaluates hyperparameter sets; each
//! evaluation's N trials are in turn spread over `tasks_per_step` inner
//! threads (trial parallelism) or executed sequentially with a
//! data-parallel cost discount. The coordinator:
//!
//!   1. runs the initial design across all workers (independent, as in
//!      the paper),
//!   2. then keeps every worker busy with surrogate proposals, refitting
//!      the surrogate after *each* completion (not per batch) — the
//!      asynchronous update of Fig. 6 — and tagging each proposal with the
//!      ids of the evaluations the surrogate had seen (provenance).
//!
//! Simulated backends report virtual costs; `time_scale` converts those to
//! real sleeps so completion *order* (and thus surrogate behaviour) matches
//! the heterogeneous-duration dynamics the paper exploits. Real backends
//! (HLO training) use `time_scale = 0` — their cost is genuine wall time.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::cluster::{ParallelMode, Topology};
use crate::eval::{aggregate, Evaluator, TrialOutcome};
use crate::optimizer::{
    initial_design, propose_next, EvalRecord, History, HpoConfig,
};
use crate::sampling::rng::Rng;

#[derive(Debug, Clone)]
pub struct AsyncConfig {
    pub hpo: HpoConfig,
    pub topology: Topology,
    pub mode: ParallelMode,
    /// Seconds of real sleep per second of reported virtual cost
    /// (e.g. 1e-4 compresses a 40 ms-cost trial to 4 µs).
    pub time_scale: f64,
}

struct Job {
    id: usize,
    theta: Vec<i64>,
    provenance: Vec<usize>,
    seed: u64,
}

struct Completion {
    id: usize,
    theta: Vec<i64>,
    provenance: Vec<usize>,
    outcomes: Vec<TrialOutcome>,
    worker: usize,
}

/// Run one evaluation's N trials with nested task parallelism.
fn run_evaluation(
    evaluator: &dyn Evaluator,
    theta: &[i64],
    n_trials: usize,
    seed: u64,
    tasks: usize,
    mode: ParallelMode,
    time_scale: f64,
) -> Vec<TrialOutcome> {
    let run_one = |trial: usize| {
        let o = evaluator.run_trial(theta, trial, seed);
        if time_scale > 0.0 {
            let scaled = o.cost.mul_f64(match mode {
                ParallelMode::TrialParallel => time_scale,
                // Data-parallel: the trial itself is sharded over tasks.
                ParallelMode::DataParallel => {
                    time_scale / (tasks as f64 * 0.85).max(1.0)
                }
            });
            std::thread::sleep(scaled);
        }
        o
    };

    if tasks <= 1 || n_trials <= 1 || mode == ParallelMode::DataParallel {
        return (0..n_trials).map(run_one).collect();
    }

    // Trial parallelism: slice trial indices over `tasks` inner threads
    // (the paper's MPI-rank slicing).
    let mut outcomes: Vec<Option<TrialOutcome>> = Vec::new();
    outcomes.resize_with(n_trials, || None);
    let slots = Mutex::new(&mut outcomes);
    std::thread::scope(|scope| {
        for task in 0..tasks.min(n_trials) {
            let slots = &slots;
            let run_one = &run_one;
            scope.spawn(move || {
                let mut t = task;
                while t < n_trials {
                    let o = run_one(t);
                    slots.lock().unwrap()[t] = Some(o);
                    t += tasks;
                }
            });
        }
    });
    outcomes.into_iter().map(|o| o.expect("trial ran")).collect()
}

/// The asynchronous HPO loop. Returns the history ordered by *completion*
/// time (the order the surrogate saw the results).
pub fn run_async(evaluator: &dyn Evaluator, cfg: &AsyncConfig) -> History {
    let space = evaluator.space().clone();
    let mut rng = Rng::new(cfg.hpo.seed);
    let n_workers = cfg.topology.steps;
    let tasks = cfg.topology.tasks_per_step;

    let queue: Arc<(Mutex<VecDeque<Option<Job>>>, std::sync::Condvar)> =
        Arc::new((Mutex::new(VecDeque::new()), std::sync::Condvar::new()));
    let (done_tx, done_rx) = mpsc::channel::<Completion>();

    let push = |q: &Arc<(Mutex<VecDeque<Option<Job>>>, std::sync::Condvar)>,
                job: Option<Job>| {
        let (lock, cv) = &**q;
        lock.lock().unwrap().push_back(job);
        cv.notify_one();
    };

    let mut history = History::default();
    std::thread::scope(|scope| {
        // --- workers ------------------------------------------------------
        for worker in 0..n_workers {
            let queue = Arc::clone(&queue);
            let done_tx = done_tx.clone();
            let evaluator: &dyn Evaluator = evaluator;
            let hpo = &cfg.hpo;
            let mode = cfg.mode;
            let time_scale = cfg.time_scale;
            scope.spawn(move || {
                loop {
                    let job = {
                        let (lock, cv) = &*queue;
                        let mut q = lock.lock().unwrap();
                        loop {
                            match q.pop_front() {
                                Some(j) => break j,
                                None => q = cv.wait(q).unwrap(),
                            }
                        }
                    };
                    let Some(job) = job else { break }; // poison pill
                    let outcomes = run_evaluation(
                        evaluator,
                        &job.theta,
                        hpo.n_trials,
                        job.seed,
                        tasks,
                        mode,
                        time_scale,
                    );
                    let _ = done_tx.send(Completion {
                        id: job.id,
                        theta: job.theta,
                        provenance: job.provenance,
                        outcomes,
                        worker,
                    });
                }
            });
        }
        drop(done_tx);

        // --- coordinator ---------------------------------------------------
        let budget = cfg.hpo.max_evaluations;
        let init = initial_design(&space, &cfg.hpo, &mut rng);
        let mut next_id = 0;
        let mut submitted = 0usize;
        for theta in init.into_iter().take(budget) {
            push(&queue, Some(Job {
                id: next_id,
                theta,
                provenance: vec![],
                seed: rng.next_u64(),
            }));
            next_id += 1;
            submitted += 1;
        }

        // Wait for the whole initial design (paper: surrogate modeling
        // starts once the initial evaluations are in).
        let mut completed = 0usize;
        let mut pending: Vec<Completion> = Vec::new();
        while completed < submitted.min(budget) {
            let c = done_rx.recv().expect("workers alive");
            completed += 1;
            pending.push(c);
        }
        // Record initial design in completion order.
        pending.sort_by_key(|c| c.id);
        for c in pending.drain(..) {
            record(&mut history, evaluator, &cfg.hpo, c);
        }

        // Adaptive phase: keep all workers busy; refit per completion.
        let mut iter = 0usize;
        let in_flight_target = n_workers.min(budget.saturating_sub(submitted));
        for _ in 0..in_flight_target {
            let theta =
                propose_next(&space, &history, &cfg.hpo, iter, &mut rng);
            iter += 1;
            push(&queue, Some(Job {
                id: next_id,
                theta,
                provenance: history.records.iter().map(|r| r.id).collect(),
                seed: rng.next_u64(),
            }));
            next_id += 1;
            submitted += 1;
        }
        let mut in_flight = in_flight_target;
        while in_flight > 0 {
            let c = done_rx.recv().expect("workers alive");
            in_flight -= 1;
            record(&mut history, evaluator, &cfg.hpo, c);
            if submitted < budget {
                // Asynchronous update: refit NOW on everything completed,
                // propose, resubmit without waiting for peers (Fig. 6).
                let theta = propose_next(
                    &space, &history, &cfg.hpo, iter, &mut rng,
                );
                iter += 1;
                push(&queue, Some(Job {
                    id: next_id,
                    theta,
                    provenance: history
                        .records
                        .iter()
                        .map(|r| r.id)
                        .collect(),
                    seed: rng.next_u64(),
                }));
                next_id += 1;
                submitted += 1;
                in_flight += 1;
            }
        }

        // Poison pills.
        for _ in 0..n_workers {
            push(&queue, None);
        }
    });
    history
}

fn record(
    history: &mut History,
    evaluator: &dyn Evaluator,
    hpo: &HpoConfig,
    c: Completion,
) {
    let summary = aggregate(evaluator, &c.theta, &c.outcomes, hpo.weights);
    history.records.push(EvalRecord {
        id: c.id,
        n_params: evaluator.n_params(&c.theta),
        theta: c.theta,
        summary,
        provenance: c.provenance,
    });
    let _ = c.worker;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::synthetic::SyntheticEvaluator;
    use crate::space::{ParamSpec, Space};
    use crate::uq::UqWeights;
    use std::collections::HashSet;

    fn evaluator() -> SyntheticEvaluator {
        let space = Space::new(vec![
            ParamSpec::new("a", 0, 24),
            ParamSpec::new("b", 0, 24),
            ParamSpec::new("c", 0, 24),
        ]);
        let mut ev = SyntheticEvaluator::new(space, 7);
        ev.t_dropout = 5;
        ev
    }

    fn config(workers: usize, tasks: usize, budget: usize) -> AsyncConfig {
        AsyncConfig {
            hpo: HpoConfig {
                max_evaluations: budget,
                n_init: 8,
                n_trials: 4,
                weights: UqWeights::default_paper(),
                seed: 3,
                ..Default::default()
            },
            topology: Topology::new(workers, tasks),
            mode: ParallelMode::TrialParallel,
            time_scale: 2e-5, // 40ms virtual -> ~1µs real
        }
    }

    #[test]
    fn completes_budget_with_unique_ids() {
        let ev = evaluator();
        let h = run_async(&ev, &config(4, 3, 30));
        assert_eq!(h.len(), 30);
        let ids: HashSet<usize> =
            h.records.iter().map(|r| r.id).collect();
        assert_eq!(ids.len(), 30);
        for r in &h.records {
            assert!(ev.space().contains(&r.theta));
        }
    }

    #[test]
    fn provenance_respects_async_causality() {
        let ev = evaluator();
        let h = run_async(&ev, &config(4, 1, 32));
        // Completion order: position of each id in the history.
        let pos: std::collections::HashMap<usize, usize> = h
            .records
            .iter()
            .enumerate()
            .map(|(i, r)| (r.id, i))
            .collect();
        for (i, r) in h.records.iter().enumerate() {
            if r.provenance.is_empty() {
                continue; // initial design
            }
            // Everything in the provenance completed before this record.
            for p in &r.provenance {
                assert!(
                    pos[p] < i,
                    "eval {} lists {} which completed later",
                    r.id,
                    p
                );
            }
            // Surrogate saw at least the full initial design.
            assert!(r.provenance.len() >= 8);
        }
    }

    #[test]
    fn async_with_many_workers_still_converges() {
        let ev = evaluator();
        let h = run_async(&ev, &config(8, 2, 48));
        let trace = h.best_trace(0.0);
        assert!(
            trace.last().unwrap() < &trace[7],
            "async search did not improve on the initial design"
        );
    }

    #[test]
    fn single_worker_behaves_like_serial_budget() {
        let ev = evaluator();
        let h = run_async(&ev, &config(1, 1, 16));
        assert_eq!(h.len(), 16);
        // With one worker, provenance grows by exactly one per adaptive
        // evaluation (fully sequential).
        let adaptive: Vec<&EvalRecord> = h
            .records
            .iter()
            .filter(|r| !r.provenance.is_empty())
            .collect();
        for (k, r) in adaptive.iter().enumerate() {
            assert_eq!(r.provenance.len(), 8 + k);
        }
    }

    #[test]
    fn trial_parallel_nested_execution_correct() {
        // Nested inner threads must return all N outcomes in trial order.
        let ev = evaluator();
        let outs = run_evaluation(
            &ev,
            &[5, 5, 5],
            7,
            42,
            3,
            ParallelMode::TrialParallel,
            0.0,
        );
        assert_eq!(outs.len(), 7);
        // Deterministic per (theta, trial, seed): matches serial run.
        let serial: Vec<f64> =
            (0..7).map(|t| ev.run_trial(&[5, 5, 5], t, 42).loss).collect();
        let got: Vec<f64> = outs.iter().map(|o| o.loss).collect();
        assert_eq!(got, serial);
    }
}
