//! Asynchronous nested-parallel HPO (paper Feature 3, Figs. 5-6) —
//! compatibility surface over the `exec` driver.
//!
//! The worker-pool loop that used to live here (a pool of `steps` step
//! threads, each evaluation's N trials spread over `tasks_per_step`
//! inner threads, per-completion surrogate refits with provenance
//! tracking) moved to `exec`: the decisions live in the sans-IO
//! `exec::Session` (ask/tell state machine) and the threads in
//! `exec::driver`, which gained incremental refits, checkpoint/resume,
//! and sweep support along the way. `run_async` keeps the original
//! one-call API: in-memory, full budget, no checkpointing.
//!
//! Simulated backends report virtual costs; `time_scale` converts those
//! to real sleeps so completion *order* (and thus surrogate behaviour)
//! matches the heterogeneous-duration dynamics the paper exploits. Real
//! backends (HLO training) use `time_scale = 0` — their cost is genuine
//! wall time.

#[cfg(test)]
use crate::exec::driver::run_evaluation;

use crate::cluster::{ParallelMode, Topology};
use crate::eval::Evaluator;
use crate::exec::{run_experiment, ExecConfig};
use crate::optimizer::{History, HpoConfig};

/// Configuration of one asynchronous in-memory run.
#[derive(Debug, Clone)]
pub struct AsyncConfig {
    /// The HPO problem (budget, surrogate, seed, ...).
    pub hpo: HpoConfig,
    /// steps × tasks worker topology.
    pub topology: Topology,
    /// Inner (per-step) parallelization mode.
    pub mode: ParallelMode,
    /// Seconds of real sleep per second of reported virtual cost
    /// (e.g. 1e-4 compresses a 40 ms-cost trial to 4 µs).
    pub time_scale: f64,
}

/// The asynchronous HPO loop. Returns the history ordered by *completion*
/// time (the order the surrogate saw the results).
pub fn run_async(evaluator: &dyn Evaluator, cfg: &AsyncConfig) -> History {
    let exec_cfg = ExecConfig::new(
        cfg.hpo.clone(),
        cfg.topology,
        cfg.mode,
        cfg.time_scale,
    );
    run_experiment(evaluator, &exec_cfg)
        .expect("in-memory experiment performs no fallible I/O")
        .history
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::synthetic::SyntheticEvaluator;
    use crate::optimizer::EvalRecord;
    use crate::space::{ParamSpec, Space};
    use crate::uq::UqWeights;
    use std::collections::HashSet;

    fn evaluator() -> SyntheticEvaluator {
        let space = Space::new(vec![
            ParamSpec::new("a", 0, 24),
            ParamSpec::new("b", 0, 24),
            ParamSpec::new("c", 0, 24),
        ]);
        let mut ev = SyntheticEvaluator::new(space, 7);
        ev.t_dropout = 5;
        ev
    }

    fn config(workers: usize, tasks: usize, budget: usize) -> AsyncConfig {
        AsyncConfig {
            hpo: HpoConfig {
                max_evaluations: budget,
                n_init: 8,
                n_trials: 4,
                weights: UqWeights::default_paper(),
                seed: 3,
                ..Default::default()
            },
            topology: Topology::new(workers, tasks),
            mode: ParallelMode::TrialParallel,
            time_scale: 2e-5, // 40ms virtual -> ~1µs real
        }
    }

    #[test]
    fn completes_budget_with_unique_ids() {
        let ev = evaluator();
        let h = run_async(&ev, &config(4, 3, 30));
        assert_eq!(h.len(), 30);
        let ids: HashSet<usize> =
            h.records.iter().map(|r| r.id).collect();
        assert_eq!(ids.len(), 30);
        for r in &h.records {
            assert!(ev.space().contains(&r.theta));
        }
    }

    #[test]
    fn provenance_respects_async_causality() {
        let ev = evaluator();
        let h = run_async(&ev, &config(4, 1, 32));
        // Completion order: position of each id in the history.
        let pos: std::collections::HashMap<usize, usize> = h
            .records
            .iter()
            .enumerate()
            .map(|(i, r)| (r.id, i))
            .collect();
        for (i, r) in h.records.iter().enumerate() {
            if r.provenance.is_empty() {
                continue; // initial design
            }
            // Everything in the provenance completed before this record.
            for p in &r.provenance {
                assert!(
                    pos[p] < i,
                    "eval {} lists {} which completed later",
                    r.id,
                    p
                );
            }
            // Surrogate saw at least the full initial design.
            assert!(r.provenance.len() >= 8);
        }
    }

    #[test]
    fn async_with_many_workers_still_converges() {
        let ev = evaluator();
        let h = run_async(&ev, &config(8, 2, 48));
        let trace = h.best_trace(0.0);
        assert!(
            trace.last().unwrap() < &trace[7],
            "async search did not improve on the initial design"
        );
    }

    #[test]
    fn single_worker_behaves_like_serial_budget() {
        let ev = evaluator();
        let h = run_async(&ev, &config(1, 1, 16));
        assert_eq!(h.len(), 16);
        // With one worker, provenance grows by exactly one per adaptive
        // evaluation (fully sequential).
        let adaptive: Vec<&EvalRecord> = h
            .records
            .iter()
            .filter(|r| !r.provenance.is_empty())
            .collect();
        for (k, r) in adaptive.iter().enumerate() {
            assert_eq!(r.provenance.len(), 8 + k);
        }
    }

    /// Wraps a deterministic evaluator and panics (simulating a worker
    /// death) for the first `deaths` trials it is asked to run. After
    /// the budget is spent it behaves exactly like the inner evaluator,
    /// so a retried run must reproduce the clean run bit-for-bit.
    struct FlakyEvaluator {
        inner: SyntheticEvaluator,
        deaths_left: std::sync::atomic::AtomicUsize,
    }

    impl FlakyEvaluator {
        fn new(inner: SyntheticEvaluator, deaths: usize) -> Self {
            Self {
                inner,
                deaths_left: std::sync::atomic::AtomicUsize::new(deaths),
            }
        }
    }

    impl crate::eval::Evaluator for FlakyEvaluator {
        fn space(&self) -> &Space {
            self.inner.space()
        }

        fn run_trial(
            &self,
            theta: &[crate::space::Value],
            trial: usize,
            seed: u64,
        ) -> crate::eval::TrialOutcome {
            use std::sync::atomic::Ordering::SeqCst;
            let died = self
                .deaths_left
                .fetch_update(SeqCst, SeqCst, |n| n.checked_sub(1))
                .is_ok();
            if died {
                panic!("injected worker death");
            }
            self.inner.run_trial(theta, trial, seed)
        }

        fn n_params(&self, theta: &[crate::space::Value]) -> u64 {
            self.inner.n_params(theta)
        }
    }

    #[test]
    fn worker_deaths_are_requeued_without_deadlock() {
        // Three injected panics across a 4-worker pool: the run must
        // still complete the full budget with unique ids — no lost
        // evaluations, no double-tells, no hung coordinator.
        let ev = FlakyEvaluator::new(evaluator(), 3);
        let h = run_async(&ev, &config(4, 1, 24));
        assert_eq!(h.len(), 24);
        let ids: HashSet<usize> =
            h.records.iter().map(|r| r.id).collect();
        assert_eq!(ids.len(), 24);
    }

    #[test]
    fn retried_run_matches_clean_run_bit_for_bit() {
        // One worker: completion order is deterministic, so the flaky
        // run (2 deaths, then retries through Session::requeue) must
        // reproduce the clean history exactly.
        let cfg = config(1, 1, 14);
        let clean = run_async(&evaluator(), &cfg);

        let ev = FlakyEvaluator::new(evaluator(), 2);
        let exec_cfg = ExecConfig::new(
            cfg.hpo.clone(),
            cfg.topology,
            cfg.mode,
            cfg.time_scale,
        );
        let out = crate::exec::run_experiment(&ev, &exec_cfg)
            .expect("flaky run stays under max_retries");
        assert!(
            out.stats.requeues >= 1,
            "injected deaths were never requeued"
        );
        assert_eq!(out.history.len(), clean.len());
        for (a, b) in
            out.history.records.iter().zip(clean.records.iter())
        {
            assert_eq!(a.id, b.id);
            assert_eq!(a.theta, b.theta);
            assert_eq!(
                a.summary.trained_mean.to_bits(),
                b.summary.trained_mean.to_bits()
            );
        }
    }

    #[test]
    fn retry_budget_exhaustion_is_a_clean_error() {
        let ev = FlakyEvaluator::new(evaluator(), usize::MAX);
        let mut exec_cfg = ExecConfig::new(
            config(2, 1, 12).hpo,
            Topology::new(2, 1),
            ParallelMode::TrialParallel,
            2e-5,
        );
        exec_cfg.max_retries = 0;
        let err = crate::exec::run_experiment(&ev, &exec_cfg)
            .expect_err("an always-dying evaluator must fail the run");
        assert!(
            err.to_string().contains("max_retries"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn trial_parallel_nested_execution_correct() {
        // Nested inner threads must return all N outcomes in trial order.
        let ev = evaluator();
        let theta = crate::space::ints(&[5, 5, 5]);
        let trials: Vec<usize> = (0..7).collect();
        let outs = run_evaluation(
            &ev,
            &theta,
            &trials,
            42,
            3,
            ParallelMode::TrialParallel,
            0.0,
        );
        assert_eq!(outs.len(), 7);
        // Deterministic per (theta, trial, seed): matches serial run.
        let serial: Vec<f64> =
            (0..7).map(|t| ev.run_trial(&theta, t, 42).loss).collect();
        let got: Vec<f64> = outs.iter().map(|o| o.loss).collect();
        assert_eq!(got, serial);
    }
}
