//! Threaded shard shell: one owning thread per shard, FIFO command
//! queues (DESIGN.md §15).
//!
//! [`ShardPool`] decomposes a [`Service`] into its shard cores, parks
//! each on its own thread behind an `mpsc` channel, and routes
//! commands by the shared routing table. Because a shard's channel is
//! FIFO and its core is single-owner, the pool preserves the service's
//! determinism contract *per shard*: commands that arrive in the same
//! order produce the same state, byte for byte. Cross-shard ordering
//! is whatever the transport delivers — studies never share state, so
//! that is unobservable.
//!
//! Threads idle on `recv_timeout`; a timeout fires the core's `tick`
//! (lease expiry, due compactions) so worker death is noticed without
//! traffic. `shutdown` reassembles the cores into a [`Service`] for
//! inspection — the chaos tests compare post-shutdown state against
//! reference runs.
//!
//! # Supervision (DESIGN.md §16)
//!
//! Each shard thread is a *seat*: the core plus a sans-IO
//! [`Supervisor`] and an optional [`RestartSpec`]. Command handling and
//! ticks run under `catch_unwind`; a panic (or a WAL wedge surfacing
//! from the core) hands the seat to the supervisor, which sleeps a
//! jittered exponential backoff and rebuilds the core from WAL replay —
//! the exact kill-and-recover path the durability proofs already pin
//! down, so a restarted shard is byte-identical to a rebooted one.
//! When the restart budget runs out (or there is no WAL to replay),
//! the core is parked in the typed `Degraded` state: asks and tells are
//! rejected with `shard-degraded`, status queries still answer. The
//! shard *thread* never dies outside shutdown, so queued commands
//! always get a reply.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::serve::clock::Clock;
use crate::serve::proto::{Client, ErrorCode, Request, Response};
use crate::serve::service::{route, Service};
use crate::serve::shard::{ShardCore, ShardOpts};
use crate::serve::supervisor::{Supervisor, SupervisorDecision};
use crate::serve::wal::{FsWalIo, Wal, WalIo};

/// Builds the storage layer for a (re)opened shard WAL. The default is
/// [`FsWalIo`]; the chaos suite injects fault-scripted implementations
/// that survive restarts (so a disk that "stays broken" keeps failing
/// the rebuilt shard too).
pub type WalIoFactory = Arc<dyn Fn() -> Box<dyn WalIo> + Send + Sync>;

enum Cmd {
    Req(Request, mpsc::Sender<Response>),
    /// Chaos injection: panic inside the shard thread, exactly where a
    /// real handler panic would unwind, then let supervision run.
    Crash(mpsc::Sender<Response>),
    Shutdown,
}

struct ShardThread {
    sender: mpsc::Sender<Cmd>,
    handle: JoinHandle<ShardCore>,
}

/// Everything needed to rebuild a shard core from durable state.
struct RestartSpec {
    shard: usize,
    wal_dir: PathBuf,
    failover: Option<PathBuf>,
    opts: ShardOpts,
    io: WalIoFactory,
    clock: Arc<dyn Clock>,
}

impl RestartSpec {
    fn rebuild(&self) -> Result<ShardCore> {
        let wal = Wal::open_with(
            &self.wal_dir,
            self.failover.as_deref(),
            self.shard,
            (self.io)(),
        )?;
        ShardCore::recover(
            self.shard,
            Arc::clone(&self.clock),
            self.opts.clone(),
            wal,
        )
    }
}

/// A shard core plus its supervision state, owned by one thread.
struct Seat {
    core: ShardCore,
    supervisor: Supervisor,
    spec: Option<RestartSpec>,
    restarts: Arc<AtomicU64>,
}

impl Seat {
    /// Run the supervisor after a panic or wedge: restart from WAL
    /// under backoff, or degrade when the budget (or the WAL) is gone.
    /// The discarded core's in-memory state is suspect after a panic;
    /// only the WAL replay (or the typed `Degraded` surface, which
    /// mutates nothing) is trusted afterwards.
    fn recover_or_degrade(&mut self, why: &str) {
        let Some(spec) = &self.spec else {
            self.core.set_degraded(format!(
                "{why}; no WAL to restart from"
            ));
            return;
        };
        loop {
            match self.supervisor.on_failure() {
                SupervisorDecision::Degrade => {
                    self.core.set_degraded(format!(
                        "{why}; restart budget exhausted"
                    ));
                    return;
                }
                SupervisorDecision::RestartAfterMs(ms) => {
                    std::thread::sleep(Duration::from_millis(ms));
                    match spec.rebuild() {
                        Ok(fresh) => {
                            self.core = fresh;
                            self.restarts.fetch_add(1, Ordering::Relaxed);
                            return;
                        }
                        // Rebuild failed (disk still broken, WAL
                        // unreadable): burn another budget unit and
                        // back off longer.
                        Err(_) => {}
                    }
                }
            }
        }
    }
}

fn shard_main(mut seat: Seat, rx: mpsc::Receiver<Cmd>, tick_ms: u64) -> ShardCore {
    loop {
        match rx.recv_timeout(Duration::from_millis(tick_ms)) {
            Ok(Cmd::Req(req, reply)) => {
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    seat.core.handle(&req)
                }));
                match outcome {
                    Ok(resp) => {
                        // A dropped reply sender means the caller gave
                        // up; the command still executed (and was
                        // logged).
                        let _ = reply.send(resp);
                        if seat.core.is_wedged() {
                            seat.recover_or_degrade("WAL wedge");
                        }
                    }
                    Err(_) => {
                        let _ = reply.send(Response::error(
                            ErrorCode::Internal,
                            format!(
                                "shard {} panicked handling the \
                                 command; supervisor engaged",
                                seat.core.id()
                            ),
                        ));
                        seat.recover_or_degrade("handler panic");
                    }
                }
            }
            Ok(Cmd::Crash(reply)) => {
                // Unwind through the same machinery a real fault would.
                // `panic_any`, not the macro: serve/ is pinned at zero
                // panic-*macro* surface (accidental panic paths), and
                // this is the one deliberate unwind — the chaos hook.
                let boom = catch_unwind(AssertUnwindSafe(|| {
                    std::panic::panic_any("injected shard crash")
                }));
                let _ = boom;
                let _ = reply.send(Response::error(
                    ErrorCode::Internal,
                    format!(
                        "shard {} panicked (injected); supervisor \
                         engaged",
                        seat.core.id()
                    ),
                ));
                seat.recover_or_degrade("injected crash");
            }
            Ok(Cmd::Shutdown)
            | Err(RecvTimeoutError::Disconnected) => return seat.core,
            Err(RecvTimeoutError::Timeout) => {
                if catch_unwind(AssertUnwindSafe(|| seat.core.tick()))
                    .is_err()
                {
                    seat.recover_or_degrade("tick panic");
                } else if seat.core.is_wedged() {
                    seat.recover_or_degrade("WAL wedge during tick");
                }
            }
        }
    }
}

fn lock_routes<'a>(
    m: &'a Mutex<BTreeMap<String, usize>>,
) -> std::sync::MutexGuard<'a, BTreeMap<String, usize>> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// The running, threaded form of a [`Service`].
pub struct ShardPool {
    threads: Vec<ShardThread>,
    routes: Mutex<BTreeMap<String, usize>>,
    cfg: crate::serve::service::ServeConfig,
    clock: Arc<dyn Clock>,
    /// Supervisor restarts granted per shard (the chaos proofs assert
    /// these analytically).
    restarts: Vec<Arc<AtomicU64>>,
}

impl ShardPool {
    /// Spawn one owning thread per shard with the default filesystem
    /// WAL storage. `tick_ms` is the idle maintenance interval (lease
    /// expiry resolution).
    pub fn new(service: Service, tick_ms: u64) -> ShardPool {
        ShardPool::with_io(
            service,
            tick_ms,
            Arc::new(|| Box::new(FsWalIo) as Box<dyn WalIo>),
        )
    }

    /// Spawn with an injected WAL storage factory. The factory is
    /// called once per supervisor restart, so a fault-scripted
    /// implementation shared through the factory persists across
    /// restarts of the same shard.
    pub fn with_io(
        service: Service,
        tick_ms: u64,
        io: WalIoFactory,
    ) -> ShardPool {
        let (cfg, clock, shards, routes) = service.into_parts();
        let tick_ms = tick_ms.max(1);
        let sup_cfg = cfg.supervisor_config();
        let restarts: Vec<Arc<AtomicU64>> = (0..shards.len())
            .map(|_| Arc::new(AtomicU64::new(0)))
            .collect();
        let threads = shards
            .into_iter()
            .enumerate()
            .map(|(i, core)| {
                let (tx, rx) = mpsc::channel();
                let spec = cfg.wal_dir.as_ref().map(|dir| RestartSpec {
                    shard: i,
                    wal_dir: dir.clone(),
                    failover: cfg.wal_failover_dir.clone(),
                    opts: cfg.shard_opts(),
                    io: Arc::clone(&io),
                    clock: Arc::clone(&clock),
                });
                let seat = Seat {
                    core,
                    supervisor: Supervisor::new(sup_cfg.clone(), i),
                    spec,
                    restarts: restarts
                        .get(i)
                        .map(Arc::clone)
                        .unwrap_or_default(),
                };
                let handle = std::thread::spawn(move || {
                    shard_main(seat, rx, tick_ms)
                });
                ShardThread { sender: tx, handle }
            })
            .collect();
        ShardPool {
            threads,
            routes: Mutex::new(routes),
            cfg,
            clock,
            restarts,
        }
    }

    /// Route one command to its shard's queue and wait for the reply.
    pub fn call(&self, req: &Request) -> Response {
        let target = match req {
            Request::ListStudies => {
                let routes = lock_routes(&self.routes);
                return Response::Studies {
                    studies: routes.keys().cloned().collect(),
                };
            }
            Request::CreateStudy { study, .. } => {
                let routes = lock_routes(&self.routes);
                if routes.contains_key(study) {
                    return Response::error(
                        ErrorCode::DuplicateStudy,
                        format!("study {study:?} already exists"),
                    );
                }
                route(study, self.threads.len())
            }
            Request::Ask { study, .. }
            | Request::Tell { study, .. }
            | Request::Heartbeat { study, .. }
            | Request::StudyStatus { study }
            | Request::StopStudy { study } => {
                match lock_routes(&self.routes).get(study) {
                    Some(s) => *s,
                    None => {
                        return Response::error(
                            ErrorCode::UnknownStudy,
                            format!("no study {study:?} on this service"),
                        )
                    }
                }
            }
        };
        let Some(thread) = self.threads.get(target) else {
            return Response::error(
                ErrorCode::Internal,
                format!("route to missing shard {target}"),
            );
        };
        let (reply_tx, reply_rx) = mpsc::channel();
        if thread.sender.send(Cmd::Req(req.clone(), reply_tx)).is_err() {
            return Response::error(
                ErrorCode::Internal,
                format!("shard {target} thread is gone"),
            );
        }
        let resp = match reply_rx.recv() {
            Ok(r) => r,
            Err(_) => {
                return Response::error(
                    ErrorCode::Internal,
                    format!("shard {target} died mid-command"),
                )
            }
        };
        if let (Request::CreateStudy { study, .. }, Response::Created { .. }) =
            (req, &resp)
        {
            lock_routes(&self.routes).insert(study.clone(), target);
        }
        resp
    }

    /// Number of shards.
    pub fn n_shards(&self) -> usize {
        self.threads.len()
    }

    /// Supervisor restarts granted so far, per shard.
    pub fn restarts(&self) -> Vec<u64> {
        self.restarts.iter().map(|r| r.load(Ordering::Relaxed)).collect()
    }

    /// Chaos hook: panic shard `i`'s thread at the top of its command
    /// loop and let supervision run its course. Blocks until the
    /// injected fault has been answered (the returned response is the
    /// typed internal error a real panic would produce); the restart
    /// or degradation itself happens before the shard touches its next
    /// command.
    pub fn inject_panic(&self, shard: usize) -> Response {
        let Some(thread) = self.threads.get(shard) else {
            return Response::error(
                ErrorCode::Internal,
                format!("no shard {shard} to crash"),
            );
        };
        let (reply_tx, reply_rx) = mpsc::channel();
        if thread.sender.send(Cmd::Crash(reply_tx)).is_err() {
            return Response::error(
                ErrorCode::Internal,
                format!("shard {shard} thread is gone"),
            );
        }
        match reply_rx.recv() {
            Ok(r) => r,
            Err(_) => Response::error(
                ErrorCode::Internal,
                format!("shard {shard} died mid-crash"),
            ),
        }
    }

    /// Drain the queues, join every shard thread, and reassemble the
    /// [`Service`] for inspection.
    pub fn shutdown(self) -> Result<Service> {
        for t in &self.threads {
            // A full queue drains first: Shutdown is FIFO like any
            // other command.
            let _ = t.sender.send(Cmd::Shutdown);
        }
        let mut shards = Vec::with_capacity(self.threads.len());
        for t in self.threads {
            let core = t
                .handle
                .join()
                .map_err(|_| anyhow!("a shard thread panicked"))?;
            shards.push(core);
        }
        let routes = match self.routes.into_inner() {
            Ok(r) => r,
            Err(poisoned) => poisoned.into_inner(),
        };
        Ok(Service::from_parts(self.cfg, self.clock, shards, routes))
    }
}

/// In-process [`Client`]: calls go straight into the pool's queues.
pub struct PoolClient {
    pool: Arc<ShardPool>,
}

impl PoolClient {
    /// A client handle onto `pool`.
    pub fn new(pool: Arc<ShardPool>) -> PoolClient {
        PoolClient { pool }
    }
}

impl Client for PoolClient {
    fn call(&mut self, req: &Request) -> Result<Response> {
        Ok(self.pool.call(req))
    }
}
