//! Threaded shard shell: one owning thread per shard, FIFO command
//! queues (DESIGN.md §15).
//!
//! [`ShardPool`] decomposes a [`Service`] into its shard cores, parks
//! each on its own thread behind an `mpsc` channel, and routes
//! commands by the shared routing table. Because a shard's channel is
//! FIFO and its core is single-owner, the pool preserves the service's
//! determinism contract *per shard*: commands that arrive in the same
//! order produce the same state, byte for byte. Cross-shard ordering
//! is whatever the transport delivers — studies never share state, so
//! that is unobservable.
//!
//! Threads idle on `recv_timeout`; a timeout fires the core's `tick`
//! (lease expiry, due compactions) so worker death is noticed without
//! traffic. `shutdown` reassembles the cores into a [`Service`] for
//! inspection — the chaos tests compare post-shutdown state against
//! reference runs.

use std::collections::BTreeMap;
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::serve::proto::{Client, ErrorCode, Request, Response};
use crate::serve::service::{route, Service};
use crate::serve::shard::ShardCore;

enum Cmd {
    Req(Request, mpsc::Sender<Response>),
    Shutdown,
}

struct ShardThread {
    sender: mpsc::Sender<Cmd>,
    handle: JoinHandle<ShardCore>,
}

/// The running, threaded form of a [`Service`].
pub struct ShardPool {
    threads: Vec<ShardThread>,
    routes: Mutex<BTreeMap<String, usize>>,
    cfg: crate::serve::service::ServeConfig,
    clock: Arc<dyn crate::serve::clock::Clock>,
}

fn shard_main(mut core: ShardCore, rx: mpsc::Receiver<Cmd>, tick_ms: u64) -> ShardCore {
    loop {
        match rx.recv_timeout(Duration::from_millis(tick_ms)) {
            Ok(Cmd::Req(req, reply)) => {
                let resp = core.handle(&req);
                // A dropped reply sender means the caller gave up;
                // the command still executed (and was logged).
                let _ = reply.send(resp);
            }
            Ok(Cmd::Shutdown)
            | Err(RecvTimeoutError::Disconnected) => return core,
            Err(RecvTimeoutError::Timeout) => core.tick(),
        }
    }
}

fn lock_routes<'a>(
    m: &'a Mutex<BTreeMap<String, usize>>,
) -> std::sync::MutexGuard<'a, BTreeMap<String, usize>> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

impl ShardPool {
    /// Spawn one owning thread per shard. `tick_ms` is the idle
    /// maintenance interval (lease expiry resolution).
    pub fn new(service: Service, tick_ms: u64) -> ShardPool {
        let (cfg, clock, shards, routes) = service.into_parts();
        let tick_ms = tick_ms.max(1);
        let threads = shards
            .into_iter()
            .map(|core| {
                let (tx, rx) = mpsc::channel();
                let handle = std::thread::spawn(move || {
                    shard_main(core, rx, tick_ms)
                });
                ShardThread { sender: tx, handle }
            })
            .collect();
        ShardPool { threads, routes: Mutex::new(routes), cfg, clock }
    }

    /// Route one command to its shard's queue and wait for the reply.
    pub fn call(&self, req: &Request) -> Response {
        let target = match req {
            Request::ListStudies => {
                let routes = lock_routes(&self.routes);
                return Response::Studies {
                    studies: routes.keys().cloned().collect(),
                };
            }
            Request::CreateStudy { study, .. } => {
                let routes = lock_routes(&self.routes);
                if routes.contains_key(study) {
                    return Response::error(
                        ErrorCode::DuplicateStudy,
                        format!("study {study:?} already exists"),
                    );
                }
                route(study, self.threads.len())
            }
            Request::Ask { study, .. }
            | Request::Tell { study, .. }
            | Request::Heartbeat { study, .. }
            | Request::StudyStatus { study }
            | Request::StopStudy { study } => {
                match lock_routes(&self.routes).get(study) {
                    Some(s) => *s,
                    None => {
                        return Response::error(
                            ErrorCode::UnknownStudy,
                            format!("no study {study:?} on this service"),
                        )
                    }
                }
            }
        };
        let Some(thread) = self.threads.get(target) else {
            return Response::error(
                ErrorCode::Internal,
                format!("route to missing shard {target}"),
            );
        };
        let (reply_tx, reply_rx) = mpsc::channel();
        if thread.sender.send(Cmd::Req(req.clone(), reply_tx)).is_err() {
            return Response::error(
                ErrorCode::Internal,
                format!("shard {target} thread is gone"),
            );
        }
        let resp = match reply_rx.recv() {
            Ok(r) => r,
            Err(_) => {
                return Response::error(
                    ErrorCode::Internal,
                    format!("shard {target} died mid-command"),
                )
            }
        };
        if let (Request::CreateStudy { study, .. }, Response::Created { .. }) =
            (req, &resp)
        {
            lock_routes(&self.routes).insert(study.clone(), target);
        }
        resp
    }

    /// Number of shards.
    pub fn n_shards(&self) -> usize {
        self.threads.len()
    }

    /// Drain the queues, join every shard thread, and reassemble the
    /// [`Service`] for inspection.
    pub fn shutdown(self) -> Result<Service> {
        for t in &self.threads {
            // A full queue drains first: Shutdown is FIFO like any
            // other command.
            let _ = t.sender.send(Cmd::Shutdown);
        }
        let mut shards = Vec::with_capacity(self.threads.len());
        for t in self.threads {
            let core = t
                .handle
                .join()
                .map_err(|_| anyhow!("a shard thread panicked"))?;
            shards.push(core);
        }
        let routes = match self.routes.into_inner() {
            Ok(r) => r,
            Err(poisoned) => poisoned.into_inner(),
        };
        Ok(Service::from_parts(self.cfg, self.clock, shards, routes))
    }
}

/// In-process [`Client`]: calls go straight into the pool's queues.
pub struct PoolClient {
    pool: Arc<ShardPool>,
}

impl PoolClient {
    /// A client handle onto `pool`.
    pub fn new(pool: Arc<ShardPool>) -> PoolClient {
        PoolClient { pool }
    }
}

impl Client for PoolClient {
    fn call(&mut self, req: &Request) -> Result<Response> {
        Ok(self.pool.call(req))
    }
}
