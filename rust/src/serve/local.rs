//! Worker loop and in-process worker pool (DESIGN.md §15).
//!
//! [`worker_loop`] is the reference trial worker, written against the
//! transport-agnostic [`Client`] trait so the identical loop drives an
//! in-process [`PoolClient`](crate::serve::pool::PoolClient) (tests,
//! CI smoke, benches) or a [`TcpClient`](crate::serve::net::TcpClient)
//! (`hyppo worker`). It self-configures from the service: `status`
//! returns the study's config document, from which the worker builds
//! the same deterministic [`SyntheticEvaluator`] the server used for
//! its search space — so outcomes are exactly what a server-side run
//! would have produced, and the bit-identity proofs in
//! `tests/serve.rs` can compare against a bare `exec::Session` loop.
//!
//! [`run_local`] is the process-pool backend: M worker threads over
//! one shard pool, each study assigned to exactly one worker
//! (`study index mod M`). One worker per study keeps each study's
//! command arrival order — and therefore its result — deterministic;
//! multiple workers per study are supported by the protocol (leases
//! make it safe) but race on arrival order, like any asynchronous
//! optimizer.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::config;
use crate::eval::synthetic::SyntheticEvaluator;
use crate::eval::Evaluator;
use crate::serve::pool::{PoolClient, ShardPool};
use crate::serve::proto::{Client, ErrorCode, Request, Response};

/// What one worker did, for logs and smoke checks.
#[derive(Debug, Clone, Default)]
pub struct WorkerReport {
    /// Worker id.
    pub worker: String,
    /// Evaluations leased.
    pub asks: usize,
    /// Outcomes delivered and accepted.
    pub tells: usize,
    /// Deliveries the service rejected as duplicates (redelivery
    /// drills; 0 in a clean run).
    pub duplicate_tells: usize,
    /// Studies this worker drove to completion (`done` from ask).
    pub studies_done: Vec<String>,
}

fn evaluator_for(config_toml: &str) -> Result<SyntheticEvaluator> {
    let doc = config::parse(config_toml).context("study config")?;
    let cfg = config::build(&doc).context("study config")?;
    Ok(SyntheticEvaluator::new(cfg.space.clone(), cfg.hpo.seed))
}

/// Drive `studies` to completion through `client`. Round-robins over
/// the studies, heartbeating each pass, until every study reports
/// `done`.
pub fn worker_loop(
    client: &mut dyn Client,
    worker: &str,
    studies: &[String],
) -> Result<WorkerReport> {
    let mut report = WorkerReport {
        worker: worker.to_string(),
        ..WorkerReport::default()
    };
    // Self-configure: fetch each study's config and build its
    // deterministic evaluator.
    let mut evs: BTreeMap<String, SyntheticEvaluator> = BTreeMap::new();
    for study in studies {
        let resp = client.call(&Request::StudyStatus {
            study: study.clone(),
        })?;
        match resp {
            Response::Status { config_toml, .. } => {
                evs.insert(study.clone(), evaluator_for(&config_toml)?);
            }
            Response::Error { code, message } => bail!(
                "status of {study:?} failed: {}: {message}",
                code.as_str()
            ),
            other => bail!("unexpected status reply: {other:?}"),
        }
    }
    let mut done: BTreeMap<&str, bool> =
        studies.iter().map(|s| (s.as_str(), false)).collect();
    while done.values().any(|d| !d) {
        let mut progressed = false;
        for study in studies {
            if done.get(study.as_str()).copied().unwrap_or(true) {
                continue;
            }
            client.call(&Request::Heartbeat {
                study: study.clone(),
                worker: worker.to_string(),
                eval: None,
            })?;
            let resp = client.call(&Request::Ask {
                study: study.clone(),
                worker: worker.to_string(),
            })?;
            let job = match resp {
                Response::Asked { job: Some(job), .. } => job,
                Response::Asked { job: None, done: true, .. } => {
                    done.insert(study.as_str(), true);
                    report.studies_done.push(study.clone());
                    progressed = true;
                    continue;
                }
                Response::Asked { job: None, done: false, .. } => {
                    // Another worker's lease is in flight; back off.
                    continue;
                }
                Response::Error { code, message } => bail!(
                    "ask on {study:?} failed: {}: {message}",
                    code.as_str()
                ),
                other => bail!("unexpected ask reply: {other:?}"),
            };
            report.asks += 1;
            progressed = true;
            let ev = evs
                .get(study.as_str())
                .ok_or_else(|| anyhow!("no evaluator for {study:?}"))?;
            for trial in &job.trials {
                let outcome = ev.run_trial(&job.theta, *trial, job.seed);
                let resp = client.call(&Request::Tell {
                    study: study.clone(),
                    worker: worker.to_string(),
                    eval_id: job.eval_id,
                    trial: *trial,
                    outcome,
                })?;
                match resp {
                    Response::Told { .. } => report.tells += 1,
                    Response::Error {
                        code: ErrorCode::DuplicateTell,
                        ..
                    } => report.duplicate_tells += 1,
                    Response::Error { code, message } => bail!(
                        "tell on {study:?} eval {} trial {trial} \
                         failed: {}: {message}",
                        job.eval_id,
                        code.as_str()
                    ),
                    other => bail!("unexpected tell reply: {other:?}"),
                }
            }
        }
        if !progressed {
            // Every incomplete study is waiting on someone else's
            // lease; yield rather than hot-spin.
            std::thread::yield_now();
        }
    }
    Ok(report)
}

/// The process-pool backend: create `studies` on `pool`, then drive
/// them with `n_workers` threads, study *i* owned by worker *i* mod
/// `n_workers` (deterministic per-study command order — see module
/// docs).
pub fn run_local(
    pool: &Arc<ShardPool>,
    studies: &[(String, String)],
    n_workers: usize,
) -> Result<Vec<WorkerReport>> {
    if n_workers == 0 {
        bail!("run_local needs at least one worker");
    }
    for (study, config_toml) in studies {
        let resp = pool.call(&Request::CreateStudy {
            study: study.clone(),
            config_toml: config_toml.clone(),
        });
        match resp {
            Response::Created { .. } => {}
            Response::Error { code, message } => bail!(
                "create {study:?} failed: {}: {message}",
                code.as_str()
            ),
            other => bail!("unexpected create reply: {other:?}"),
        }
    }
    let handles: Vec<_> = (0..n_workers)
        .map(|w| {
            let assigned: Vec<String> = studies
                .iter()
                .enumerate()
                .filter(|(i, _)| i % n_workers == w)
                .map(|(_, (name, _))| name.clone())
                .collect();
            let pool = Arc::clone(pool);
            std::thread::spawn(move || {
                let mut client = PoolClient::new(pool);
                worker_loop(&mut client, &format!("w{w}"), &assigned)
            })
        })
        .collect();
    let mut reports = Vec::with_capacity(handles.len());
    for h in handles {
        let report = h
            .join()
            .map_err(|_| anyhow!("a worker thread panicked"))??;
        reports.push(report);
    }
    Ok(reports)
}
