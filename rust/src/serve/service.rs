//! Multi-shard service assembly: routing, recovery, migration
//! (DESIGN.md §15).
//!
//! A [`Service`] is N [`ShardCore`]s plus a routing table. Studies hash
//! to shards with FNV-1a 64 (a fixed, documented function — *not*
//! `DefaultHasher`, whose SipHash keys are randomized per process and
//! would scatter studies differently on every restart), and the
//! `routes` override map records where each study actually lives so
//! migration can move a study off its hash-home without breaking
//! lookups.
//!
//! This type is itself single-threaded and sans-IO apart from the WAL —
//! the deterministic interleaving proofs in `tests/serve.rs` drive it
//! directly with a virtual scheduler. The threaded shell
//! (`serve::pool`) splits it into per-shard threads and reassembles it
//! on shutdown.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::{Doc, Value};
use crate::serve::clock::Clock;
use crate::serve::proto::{ErrorCode, Request, Response};
use crate::serve::shard::{ShardCore, ShardOpts};
use crate::serve::supervisor::SupervisorConfig;
use crate::serve::wal::{Wal, WalFailure};

/// FNV-1a 64-bit: tiny, stable across processes and platforms.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A study's hash-home shard.
pub fn route(study: &str, n_shards: usize) -> usize {
    if n_shards <= 1 {
        return 0;
    }
    // Modulo keeps the map obvious and re-derivable by operators; the
    // shard count is fixed for a service's lifetime (migration, not
    // rehashing, rebalances load).
    usize::try_from(fnv1a64(study.as_bytes()) % n_shards as u64)
        .unwrap_or(0)
}

/// Service-level knobs, read from a config document's `[serve]` table
/// (see `examples/configs/serve.toml`).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Number of shards (owning threads under the pool shell).
    pub n_shards: usize,
    /// Worker lease duration in clock milliseconds.
    pub lease_ms: u64,
    /// Compact a shard's WAL after this many appends; 0 disables.
    pub compact_every: usize,
    /// WAL directory; `None` runs without durability.
    pub wal_dir: Option<PathBuf>,
    /// What a shard does when a WAL append fails.
    pub wal_failure: WalFailure,
    /// Secondary WAL directory for the `failover` policy (required by
    /// it, rejected otherwise).
    pub wal_failover_dir: Option<PathBuf>,
    /// Lease-expiry strikes before an evaluation is quarantined; 0
    /// disables quarantine.
    pub max_eval_retries: usize,
    /// Loss scored for each trial of a quarantined evaluation.
    pub poison_penalty: f64,
    /// Supervisor restarts granted to a shard before it degrades.
    pub max_restarts: u32,
    /// Supervisor backoff envelope base, milliseconds.
    pub restart_backoff_ms: u64,
    /// Supervisor backoff envelope cap, milliseconds.
    pub restart_backoff_max_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let sup = SupervisorConfig::default();
        let shard = ShardOpts::default();
        ServeConfig {
            n_shards: 2,
            lease_ms: 5_000,
            compact_every: 0,
            wal_dir: None,
            wal_failure: shard.wal_failure,
            wal_failover_dir: None,
            max_eval_retries: shard.max_eval_retries,
            poison_penalty: shard.poison_penalty,
            max_restarts: sup.max_restarts,
            restart_backoff_ms: sup.backoff_base_ms,
            restart_backoff_max_ms: sup.backoff_max_ms,
        }
    }
}

impl ServeConfig {
    /// Read the `[serve]` table (all keys optional).
    pub fn from_doc(doc: &Doc) -> Result<ServeConfig> {
        let mut cfg = ServeConfig::default();
        let Some(table) = doc.get("serve") else { return Ok(cfg) };
        for (key, value) in table {
            match key.as_str() {
                "shards" => {
                    let n = value
                        .as_i64()
                        .context("[serve] shards: expected integer")?;
                    if n < 1 {
                        bail!("[serve] shards must be >= 1, got {n}");
                    }
                    cfg.n_shards = n as usize;
                }
                "lease_ms" => {
                    let n = value
                        .as_i64()
                        .context("[serve] lease_ms: expected integer")?;
                    if n < 1 {
                        bail!("[serve] lease_ms must be >= 1, got {n}");
                    }
                    cfg.lease_ms = n as u64;
                }
                "compact_every" => {
                    let n = value.as_i64().context(
                        "[serve] compact_every: expected integer",
                    )?;
                    if n < 0 {
                        bail!("[serve] compact_every must be >= 0");
                    }
                    cfg.compact_every = n as usize;
                }
                "wal_dir" => {
                    let s = value
                        .as_str()
                        .context("[serve] wal_dir: expected string")?;
                    cfg.wal_dir = Some(PathBuf::from(s));
                }
                "wal_failure" => {
                    let s = value
                        .as_str()
                        .context("[serve] wal_failure: expected string")?;
                    cfg.wal_failure = WalFailure::from_str(s)
                        .context("[serve] wal_failure")?;
                }
                "wal_failover_dir" => {
                    let s = value.as_str().context(
                        "[serve] wal_failover_dir: expected string",
                    )?;
                    cfg.wal_failover_dir = Some(PathBuf::from(s));
                }
                "max_eval_retries" => {
                    let n = value.as_i64().context(
                        "[serve] max_eval_retries: expected integer",
                    )?;
                    if n < 0 {
                        bail!("[serve] max_eval_retries must be >= 0");
                    }
                    cfg.max_eval_retries = n as usize;
                }
                "poison_penalty" => {
                    let x = value.as_f64().context(
                        "[serve] poison_penalty: expected number",
                    )?;
                    if !x.is_finite() {
                        bail!("[serve] poison_penalty must be finite");
                    }
                    cfg.poison_penalty = x;
                }
                "max_restarts" => {
                    let n = value.as_i64().context(
                        "[serve] max_restarts: expected integer",
                    )?;
                    if n < 0 {
                        bail!("[serve] max_restarts must be >= 0");
                    }
                    cfg.max_restarts = n as u32;
                }
                "restart_backoff_ms" => {
                    let n = value.as_i64().context(
                        "[serve] restart_backoff_ms: expected integer",
                    )?;
                    if n < 1 {
                        bail!("[serve] restart_backoff_ms must be >= 1");
                    }
                    cfg.restart_backoff_ms = n as u64;
                }
                "restart_backoff_max_ms" => {
                    let n = value.as_i64().context(
                        "[serve] restart_backoff_max_ms: expected \
                         integer",
                    )?;
                    if n < 1 {
                        bail!(
                            "[serve] restart_backoff_max_ms must be >= 1"
                        );
                    }
                    cfg.restart_backoff_max_ms = n as u64;
                }
                other => bail!("unknown [serve] key {other:?}"),
            }
        }
        match (cfg.wal_failure, &cfg.wal_failover_dir) {
            (WalFailure::Failover, None) => bail!(
                "[serve] wal_failure = \"failover\" requires \
                 wal_failover_dir"
            ),
            (WalFailure::Failover, Some(_)) if cfg.wal_dir.is_none() => {
                bail!(
                    "[serve] wal_failure = \"failover\" requires wal_dir \
                     (nothing to fail over without a primary WAL)"
                )
            }
            (WalFailure::Failover, Some(f)) => {
                if Some(f) == cfg.wal_dir.as_ref() {
                    bail!(
                        "[serve] wal_failover_dir must differ from \
                         wal_dir (a failover on the same disk protects \
                         nothing)"
                    );
                }
            }
            (_, Some(_)) => bail!(
                "[serve] wal_failover_dir is only meaningful with \
                 wal_failure = \"failover\""
            ),
            (_, None) => {}
        }
        Ok(cfg)
    }

    /// The per-shard behaviour knobs this config implies.
    pub fn shard_opts(&self) -> ShardOpts {
        ShardOpts {
            lease_ms: self.lease_ms,
            compact_every: self.compact_every,
            max_eval_retries: self.max_eval_retries,
            poison_penalty: self.poison_penalty,
            wal_failure: self.wal_failure,
        }
    }

    /// The supervisor policy this config implies (jitter seed is the
    /// library default — delays are deterministic per shard, which is
    /// all the chaos proofs need).
    pub fn supervisor_config(&self) -> SupervisorConfig {
        SupervisorConfig {
            max_restarts: self.max_restarts,
            backoff_base_ms: self.restart_backoff_ms,
            backoff_max_ms: self.restart_backoff_max_ms,
            ..SupervisorConfig::default()
        }
    }

    /// Read the `[studies]` table: `name = "path/to/config.toml"`.
    pub fn studies_from_doc(doc: &Doc) -> Result<Vec<(String, String)>> {
        let Some(table) = doc.get("studies") else {
            return Ok(Vec::new());
        };
        let mut out = Vec::new();
        for (name, value) in table {
            match value {
                Value::Str(path) => {
                    out.push((name.clone(), path.clone()))
                }
                _ => bail!(
                    "[studies] {name}: expected a config path string"
                ),
            }
        }
        Ok(out)
    }
}

/// N shards plus the routing table. See the module docs.
pub struct Service {
    cfg: ServeConfig,
    clock: Arc<dyn Clock>,
    shards: Vec<ShardCore>,
    /// Where each study lives (usually its hash-home; migration moves
    /// entries).
    routes: BTreeMap<String, usize>,
}

impl Service {
    fn shard_wal(cfg: &ServeConfig, shard: usize) -> Result<Option<Wal>> {
        match &cfg.wal_dir {
            Some(dir) => Ok(Some(Wal::open_with(
                dir,
                cfg.wal_failover_dir.as_deref(),
                shard,
                Box::new(crate::serve::wal::FsWalIo),
            )?)),
            None => Ok(None),
        }
    }

    /// True when any shard WAL exists in the primary or failover dir.
    fn wal_present(cfg: &ServeConfig) -> bool {
        [cfg.wal_dir.as_deref(), cfg.wal_failover_dir.as_deref()]
            .into_iter()
            .flatten()
            .any(|dir| {
                (0..cfg.n_shards).any(|s| Wal::exists(dir, s))
            })
    }

    /// A fresh service. Refuses to start over an existing WAL (that
    /// state belongs to [`Service::recover`]).
    pub fn new(cfg: ServeConfig, clock: Arc<dyn Clock>) -> Result<Service> {
        if Self::wal_present(&cfg) {
            bail!(
                "a WAL already exists under the configured \
                 directories; use recovery instead of overwriting it"
            );
        }
        let shards = (0..cfg.n_shards)
            .map(|i| {
                Ok(ShardCore::new(
                    i,
                    Arc::clone(&clock),
                    cfg.shard_opts(),
                    Self::shard_wal(&cfg, i)?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Service { cfg, clock, shards, routes: BTreeMap::new() })
    }

    /// Rebuild every shard from its WAL (chasing any failover chain)
    /// and re-derive the routing table from actual study placement.
    pub fn recover(
        cfg: ServeConfig,
        clock: Arc<dyn Clock>,
    ) -> Result<Service> {
        if cfg.wal_dir.is_none() {
            bail!("recovery requires [serve] wal_dir");
        }
        let mut shards = Vec::with_capacity(cfg.n_shards);
        let mut routes = BTreeMap::new();
        for i in 0..cfg.n_shards {
            let wal = Self::shard_wal(&cfg, i)?
                .ok_or_else(|| anyhow::anyhow!("no WAL for shard {i}"))?;
            let core = ShardCore::recover(
                i,
                Arc::clone(&clock),
                cfg.shard_opts(),
                wal,
            )
            .with_context(|| format!("recovering shard {i}"))?;
            for study in core.study_names() {
                if let Some(prev) = routes.insert(study.clone(), i) {
                    bail!(
                        "study {study:?} present on shards {prev} and \
                         {i}; the WAL set is inconsistent"
                    );
                }
            }
            shards.push(core);
        }
        Ok(Service { cfg, clock, shards, routes })
    }

    /// Open: recover when any shard WAL exists, start fresh otherwise.
    pub fn open(cfg: ServeConfig, clock: Arc<dyn Clock>) -> Result<Service> {
        if Self::wal_present(&cfg) {
            Service::recover(cfg, clock)
        } else {
            Service::new(cfg, clock)
        }
    }

    /// Route and process one command.
    pub fn handle(&mut self, req: &Request) -> Response {
        let target = match req {
            Request::ListStudies => {
                return Response::Studies {
                    studies: self.routes.keys().cloned().collect(),
                }
            }
            Request::CreateStudy { study, .. } => {
                if self.routes.contains_key(study) {
                    return Response::error(
                        ErrorCode::DuplicateStudy,
                        format!("study {study:?} already exists"),
                    );
                }
                route(study, self.shards.len())
            }
            Request::Ask { study, .. }
            | Request::Tell { study, .. }
            | Request::Heartbeat { study, .. }
            | Request::StudyStatus { study }
            | Request::StopStudy { study } => {
                match self.routes.get(study) {
                    Some(s) => *s,
                    None => {
                        return Response::error(
                            ErrorCode::UnknownStudy,
                            format!("no study {study:?} on this service"),
                        )
                    }
                }
            }
        };
        let Some(shard) = self.shards.get_mut(target) else {
            return Response::error(
                ErrorCode::Internal,
                format!("route to missing shard {target}"),
            );
        };
        let resp = shard.handle(req);
        if let (Request::CreateStudy { study, .. }, Response::Created { .. }) =
            (req, &resp)
        {
            self.routes.insert(study.clone(), target);
        }
        resp
    }

    /// Lease maintenance across all shards (the pool shell calls the
    /// per-shard equivalent on idle timeouts).
    pub fn tick(&mut self) {
        for shard in &mut self.shards {
            shard.tick();
        }
    }

    /// Move a study to another shard by snapshot hand-off: the source
    /// logs an eviction, the destination logs the imported snapshot,
    /// and the routing table flips. In-flight evaluations re-emerge
    /// from future asks on the new shard.
    pub fn migrate(&mut self, study: &str, to: usize) -> Result<()> {
        let from = *self
            .routes
            .get(study)
            .ok_or_else(|| anyhow::anyhow!("unknown study {study:?}"))?;
        if to >= self.shards.len() {
            bail!("no shard {to} (have {})", self.shards.len());
        }
        if from == to {
            return Ok(());
        }
        let snap = match self.shards.get_mut(from) {
            Some(s) => s.export_study(study)?,
            None => bail!("route to missing shard {from}"),
        };
        match self.shards.get_mut(to) {
            Some(s) => s.import_study(snap)?,
            None => bail!("no shard {to}"),
        }
        self.routes.insert(study.to_string(), to);
        Ok(())
    }

    /// Compact every shard's WAL now.
    pub fn compact_all(&mut self) -> Result<()> {
        for shard in &mut self.shards {
            shard.compact()?;
        }
        Ok(())
    }

    // -- inspection / decomposition -----------------------------------

    /// Number of shards.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The shard a study currently lives on.
    pub fn shard_of(&self, study: &str) -> Option<usize> {
        self.routes.get(study).copied()
    }

    /// A study's recorded history.
    pub fn history(
        &self,
        study: &str,
    ) -> Option<&crate::optimizer::History> {
        self.shards.get(*self.routes.get(study)?)?.history(study)
    }

    /// A study's surrogate refit counters.
    pub fn stats(&self, study: &str) -> Option<crate::optimizer::RefitStats> {
        self.shards.get(*self.routes.get(study)?)?.stats(study)
    }

    /// Direct access to a shard core (tests).
    pub fn shard(&self, i: usize) -> Option<&ShardCore> {
        self.shards.get(i)
    }

    /// Split into parts for the threaded pool shell.
    pub fn into_parts(
        self,
    ) -> (ServeConfig, Arc<dyn Clock>, Vec<ShardCore>, BTreeMap<String, usize>)
    {
        (self.cfg, self.clock, self.shards, self.routes)
    }

    /// Reassemble after the pool shell shuts down.
    pub fn from_parts(
        cfg: ServeConfig,
        clock: Arc<dyn Clock>,
        shards: Vec<ShardCore>,
        routes: BTreeMap<String, usize>,
    ) -> Service {
        Service { cfg, clock, shards, routes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_is_stable() {
        // Reference vectors for the canonical FNV-1a 64 parameters —
        // pinned so the study→shard map can never drift across builds.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn routing_is_deterministic_and_in_range() {
        for n in 1..8 {
            for name in ["alpha", "beta", "gamma", "s-0", "s-1"] {
                let r = route(name, n);
                assert!(r < n);
                assert_eq!(r, route(name, n));
            }
        }
    }

    #[test]
    fn serve_config_defaults_and_overrides() {
        let doc = crate::config::parse(
            "[serve]\nshards = 3\nlease_ms = 100\ncompact_every = 8\n",
        )
        .unwrap();
        let cfg = ServeConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.n_shards, 3);
        assert_eq!(cfg.lease_ms, 100);
        assert_eq!(cfg.compact_every, 8);
        assert!(cfg.wal_dir.is_none());

        let empty = crate::config::parse("").unwrap();
        let def = ServeConfig::from_doc(&empty).unwrap();
        assert_eq!(def.n_shards, 2);
    }

    #[test]
    fn serve_config_rejects_bad_values() {
        for text in [
            "[serve]\nshards = 0\n",
            "[serve]\nlease_ms = 0\n",
            "[serve]\nbogus = 1\n",
            "[serve]\nwal_failure = \"explode\"\n",
            "[serve]\nmax_eval_retries = -1\n",
            "[serve]\npoison_penalty = 1e999\n",
            "[serve]\nrestart_backoff_ms = 0\n",
            // failover needs both dirs, distinct, and a primary.
            "[serve]\nwal_failure = \"failover\"\n",
            "[serve]\nwal_failure = \"failover\"\n\
             wal_failover_dir = \"w2\"\n",
            "[serve]\nwal_dir = \"w\"\nwal_failure = \"failover\"\n\
             wal_failover_dir = \"w\"\n",
            // a failover dir without the failover policy is a typo.
            "[serve]\nwal_dir = \"w\"\nwal_failover_dir = \"w2\"\n",
        ] {
            let doc = crate::config::parse(text).unwrap();
            assert!(ServeConfig::from_doc(&doc).is_err(), "{text}");
        }
    }

    #[test]
    fn serve_config_failure_domain_knobs_parse() {
        let doc = crate::config::parse(
            "[serve]\nwal_dir = \"w\"\nwal_failure = \"failover\"\n\
             wal_failover_dir = \"w2\"\nmax_eval_retries = 3\n\
             poison_penalty = 5.5\nmax_restarts = 7\n\
             restart_backoff_ms = 20\nrestart_backoff_max_ms = 400\n",
        )
        .unwrap();
        let cfg = ServeConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.wal_failure, WalFailure::Failover);
        assert_eq!(
            cfg.wal_failover_dir.as_deref(),
            Some(std::path::Path::new("w2"))
        );
        let opts = cfg.shard_opts();
        assert_eq!(opts.max_eval_retries, 3);
        assert_eq!(opts.poison_penalty, 5.5);
        let sup = cfg.supervisor_config();
        assert_eq!(sup.max_restarts, 7);
        assert_eq!(sup.backoff_base_ms, 20);
        assert_eq!(sup.backoff_max_ms, 400);
    }
}
