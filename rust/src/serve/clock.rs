//! Injected time for the service's lease machinery (DESIGN.md §15).
//!
//! Every wall-clock read the `serve` subsystem performs flows through
//! the [`Clock`] trait: the shard cores compare lease deadlines against
//! `now_ms()` and never touch `Instant`/`SystemTime` themselves (palint's
//! `det-wall-clock` rule bans those identifiers from `serve::shard`,
//! `serve::wal`, `serve::proto`, and `serve::service`; this file is the
//! one deliberate exception). Tests and the deterministic interleaving
//! proofs drive a [`VirtualClock`] by hand; the TCP/process shells
//! install a [`SystemClock`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// A monotonically non-decreasing millisecond counter. The zero point is
/// arbitrary (process start, simulation start) — only differences are
/// ever compared, so leases need no epoch.
pub trait Clock: Send + Sync {
    /// Milliseconds elapsed since the clock's origin.
    fn now_ms(&self) -> u64;
}

/// Deterministic clock: time moves only when the owner says so. The
/// virtual scheduler in `tests/serve.rs` advances it between commands,
/// making lease expiry (and therefore heartbeat-timeout requeues) part
/// of the reproducible command stream.
#[derive(Debug, Default)]
pub struct VirtualClock {
    now_ms: AtomicU64,
}

impl VirtualClock {
    /// A shared clock starting at 0 ms.
    pub fn shared() -> Arc<VirtualClock> {
        Arc::new(VirtualClock::default())
    }

    /// Move time forward by `ms`.
    pub fn advance(&self, ms: u64) {
        self.now_ms.fetch_add(ms, Ordering::SeqCst);
    }
}

impl Clock for VirtualClock {
    fn now_ms(&self) -> u64 {
        self.now_ms.load(Ordering::SeqCst)
    }
}

/// Real time for the TCP/process shells: milliseconds since the clock
/// was created, read from the OS monotonic clock (immune to NTP steps —
/// a lease granted for 5 s means 5 s of real time, not of calendar).
#[derive(Debug)]
pub struct SystemClock {
    origin: std::time::Instant,
}

impl SystemClock {
    /// A shared clock whose origin is now.
    pub fn shared() -> Arc<SystemClock> {
        Arc::new(SystemClock { origin: std::time::Instant::now() })
    }
}

impl Clock for SystemClock {
    fn now_ms(&self) -> u64 {
        u64::try_from(self.origin.elapsed().as_millis())
            .unwrap_or(u64::MAX)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_advances_only_on_demand() {
        let c = VirtualClock::shared();
        assert_eq!(c.now_ms(), 0);
        c.advance(250);
        c.advance(50);
        assert_eq!(c.now_ms(), 300);
    }

    #[test]
    fn system_clock_is_monotone() {
        let c = SystemClock::shared();
        let a = c.now_ms();
        let b = c.now_ms();
        assert!(b >= a);
    }
}
