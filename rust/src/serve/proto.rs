//! `hyppo-serve-v1`: the versioned line-delimited JSON ask/tell wire
//! protocol (DESIGN.md §15).
//!
//! One request or response per line, each a compact JSON object carrying
//! a `"v"` envelope field (= [`PROTO_VERSION`]) and a `"type"` tag. The
//! payload grammar reuses the checkpoint substrate — typed θ coordinates
//! via `analysis::persistence`, `u64` values as decimal strings (the
//! JSON substrate stores numbers as `f64`, which would round seeds above
//! 2⁵³) — so any language with a JSON library can implement a trial
//! worker.
//!
//! | request      | fields                                   | response |
//! |--------------|------------------------------------------|----------|
//! | `create`     | `study`, `config_toml`                   | `created`|
//! | `ask`        | `study`, `worker`                        | `asked`  |
//! | `tell`       | `study`, `worker`, `eval`, `trial`, `outcome` | `told` |
//! | `heartbeat`  | `study`, `worker`                        | `beat`   |
//! | `status`     | `study`                                  | `status` |
//! | `stop`       | `study`                                  | `stopped`|
//! | `list`       | —                                        | `studies`|
//!
//! Every request may instead yield an `error` response with a typed
//! [`ErrorCode`]. The in-process [`Client`] trait abstracts the
//! transport, so the same worker loop (`serve::local`) drives a shard
//! pool directly or a TCP socket (`serve::net`).

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::analysis::persistence::{value_from_json, value_to_json};
use crate::eval::TrialOutcome;
use crate::space::{Point, Value};
use crate::util::json::{parse, write, Json};

/// Protocol version tag carried by every message envelope. A server
/// rejects mismatched versions with [`ErrorCode::Protocol`] rather than
/// guessing at field semantics.
pub const PROTO_VERSION: &str = "hyppo-serve-v1";

/// Typed failure classes of the service boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// No study with that id exists on this service.
    UnknownStudy,
    /// `create` for a study id that already exists.
    DuplicateStudy,
    /// The study config failed to parse or build.
    BadConfig,
    /// `tell` for an evaluation the session never created.
    UnknownEval,
    /// `tell` with a trial index outside the evaluation's planned set.
    BadTrial,
    /// Redelivered `tell` (outcome already absorbed, or the whole
    /// evaluation already recorded) — rejected idempotently.
    DuplicateTell,
    /// `heartbeat` for an evaluation the worker holds no live lease on
    /// (expired, never granted, or granted to someone else) — a typed
    /// no-op, mirroring the duplicate-tell treatment.
    UnknownLease,
    /// Admin command on a stopped study.
    StudyStopped,
    /// The shard is degraded (restart budget exhausted, or read-only
    /// WAL policy engaged): mutations are rejected, status still works.
    ShardDegraded,
    /// Malformed or version-mismatched message.
    Protocol,
    /// Service-side invariant failure (WAL write error, wedged shard).
    Internal,
}

impl ErrorCode {
    /// Stable wire identifier.
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::UnknownStudy => "unknown-study",
            ErrorCode::DuplicateStudy => "duplicate-study",
            ErrorCode::BadConfig => "bad-config",
            ErrorCode::UnknownEval => "unknown-eval",
            ErrorCode::BadTrial => "bad-trial",
            ErrorCode::DuplicateTell => "duplicate-tell",
            ErrorCode::UnknownLease => "unknown-lease",
            ErrorCode::StudyStopped => "study-stopped",
            ErrorCode::ShardDegraded => "shard-degraded",
            ErrorCode::Protocol => "protocol",
            ErrorCode::Internal => "internal",
        }
    }

    fn from_str(s: &str) -> Result<ErrorCode> {
        Ok(match s {
            "unknown-study" => ErrorCode::UnknownStudy,
            "duplicate-study" => ErrorCode::DuplicateStudy,
            "bad-config" => ErrorCode::BadConfig,
            "unknown-eval" => ErrorCode::UnknownEval,
            "bad-trial" => ErrorCode::BadTrial,
            "duplicate-tell" => ErrorCode::DuplicateTell,
            "unknown-lease" => ErrorCode::UnknownLease,
            "study-stopped" => ErrorCode::StudyStopped,
            "shard-degraded" => ErrorCode::ShardDegraded,
            "protocol" => ErrorCode::Protocol,
            "internal" => ErrorCode::Internal,
            other => return Err(anyhow!("unknown error code {other:?}")),
        })
    }
}

/// An evaluation-granular work lease handed to a worker by `ask`: run
/// `trials` (usually the full set `0..planned`, or a single adaptive
/// replica) for θ with the evaluation seed, and `tell` each outcome
/// before the lease expires.
#[derive(Debug, Clone, PartialEq)]
pub struct WireJob {
    /// Evaluation id (stable across requeue and crash-replay).
    pub eval_id: usize,
    /// The hyperparameter set under evaluation.
    pub theta: Point,
    /// The evaluation seed shared by all its trials.
    pub seed: u64,
    /// Trial indices to run.
    pub trials: Vec<usize>,
    /// Lease duration granted, in clock milliseconds; heartbeats renew.
    pub lease_ms: u64,
}

/// A client → service command.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Register a study: `config_toml` is a full run-config document
    /// (`[space]` + `[hpo]`), parsed server-side by `config::build`.
    CreateStudy { study: String, config_toml: String },
    /// Lease the next evaluation of `study` for `worker`.
    Ask { study: String, worker: String },
    /// Deliver one trial outcome.
    Tell {
        study: String,
        worker: String,
        eval_id: usize,
        trial: usize,
        outcome: TrialOutcome,
    },
    /// Renew leases: all of `worker`'s leases in `study` when `eval`
    /// is `None`, or exactly that evaluation's lease. A targeted
    /// heartbeat for a lease the worker does not hold gets a typed
    /// [`ErrorCode::UnknownLease`] no-op instead of a silent renew of
    /// nothing.
    Heartbeat { study: String, worker: String, eval: Option<usize> },
    /// Progress snapshot of a study.
    StudyStatus { study: String },
    /// Stop handing out work for a study (in-flight tells still drain).
    StopStudy { study: String },
    /// All study ids on the service, sorted.
    ListStudies,
}

/// Best-evaluation summary inside a [`Response::Status`].
#[derive(Debug, Clone, PartialEq)]
pub struct WireBest {
    /// Evaluation id of the incumbent.
    pub eval_id: usize,
    /// Its γ-regulated objective value.
    pub objective: f64,
}

/// A service → client reply.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// Study registered.
    Created { study: String },
    /// `job` is the leased work; `None` with `done == false` means wait
    /// (all work in flight), `None` with `done == true` means the study
    /// is complete or stopped — the worker can move on.
    Asked { study: String, job: Option<WireJob>, done: bool },
    /// Outcome absorbed: how many evaluations it recorded and how many
    /// adaptive replica trials it scheduled.
    Told { recorded: usize, extended: usize },
    /// Leases renewed for the heartbeating worker.
    Beat { renewed: usize },
    /// Study progress.
    Status {
        study: String,
        recorded: usize,
        in_flight: usize,
        complete: bool,
        stopped: bool,
        /// Evaluations quarantined with a penalty score (never silently
        /// dropped — they are regular history records; this counts
        /// them).
        poisoned: usize,
        best: Option<WireBest>,
        config_toml: String,
    },
    /// Study stopped.
    Stopped { study: String },
    /// Sorted study ids.
    Studies { studies: Vec<String> },
    /// Typed failure.
    Error { code: ErrorCode, message: String },
}

impl Response {
    /// Shorthand for a typed error reply.
    pub fn error(code: ErrorCode, message: impl Into<String>) -> Response {
        Response::Error { code, message: message.into() }
    }
}

/// Transport abstraction: the worker loop (`serve::local`) is written
/// against this, so in-process shard pools and TCP sockets
/// (`serve::net::TcpClient`) are interchangeable.
pub trait Client {
    /// Send one request and wait for its reply.
    fn call(&mut self, req: &Request) -> Result<Response>;
}

// ---------------------------------------------------------------------
// JSON encoding
// ---------------------------------------------------------------------

fn u64_to_json(v: u64) -> Json {
    Json::Str(v.to_string())
}

fn u64_from_json(v: &Json, what: &str) -> Result<u64> {
    let s = v
        .as_str()
        .with_context(|| format!("{what}: expected decimal string"))?;
    s.parse::<u64>()
        .map_err(|e| anyhow!("{what}: bad u64 {s:?}: {e}"))
}

fn usize_from_json(v: &Json, what: &str) -> Result<usize> {
    let i = v.as_i64().with_context(|| format!("{what}: expected int"))?;
    usize::try_from(i).map_err(|_| anyhow!("{what}: negative"))
}

fn str_from_json(v: &Json, what: &str) -> Result<String> {
    Ok(v.as_str()
        .with_context(|| format!("{what}: expected string"))?
        .to_string())
}

fn f64s_to_json(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|v| Json::Num(*v)).collect())
}

fn f64s_from_json(v: &Json, what: &str) -> Result<Vec<f64>> {
    v.as_arr()
        .with_context(|| format!("{what}: expected array"))?
        .iter()
        .map(|x| x.as_f64().with_context(|| format!("{what}: non-number")))
        .collect()
}

fn theta_to_json(theta: &[Value]) -> Json {
    Json::Arr(theta.iter().map(value_to_json).collect())
}

fn theta_from_json(v: &Json, what: &str) -> Result<Point> {
    v.as_arr()
        .with_context(|| format!("{what}: expected array"))?
        .iter()
        .map(|x| value_from_json(x).with_context(|| format!("{what} item")))
        .collect()
}

/// Serialize a trial outcome. Losses and predictions travel as plain
/// JSON numbers (exact: the writer emits shortest-roundtrip `f64`
/// text); the cost travels as decimal-string nanoseconds.
pub fn outcome_to_json(o: &TrialOutcome) -> Json {
    let mut m = BTreeMap::new();
    m.insert("loss".into(), Json::Num(o.loss));
    m.insert("dl".into(), f64s_to_json(&o.dropout_losses));
    m.insert(
        "pred".into(),
        match &o.predictions {
            Some(p) => f64s_to_json(p),
            None => Json::Null,
        },
    );
    m.insert(
        "dpred".into(),
        Json::Arr(
            o.dropout_predictions.iter().map(|p| f64s_to_json(p)).collect(),
        ),
    );
    let ns = u64::try_from(o.cost.as_nanos()).unwrap_or(u64::MAX);
    m.insert("cost_ns".into(), u64_to_json(ns));
    Json::Obj(m)
}

/// Parse a trial outcome written by [`outcome_to_json`].
pub fn outcome_from_json(v: &Json) -> Result<TrialOutcome> {
    let predictions = match v.get("pred") {
        Json::Null => None,
        other => Some(f64s_from_json(other, "outcome pred")?),
    };
    let dropout_predictions = v
        .get("dpred")
        .as_arr()
        .context("outcome dpred")?
        .iter()
        .map(|p| f64s_from_json(p, "outcome dpred row"))
        .collect::<Result<Vec<_>>>()?;
    Ok(TrialOutcome {
        loss: v.get("loss").as_f64().context("outcome loss")?,
        dropout_losses: f64s_from_json(v.get("dl"), "outcome dl")?,
        predictions,
        dropout_predictions,
        cost: Duration::from_nanos(u64_from_json(
            v.get("cost_ns"),
            "outcome cost_ns",
        )?),
    })
}

fn job_to_json(j: &WireJob) -> Json {
    let mut m = BTreeMap::new();
    m.insert("eval".into(), Json::Num(j.eval_id as f64));
    m.insert("theta".into(), theta_to_json(&j.theta));
    m.insert("seed".into(), u64_to_json(j.seed));
    m.insert(
        "trials".into(),
        Json::Arr(j.trials.iter().map(|t| Json::Num(*t as f64)).collect()),
    );
    m.insert("lease_ms".into(), u64_to_json(j.lease_ms));
    Json::Obj(m)
}

fn job_from_json(v: &Json) -> Result<WireJob> {
    Ok(WireJob {
        eval_id: usize_from_json(v.get("eval"), "job eval")?,
        theta: theta_from_json(v.get("theta"), "job theta")?,
        seed: u64_from_json(v.get("seed"), "job seed")?,
        trials: v
            .get("trials")
            .as_arr()
            .context("job trials")?
            .iter()
            .map(|t| usize_from_json(t, "job trial"))
            .collect::<Result<Vec<_>>>()?,
        lease_ms: u64_from_json(v.get("lease_ms"), "job lease_ms")?,
    })
}

fn envelope(kind: &str) -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("v".into(), Json::Str(PROTO_VERSION.into()));
    m.insert("type".into(), Json::Str(kind.into()));
    m
}

fn check_envelope(root: &Json) -> Result<String> {
    let v = root.get("v").as_str().context("missing protocol version")?;
    if v != PROTO_VERSION {
        return Err(anyhow!(
            "protocol version mismatch: got {v:?}, want {PROTO_VERSION:?}"
        ));
    }
    str_from_json(root.get("type"), "type")
}

fn request_map(req: &Request) -> BTreeMap<String, Json> {
    let mut m;
    match req {
        Request::CreateStudy { study, config_toml } => {
            m = envelope("create");
            m.insert("study".into(), Json::Str(study.clone()));
            m.insert("config_toml".into(), Json::Str(config_toml.clone()));
        }
        Request::Ask { study, worker } => {
            m = envelope("ask");
            m.insert("study".into(), Json::Str(study.clone()));
            m.insert("worker".into(), Json::Str(worker.clone()));
        }
        Request::Tell { study, worker, eval_id, trial, outcome } => {
            m = envelope("tell");
            m.insert("study".into(), Json::Str(study.clone()));
            m.insert("worker".into(), Json::Str(worker.clone()));
            m.insert("eval".into(), Json::Num(*eval_id as f64));
            m.insert("trial".into(), Json::Num(*trial as f64));
            m.insert("outcome".into(), outcome_to_json(outcome));
        }
        Request::Heartbeat { study, worker, eval } => {
            m = envelope("heartbeat");
            m.insert("study".into(), Json::Str(study.clone()));
            m.insert("worker".into(), Json::Str(worker.clone()));
            if let Some(id) = eval {
                m.insert("eval".into(), Json::Num(*id as f64));
            }
        }
        Request::StudyStatus { study } => {
            m = envelope("status");
            m.insert("study".into(), Json::Str(study.clone()));
        }
        Request::StopStudy { study } => {
            m = envelope("stop");
            m.insert("study".into(), Json::Str(study.clone()));
        }
        Request::ListStudies => {
            m = envelope("list");
        }
    }
    m
}

/// Encode a request as one compact JSON line (no trailing newline).
pub fn request_to_line(req: &Request) -> String {
    write(&Json::Obj(request_map(req)))
}

/// Encode a request with a client-chosen sequence number in the
/// envelope (top-level `"req"`, decimal string). A retrying client
/// stamps every attempt of the same logical request with the same
/// sequence number, and uses the echo in the response envelope to
/// discard stale replies surfacing from duplicated or reordered
/// transport frames.
pub fn request_to_line_seq(req: &Request, seq: u64) -> String {
    let mut m = request_map(req);
    m.insert("req".into(), u64_to_json(seq));
    write(&Json::Obj(m))
}

fn seq_from_root(root: &Json) -> Result<Option<u64>> {
    match root.get("req") {
        Json::Null => Ok(None),
        other => Ok(Some(u64_from_json(other, "req")?)),
    }
}

fn request_from_root(root: &Json) -> Result<Request> {
    let kind = check_envelope(root)?;
    let study = || str_from_json(root.get("study"), "study");
    let worker = || str_from_json(root.get("worker"), "worker");
    Ok(match kind.as_str() {
        "create" => Request::CreateStudy {
            study: study()?,
            config_toml: str_from_json(
                root.get("config_toml"),
                "config_toml",
            )?,
        },
        "ask" => Request::Ask { study: study()?, worker: worker()? },
        "tell" => Request::Tell {
            study: study()?,
            worker: worker()?,
            eval_id: usize_from_json(root.get("eval"), "eval")?,
            trial: usize_from_json(root.get("trial"), "trial")?,
            outcome: outcome_from_json(root.get("outcome"))?,
        },
        "heartbeat" => Request::Heartbeat {
            study: study()?,
            worker: worker()?,
            eval: match root.get("eval") {
                Json::Null => None,
                other => Some(usize_from_json(other, "eval")?),
            },
        },
        "status" => Request::StudyStatus { study: study()? },
        "stop" => Request::StopStudy { study: study()? },
        "list" => Request::ListStudies,
        other => return Err(anyhow!("unknown request type {other:?}")),
    })
}

/// Parse one request line written by [`request_to_line`].
pub fn request_from_line(line: &str) -> Result<Request> {
    let root = parse(line.trim())
        .map_err(|e| anyhow!("request parse: {e}"))?;
    request_from_root(&root)
}

/// Parse one request line plus its optional envelope sequence number
/// (see [`request_to_line_seq`]). Requests from pre-retry clients carry
/// no sequence number and parse as `(None, req)`.
pub fn request_from_line_seq(line: &str) -> Result<(Option<u64>, Request)> {
    let root = parse(line.trim())
        .map_err(|e| anyhow!("request parse: {e}"))?;
    Ok((seq_from_root(&root)?, request_from_root(&root)?))
}

fn response_map(resp: &Response) -> BTreeMap<String, Json> {
    let mut m;
    match resp {
        Response::Created { study } => {
            m = envelope("created");
            m.insert("study".into(), Json::Str(study.clone()));
        }
        Response::Asked { study, job, done } => {
            m = envelope("asked");
            m.insert("study".into(), Json::Str(study.clone()));
            m.insert(
                "job".into(),
                match job {
                    Some(j) => job_to_json(j),
                    None => Json::Null,
                },
            );
            m.insert("done".into(), Json::Bool(*done));
        }
        Response::Told { recorded, extended } => {
            m = envelope("told");
            m.insert("recorded".into(), Json::Num(*recorded as f64));
            m.insert("extended".into(), Json::Num(*extended as f64));
        }
        Response::Beat { renewed } => {
            m = envelope("beat");
            m.insert("renewed".into(), Json::Num(*renewed as f64));
        }
        Response::Status {
            study,
            recorded,
            in_flight,
            complete,
            stopped,
            poisoned,
            best,
            config_toml,
        } => {
            m = envelope("status");
            m.insert("study".into(), Json::Str(study.clone()));
            m.insert("recorded".into(), Json::Num(*recorded as f64));
            m.insert("in_flight".into(), Json::Num(*in_flight as f64));
            m.insert("complete".into(), Json::Bool(*complete));
            m.insert("stopped".into(), Json::Bool(*stopped));
            m.insert("poisoned".into(), Json::Num(*poisoned as f64));
            m.insert(
                "best".into(),
                match best {
                    Some(b) => {
                        let mut bm = BTreeMap::new();
                        bm.insert(
                            "eval".into(),
                            Json::Num(b.eval_id as f64),
                        );
                        bm.insert(
                            "objective".into(),
                            Json::Num(b.objective),
                        );
                        Json::Obj(bm)
                    }
                    None => Json::Null,
                },
            );
            m.insert("config_toml".into(), Json::Str(config_toml.clone()));
        }
        Response::Stopped { study } => {
            m = envelope("stopped");
            m.insert("study".into(), Json::Str(study.clone()));
        }
        Response::Studies { studies } => {
            m = envelope("studies");
            m.insert(
                "studies".into(),
                Json::Arr(
                    studies.iter().map(|s| Json::Str(s.clone())).collect(),
                ),
            );
        }
        Response::Error { code, message } => {
            m = envelope("error");
            m.insert("code".into(), Json::Str(code.as_str().into()));
            m.insert("message".into(), Json::Str(message.clone()));
        }
    }
    m
}

/// Encode a response as one compact JSON line (no trailing newline).
pub fn response_to_line(resp: &Response) -> String {
    write(&Json::Obj(response_map(resp)))
}

/// Encode a response, echoing the request's envelope sequence number
/// when it carried one (see [`request_to_line_seq`]). `None` omits the
/// field — the reply to a sequence-free request, or a protocol error
/// for a line too garbled to recover a sequence number from.
pub fn response_to_line_seq(resp: &Response, seq: Option<u64>) -> String {
    let mut m = response_map(resp);
    if let Some(s) = seq {
        m.insert("req".into(), u64_to_json(s));
    }
    write(&Json::Obj(m))
}

fn response_from_root(root: &Json) -> Result<Response> {
    let kind = check_envelope(root)?;
    let study = || str_from_json(root.get("study"), "study");
    Ok(match kind.as_str() {
        "created" => Response::Created { study: study()? },
        "asked" => Response::Asked {
            study: study()?,
            job: match root.get("job") {
                Json::Null => None,
                other => Some(job_from_json(other)?),
            },
            done: root.get("done").as_bool().context("done")?,
        },
        "told" => Response::Told {
            recorded: usize_from_json(root.get("recorded"), "recorded")?,
            extended: usize_from_json(root.get("extended"), "extended")?,
        },
        "beat" => Response::Beat {
            renewed: usize_from_json(root.get("renewed"), "renewed")?,
        },
        "status" => Response::Status {
            study: study()?,
            recorded: usize_from_json(root.get("recorded"), "recorded")?,
            in_flight: usize_from_json(
                root.get("in_flight"),
                "in_flight",
            )?,
            complete: root.get("complete").as_bool().context("complete")?,
            stopped: root.get("stopped").as_bool().context("stopped")?,
            // Absent in pre-quarantine peers; default 0.
            poisoned: match root.get("poisoned") {
                Json::Null => 0,
                other => usize_from_json(other, "poisoned")?,
            },
            best: match root.get("best") {
                Json::Null => None,
                other => Some(WireBest {
                    eval_id: usize_from_json(
                        other.get("eval"),
                        "best eval",
                    )?,
                    objective: other
                        .get("objective")
                        .as_f64()
                        .context("best objective")?,
                }),
            },
            config_toml: str_from_json(
                root.get("config_toml"),
                "config_toml",
            )?,
        },
        "stopped" => Response::Stopped { study: study()? },
        "studies" => Response::Studies {
            studies: root
                .get("studies")
                .as_arr()
                .context("studies")?
                .iter()
                .map(|s| str_from_json(s, "study id"))
                .collect::<Result<Vec<_>>>()?,
        },
        "error" => Response::Error {
            code: ErrorCode::from_str(
                root.get("code").as_str().context("code")?,
            )?,
            message: str_from_json(root.get("message"), "message")?,
        },
        other => return Err(anyhow!("unknown response type {other:?}")),
    })
}

/// Parse one response line written by [`response_to_line`].
pub fn response_from_line(line: &str) -> Result<Response> {
    let root = parse(line.trim())
        .map_err(|e| anyhow!("response parse: {e}"))?;
    response_from_root(&root)
}

/// Parse one response line plus its optional echoed sequence number
/// (see [`response_to_line_seq`]).
pub fn response_from_line_seq(line: &str) -> Result<(Option<u64>, Response)> {
    let root = parse(line.trim())
        .map_err(|e| anyhow!("response parse: {e}"))?;
    Ok((seq_from_root(&root)?, response_from_root(&root)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome() -> TrialOutcome {
        TrialOutcome {
            loss: 0.123456789123456789,
            dropout_losses: vec![0.5, 0.25],
            predictions: Some(vec![1.0, -2.5]),
            dropout_predictions: vec![vec![0.1], vec![0.2]],
            cost: Duration::from_nanos(u64::MAX - 3),
        }
    }

    #[test]
    fn every_request_roundtrips() {
        let reqs = vec![
            Request::CreateStudy {
                study: "s1".into(),
                config_toml: "[hpo]\nseed = 1\n".into(),
            },
            Request::Ask { study: "s1".into(), worker: "w0".into() },
            Request::Tell {
                study: "s1".into(),
                worker: "w0".into(),
                eval_id: 7,
                trial: 2,
                outcome: outcome(),
            },
            Request::Heartbeat {
                study: "s1".into(),
                worker: "w0".into(),
                eval: None,
            },
            Request::Heartbeat {
                study: "s1".into(),
                worker: "w0".into(),
                eval: Some(7),
            },
            Request::StudyStatus { study: "s1".into() },
            Request::StopStudy { study: "s1".into() },
            Request::ListStudies,
        ];
        for r in reqs {
            let line = request_to_line(&r);
            assert!(!line.contains('\n'), "line-delimited framing");
            let back = request_from_line(&line).unwrap();
            match (&r, &back) {
                (
                    Request::Tell { outcome: a, .. },
                    Request::Tell { outcome: b, .. },
                ) => {
                    assert_eq!(a.loss.to_bits(), b.loss.to_bits());
                    assert_eq!(a.cost, b.cost);
                    assert_eq!(a.predictions, b.predictions);
                }
                _ => assert_eq!(r, back),
            }
        }
    }

    #[test]
    fn every_response_roundtrips() {
        let resps = vec![
            Response::Created { study: "s".into() },
            Response::Asked {
                study: "s".into(),
                job: Some(WireJob {
                    eval_id: 3,
                    theta: vec![
                        crate::space::Value::Int(4),
                        crate::space::Value::Float(0.25),
                    ],
                    seed: u64::MAX - 1,
                    trials: vec![0, 1, 2],
                    lease_ms: 5000,
                }),
                done: false,
            },
            Response::Asked { study: "s".into(), job: None, done: true },
            Response::Told { recorded: 1, extended: 0 },
            Response::Beat { renewed: 2 },
            Response::Status {
                study: "s".into(),
                recorded: 5,
                in_flight: 2,
                complete: false,
                stopped: false,
                poisoned: 1,
                best: Some(WireBest { eval_id: 4, objective: -0.5 }),
                config_toml: "[hpo]\n".into(),
            },
            Response::Stopped { study: "s".into() },
            Response::Studies { studies: vec!["a".into(), "b".into()] },
            Response::error(ErrorCode::DuplicateTell, "again"),
        ];
        for r in resps {
            let line = response_to_line(&r);
            assert!(!line.contains('\n'));
            assert_eq!(response_from_line(&line).unwrap(), r);
        }
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let line = request_to_line(&Request::ListStudies)
            .replace(PROTO_VERSION, "hyppo-serve-v0");
        let err = request_from_line(&line).unwrap_err();
        assert!(format!("{err:#}").contains("version"));
    }

    #[test]
    fn seq_envelope_roundtrips_and_stays_optional() {
        let req = Request::Ask { study: "s".into(), worker: "w".into() };
        let line = request_to_line_seq(&req, u64::MAX - 5);
        let (seq, back) = request_from_line_seq(&line).unwrap();
        assert_eq!(seq, Some(u64::MAX - 5));
        assert_eq!(back, req);
        // A sequence-free line parses with seq = None via the same fn,
        // and a seq-stamped line still parses via the plain parser.
        let bare = request_to_line(&req);
        assert_eq!(request_from_line_seq(&bare).unwrap(), (None, req.clone()));
        assert_eq!(request_from_line(&line).unwrap(), req);

        let resp = Response::Told { recorded: 1, extended: 0 };
        let echoed = response_to_line_seq(&resp, Some(9));
        let (seq, back) = response_from_line_seq(&echoed).unwrap();
        assert_eq!((seq, &back), (Some(9), &resp));
        let silent = response_to_line_seq(&resp, None);
        assert_eq!(silent, response_to_line(&resp));
        assert_eq!(response_from_line_seq(&silent).unwrap(), (None, resp));
    }

    #[test]
    fn status_without_poisoned_field_defaults_to_zero() {
        // PR 9 peers never emit "poisoned"; their status lines must
        // still parse.
        let modern = Response::Status {
            study: "s".into(),
            recorded: 2,
            in_flight: 0,
            complete: false,
            stopped: false,
            poisoned: 0,
            best: None,
            config_toml: String::new(),
        };
        let line = response_to_line(&modern).replace("\"poisoned\":0,", "");
        assert!(!line.contains("poisoned"), "field really removed");
        assert_eq!(response_from_line(&line).unwrap(), modern);
    }

    #[test]
    fn outcome_roundtrip_is_bit_exact() {
        let o = outcome();
        let back =
            outcome_from_json(&outcome_to_json(&o)).unwrap();
        assert_eq!(o.loss.to_bits(), back.loss.to_bits());
        assert_eq!(o.dropout_losses, back.dropout_losses);
        assert_eq!(o.predictions, back.predictions);
        assert_eq!(o.dropout_predictions, back.dropout_predictions);
        assert_eq!(o.cost, back.cost);
    }
}
