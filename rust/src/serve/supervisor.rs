//! Shard supervision policy: restart budget + jittered exponential
//! backoff (DESIGN.md §16).
//!
//! The policy is sans-IO and deterministic: it owns no threads, reads
//! no clock, and draws jitter from a seeded [`Rng`], so the exact
//! delay sequence a shard will see is a pure function of
//! `(config, shard id, failure count)` — which is what lets the chaos
//! suite assert restart counts analytically. The threaded shell
//! (`serve::pool`) does the actual sleeping, `catch_unwind`ing, and
//! WAL re-recovery; it asks this type only "what now?" after each
//! failure.
//!
//! Backoff is *full jitter* over an exponential envelope: failure
//! `n` (1-based) draws uniformly from `[base·2ⁿ⁻¹ / 2, base·2ⁿ⁻¹]`,
//! capped at `backoff_max_ms`. The budget is cumulative over the
//! shard's lifetime, not per-incident: a shard that keeps crashing
//! eventually stops burning CPU and degrades, exactly like a crash
//!-looping unit under any sane init system.

use crate::sampling::rng::Rng;

/// Restart-policy knobs (`[serve]` config).
#[derive(Debug, Clone)]
pub struct SupervisorConfig {
    /// Restarts granted before the shard is declared [`Degrade`]d;
    /// 0 means never restart (every failure degrades immediately).
    ///
    /// [`Degrade`]: SupervisorDecision::Degrade
    pub max_restarts: u32,
    /// Backoff envelope base, in milliseconds (failure 1 draws from
    /// `[base/2, base]`).
    pub backoff_base_ms: u64,
    /// Backoff envelope cap, in milliseconds.
    pub backoff_max_ms: u64,
    /// Seed for the jitter stream (forked per shard, so restarts of
    /// different shards don't synchronize into a thundering herd).
    pub jitter_seed: u64,
}

impl Default for SupervisorConfig {
    fn default() -> SupervisorConfig {
        SupervisorConfig {
            max_restarts: 3,
            backoff_base_ms: 100,
            backoff_max_ms: 5_000,
            jitter_seed: 0x5u64 << 32 | 0xec0_5ec,
        }
    }
}

/// What the shell should do about a shard failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SupervisorDecision {
    /// Sleep this many milliseconds, then restart the shard from its
    /// WAL (replay rebuilds the exact pre-crash state).
    RestartAfterMs(u64),
    /// Budget exhausted: put the shard into the typed `Degraded`
    /// state — reject mutations, keep serving status — instead of
    /// crash-looping.
    Degrade,
}

/// Per-shard supervision state: how many restarts were spent, and the
/// shard's private jitter stream.
#[derive(Debug)]
pub struct Supervisor {
    cfg: SupervisorConfig,
    shard: usize,
    restarts: u32,
    rng: Rng,
}

impl Supervisor {
    /// A fresh supervisor for `shard`. The jitter stream is
    /// `jitter_seed` forked by the shard index, so equal configs give
    /// different shards decorrelated delays.
    pub fn new(cfg: SupervisorConfig, shard: usize) -> Supervisor {
        let mut root = Rng::new(cfg.jitter_seed);
        let rng = root.fork(shard as u64);
        Supervisor { cfg, shard, restarts: 0, rng }
    }

    /// Shard index this supervisor governs.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Restarts spent so far.
    pub fn restarts(&self) -> u32 {
        self.restarts
    }

    /// Record one failure (panic or wedge) and decide what happens
    /// next. Consumes one unit of budget per restart granted.
    pub fn on_failure(&mut self) -> SupervisorDecision {
        if self.restarts >= self.cfg.max_restarts {
            return SupervisorDecision::Degrade;
        }
        self.restarts += 1;
        // Exponential envelope, saturating: base·2^(n-1) capped at max.
        let exp = self
            .cfg
            .backoff_base_ms
            .saturating_mul(
                1u64.checked_shl(self.restarts - 1).unwrap_or(u64::MAX),
            )
            .min(self.cfg.backoff_max_ms);
        // Full jitter: uniform in [exp/2, exp].
        let span = exp - exp / 2;
        let delay = exp / 2
            + if span > 0 { self.rng.next_u64() % (span + 1) } else { 0 };
        SupervisorDecision::RestartAfterMs(delay)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SupervisorConfig {
        SupervisorConfig {
            max_restarts: 3,
            backoff_base_ms: 100,
            backoff_max_ms: 5_000,
            jitter_seed: 42,
        }
    }

    #[test]
    fn budget_then_degrade() {
        let mut s = Supervisor::new(cfg(), 0);
        for n in 1..=3u32 {
            match s.on_failure() {
                SupervisorDecision::RestartAfterMs(d) => {
                    // Envelope for failure n: [base·2ⁿ⁻¹/2, base·2ⁿ⁻¹].
                    let exp = 100u64 * (1 << (n - 1));
                    assert!(
                        d >= exp / 2 && d <= exp,
                        "failure {n}: delay {d} outside [{}, {exp}]",
                        exp / 2
                    );
                }
                SupervisorDecision::Degrade => {
                    panic!("degraded inside the budget (failure {n})")
                }
            }
            assert_eq!(s.restarts(), n);
        }
        assert_eq!(s.on_failure(), SupervisorDecision::Degrade);
        assert_eq!(s.on_failure(), SupervisorDecision::Degrade);
        assert_eq!(s.restarts(), 3, "degrade spends no budget");
    }

    #[test]
    fn delays_are_deterministic_per_seed_and_shard() {
        let seq = |shard| {
            let mut s = Supervisor::new(cfg(), shard);
            (0..3)
                .map(|_| match s.on_failure() {
                    SupervisorDecision::RestartAfterMs(d) => d,
                    SupervisorDecision::Degrade => u64::MAX,
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(seq(0), seq(0), "same shard, same delays");
        assert_ne!(seq(0), seq(1), "shards are decorrelated");
    }

    #[test]
    fn backoff_saturates_at_the_cap() {
        let mut s = Supervisor::new(
            SupervisorConfig {
                max_restarts: 80,
                backoff_base_ms: 1_000,
                backoff_max_ms: 2_000,
                jitter_seed: 7,
            },
            0,
        );
        // Far past where 2ⁿ would overflow a shift: the envelope must
        // sit at the cap, not wrap.
        for _ in 0..80 {
            match s.on_failure() {
                SupervisorDecision::RestartAfterMs(d) => {
                    assert!(d <= 2_000);
                }
                SupervisorDecision::Degrade => break,
            }
        }
    }

    #[test]
    fn zero_budget_degrades_immediately() {
        let mut s = Supervisor::new(
            SupervisorConfig { max_restarts: 0, ..cfg() },
            0,
        );
        assert_eq!(s.on_failure(), SupervisorDecision::Degrade);
    }
}
