//! `serve`: the sharded multi-study HPO service (DESIGN.md §15).
//!
//! Layers, inside out:
//!
//! * [`shard`] — the single-owner state machine: a [`ShardCore`] owns a
//!   disjoint set of studies (each an `exec::Session` plus a lease
//!   table) and processes commands one at a time. Determinism contract:
//!   same commands, same order, same clock readings → bit-identical
//!   sessions.
//! * [`wal`] — per-shard write-ahead log: every state-changing command
//!   (asks included — they advance the RNG) is a durable
//!   length-prefixed JSON record; replay rebuilds the shard
//!   bit-for-bit, snapshot+truncate compaction bounds the log, and the
//!   snapshot unit doubles as the migration hand-off.
//! * [`proto`] — the versioned (`hyppo-serve-v1`) line-delimited JSON
//!   ask/tell wire protocol and the transport-agnostic [`Client`]
//!   trait.
//! * [`service`] — N shards plus FNV-1a routing, recovery, and
//!   migration; [`pool`] — the threaded shell (one owning thread and
//!   FIFO queue per shard); [`net`] — the TCP accept loop and client;
//!   [`local`] — the reference worker loop and in-process worker pool.
//! * [`clock`] — injected time ([`Clock`]): lease expiry is driven by
//!   a [`VirtualClock`] in tests (making timeouts part of the
//!   reproducible command stream) and a [`SystemClock`] in production.
//! * [`supervisor`] — the sans-IO restart policy behind shard
//!   supervision: jittered exponential backoff under a cumulative
//!   restart budget; exhaustion parks the shard in the typed
//!   `Degraded` state (DESIGN.md §16).
//!
//! Entry points: `hyppo serve` (TCP server) and `hyppo worker` (remote
//! trial worker); `tests/serve.rs` proves crash-replay and
//! service-vs-bare-session bit-identity, and `tests/serve_chaos.rs`
//! proves the failure-domain contracts (supervised restart identity,
//! WAL failover chains, poison-trial quarantine, retry/dedup).

pub mod clock;
pub mod local;
pub mod net;
pub mod pool;
pub mod proto;
pub mod service;
pub mod shard;
pub mod supervisor;
pub mod wal;

pub use clock::{Clock, SystemClock, VirtualClock};
pub use local::{run_local, worker_loop, WorkerReport};
pub use net::{
    serve_listener, Connector, LineServer, RetryClient, RetryPolicy,
    TcpClient, Transport,
};
pub use pool::{PoolClient, ShardPool, WalIoFactory};
pub use proto::{
    Client, ErrorCode, Request, Response, WireBest, WireJob,
    PROTO_VERSION,
};
pub use service::{route, ServeConfig, Service};
pub use shard::{
    Lease, ShardCore, ShardCounters, ShardHealth, ShardOpts,
};
pub use supervisor::{Supervisor, SupervisorConfig, SupervisorDecision};
pub use wal::{
    FsWalIo, ShardSnapshot, StudySnapshot, Wal, WalFailure, WalIo,
    WalRecord,
};
