//! Per-shard append-only write-ahead log (DESIGN.md §15).
//!
//! The shard's in-memory state (a fleet of `exec::Session`s) is the
//! *cache*; the WAL is the truth. Every state-changing command — study
//! creation, evaluation hand-out, outcome delivery, requeue, stop,
//! migration — is appended as one length-prefixed JSON record and
//! `fsync`ed (`util::fsio::append_sync`) before the command is
//! acknowledged, so replaying the log from an empty shard rebuilds a
//! **bit-identical** session fleet: same RNG stream, same histories,
//! same refit counters (proven in `tests/serve.rs`).
//!
//! Ask records are logged too, not just tells: a proposal-creating
//! `ask` advances the session RNG and depends on the history at ask
//! time, so the ask stream is part of the decision state. Each ask
//! record carries the evaluation id and trial set it handed out, which
//! replay verifies against the rebuilt session — any divergence is a
//! corruption error, never a silently different experiment.
//!
//! # Framing
//!
//! One record per line: `<len> <json>\n`, where `len` is the byte
//! length of the JSON text. A crash mid-append leaves a torn tail —
//! a record whose bytes run out before `len` (or whose trailing
//! newline is missing) — which recovery tolerates by dropping it: it
//! was never acknowledged. Malformed bytes *followed by more records*
//! are corruption and fail loudly.
//!
//! # Generations and compaction
//!
//! Files are `wal-<shard>.<gen>.log` plus `snap-<shard>.<gen>.json`.
//! Compaction snapshots every study (config + `Checkpoint` wire form,
//! reusing the `Checkpoint::wire_roundtrip` plumbing) into generation
//! G+1 with one atomic durable write, then retires generation G. A
//! snapshot restore rebuilds the surrogate by preloading the recorded
//! history (a full refit), so refit *counters* reset across a
//! compaction boundary — histories stay bit-identical (the same
//! semantics as the chaos testbed's checkpoint restarts). The same
//! `StudySnapshot` unit is the migration hand-off between shards.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::eval::TrialOutcome;
use crate::exec::Checkpoint;
use crate::serve::proto::{outcome_from_json, outcome_to_json};
use crate::util::fsio::{append_sync, atomic_write_sync};
use crate::util::json::{parse, write, Json};

/// WAL format version tag carried by every record and snapshot.
pub const WAL_VERSION: &str = "hyppo-wal-v1";

/// One logged state transition of a shard.
#[derive(Debug, Clone)]
pub enum WalRecord {
    /// A study was registered with this config document.
    Create { study: String, config_toml: String },
    /// `ask_eval` handed out `trials` of evaluation `eval_id`. Replay
    /// re-asks and verifies the session hands out exactly this.
    Ask { study: String, eval_id: usize, trials: Vec<usize> },
    /// One trial outcome was absorbed.
    Tell {
        study: String,
        eval_id: usize,
        trial: usize,
        outcome: TrialOutcome,
    },
    /// An in-flight evaluation was requeued (lease expiry or recovery).
    Requeue { study: String, eval_id: usize },
    /// The study stopped handing out work.
    Stop { study: String },
    /// The study migrated away from this shard.
    Evict { study: String },
    /// The study migrated onto this shard with this snapshot.
    Import(StudySnapshot),
}

/// A study's durable form: everything needed to rebuild its session on
/// another shard (migration) or after compaction.
#[derive(Debug, Clone)]
pub struct StudySnapshot {
    /// Study id.
    pub study: String,
    /// The run-config document the study was created with.
    pub config_toml: String,
    /// Whether the study was stopped.
    pub stopped: bool,
    /// The session's decision state in checkpoint wire form.
    pub checkpoint: Checkpoint,
}

/// A whole-shard snapshot written by compaction.
#[derive(Debug, Clone)]
pub struct ShardSnapshot {
    /// Generation this snapshot begins.
    pub generation: u64,
    /// Every study owned by the shard, sorted by id.
    pub studies: Vec<StudySnapshot>,
}

// ---------------------------------------------------------------------
// JSON forms
// ---------------------------------------------------------------------

fn study_snapshot_to_json(s: &StudySnapshot) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert("study".into(), Json::Str(s.study.clone()));
    m.insert("config_toml".into(), Json::Str(s.config_toml.clone()));
    m.insert("stopped".into(), Json::Bool(s.stopped));
    // The checkpoint travels in its own wire format (a JSON string),
    // so WAL snapshots exercise exactly the kill/resume serialization.
    m.insert(
        "checkpoint".into(),
        Json::Str(s.checkpoint.to_json_string()),
    );
    Json::Obj(m)
}

fn study_snapshot_from_json(v: &Json) -> Result<StudySnapshot> {
    let ckpt_text =
        v.get("checkpoint").as_str().context("snapshot checkpoint")?;
    Ok(StudySnapshot {
        study: v
            .get("study")
            .as_str()
            .context("snapshot study")?
            .to_string(),
        config_toml: v
            .get("config_toml")
            .as_str()
            .context("snapshot config_toml")?
            .to_string(),
        stopped: v.get("stopped").as_bool().context("snapshot stopped")?,
        checkpoint: Checkpoint::from_json_str(ckpt_text)
            .context("snapshot checkpoint body")?,
    })
}

fn record_to_json(r: &WalRecord) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert("v".into(), Json::Str(WAL_VERSION.into()));
    match r {
        WalRecord::Create { study, config_toml } => {
            m.insert("t".into(), Json::Str("create".into()));
            m.insert("study".into(), Json::Str(study.clone()));
            m.insert("config_toml".into(), Json::Str(config_toml.clone()));
        }
        WalRecord::Ask { study, eval_id, trials } => {
            m.insert("t".into(), Json::Str("ask".into()));
            m.insert("study".into(), Json::Str(study.clone()));
            m.insert("eval".into(), Json::Num(*eval_id as f64));
            m.insert(
                "trials".into(),
                Json::Arr(
                    trials.iter().map(|t| Json::Num(*t as f64)).collect(),
                ),
            );
        }
        WalRecord::Tell { study, eval_id, trial, outcome } => {
            m.insert("t".into(), Json::Str("tell".into()));
            m.insert("study".into(), Json::Str(study.clone()));
            m.insert("eval".into(), Json::Num(*eval_id as f64));
            m.insert("trial".into(), Json::Num(*trial as f64));
            m.insert("outcome".into(), outcome_to_json(outcome));
        }
        WalRecord::Requeue { study, eval_id } => {
            m.insert("t".into(), Json::Str("requeue".into()));
            m.insert("study".into(), Json::Str(study.clone()));
            m.insert("eval".into(), Json::Num(*eval_id as f64));
        }
        WalRecord::Stop { study } => {
            m.insert("t".into(), Json::Str("stop".into()));
            m.insert("study".into(), Json::Str(study.clone()));
        }
        WalRecord::Evict { study } => {
            m.insert("t".into(), Json::Str("evict".into()));
            m.insert("study".into(), Json::Str(study.clone()));
        }
        WalRecord::Import(snap) => {
            m.insert("t".into(), Json::Str("import".into()));
            m.insert("snapshot".into(), study_snapshot_to_json(snap));
        }
    }
    Json::Obj(m)
}

fn usize_field(v: &Json, what: &str) -> Result<usize> {
    let i = v.as_i64().with_context(|| format!("{what}: expected int"))?;
    usize::try_from(i).map_err(|_| anyhow!("{what}: negative"))
}

fn str_field(v: &Json, what: &str) -> Result<String> {
    Ok(v.as_str()
        .with_context(|| format!("{what}: expected string"))?
        .to_string())
}

fn record_from_json(root: &Json) -> Result<WalRecord> {
    let ver = root.get("v").as_str().context("record version")?;
    if ver != WAL_VERSION {
        bail!("WAL version mismatch: got {ver:?}, want {WAL_VERSION:?}");
    }
    let tag = root.get("t").as_str().context("record tag")?;
    let study = || str_field(root.get("study"), "record study");
    Ok(match tag {
        "create" => WalRecord::Create {
            study: study()?,
            config_toml: str_field(
                root.get("config_toml"),
                "record config_toml",
            )?,
        },
        "ask" => WalRecord::Ask {
            study: study()?,
            eval_id: usize_field(root.get("eval"), "record eval")?,
            trials: root
                .get("trials")
                .as_arr()
                .context("record trials")?
                .iter()
                .map(|t| usize_field(t, "record trial"))
                .collect::<Result<Vec<_>>>()?,
        },
        "tell" => WalRecord::Tell {
            study: study()?,
            eval_id: usize_field(root.get("eval"), "record eval")?,
            trial: usize_field(root.get("trial"), "record trial")?,
            outcome: outcome_from_json(root.get("outcome"))?,
        },
        "requeue" => WalRecord::Requeue {
            study: study()?,
            eval_id: usize_field(root.get("eval"), "record eval")?,
        },
        "stop" => WalRecord::Stop { study: study()? },
        "evict" => WalRecord::Evict { study: study()? },
        "import" => WalRecord::Import(study_snapshot_from_json(
            root.get("snapshot"),
        )?),
        other => bail!("unknown WAL record tag {other:?}"),
    })
}

/// Encode one record in the `<len> <json>\n` framing.
pub fn encode_record(r: &WalRecord) -> String {
    let body = write(&record_to_json(r));
    format!("{} {}\n", body.len(), body)
}

/// Parse `<len> ` starting at byte `at`; returns `(len, body_start)`.
fn parse_len(bytes: &[u8], mut at: usize) -> Option<(usize, usize)> {
    let mut len = 0usize;
    let mut digits = 0usize;
    loop {
        match bytes.get(at) {
            Some(b @ b'0'..=b'9') => {
                len = len
                    .checked_mul(10)?
                    .checked_add(usize::from(b - b'0'))?;
                digits += 1;
                at += 1;
            }
            Some(b' ') if digits > 0 => return Some((len, at + 1)),
            _ => return None,
        }
    }
}

/// Decode a record stream. The torn tail a crash mid-append leaves —
/// a final record whose bytes run out early or whose newline is
/// missing — is silently dropped (it was never acknowledged); any
/// malformation *before* the end of the stream is a hard error.
pub fn decode_stream(bytes: &[u8]) -> Result<Vec<WalRecord>> {
    let mut records = Vec::new();
    let mut at = 0usize;
    while at < bytes.len() {
        let Some((len, body_start)) = parse_len(bytes, at) else {
            // No complete `<len> ` prefix: only legal as a torn tail.
            if bytes.get(at..).map(|r| r.contains(&b'\n')).unwrap_or(false)
            {
                bail!("corrupt WAL framing at byte {at}");
            }
            break;
        };
        let body_end = body_start.saturating_add(len);
        let Some(body) = bytes.get(body_start..body_end) else {
            break; // body runs past EOF: torn tail
        };
        match bytes.get(body_end) {
            Some(b'\n') => {}
            None => break, // newline missing at EOF: torn tail
            Some(_) => bail!(
                "corrupt WAL record at byte {at}: missing newline"
            ),
        }
        let text = std::str::from_utf8(body)
            .map_err(|_| anyhow!("corrupt WAL record at byte {at}"))?;
        let root = parse(text).map_err(|e| {
            anyhow!("corrupt WAL record at byte {at}: {e}")
        })?;
        records.push(record_from_json(&root)?);
        at = body_end + 1;
    }
    Ok(records)
}

fn shard_snapshot_to_json(s: &ShardSnapshot) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert("v".into(), Json::Str(WAL_VERSION.into()));
    m.insert("generation".into(), Json::Str(s.generation.to_string()));
    m.insert(
        "studies".into(),
        Json::Arr(s.studies.iter().map(study_snapshot_to_json).collect()),
    );
    Json::Obj(m)
}

fn shard_snapshot_from_json(root: &Json) -> Result<ShardSnapshot> {
    let ver = root.get("v").as_str().context("snapshot version")?;
    if ver != WAL_VERSION {
        bail!("snapshot version mismatch: got {ver:?}");
    }
    let generation = root
        .get("generation")
        .as_str()
        .context("snapshot generation")?
        .parse::<u64>()
        .context("snapshot generation")?;
    Ok(ShardSnapshot {
        generation,
        studies: root
            .get("studies")
            .as_arr()
            .context("snapshot studies")?
            .iter()
            .map(study_snapshot_from_json)
            .collect::<Result<Vec<_>>>()?,
    })
}

// ---------------------------------------------------------------------
// On-disk layout
// ---------------------------------------------------------------------

/// One shard's log handle: the current generation's append target plus
/// the compaction machinery.
#[derive(Debug)]
pub struct Wal {
    dir: PathBuf,
    shard: usize,
    generation: u64,
}

fn log_path(dir: &Path, shard: usize, generation: u64) -> PathBuf {
    dir.join(format!("wal-{shard}.{generation}.log"))
}

fn snap_path(dir: &Path, shard: usize, generation: u64) -> PathBuf {
    dir.join(format!("snap-{shard}.{generation}.json"))
}

/// Parse `<stem>-<shard>.<gen>.<ext>`; returns the generation when the
/// name belongs to this shard.
fn parse_gen(name: &str, stem: &str, shard: usize, ext: &str) -> Option<u64> {
    let rest = name.strip_prefix(&format!("{stem}-{shard}."))?;
    rest.strip_suffix(&format!(".{ext}"))?.parse().ok()
}

impl Wal {
    /// Open (or initialize) the shard's WAL under `dir`, resuming the
    /// highest generation present on disk.
    pub fn open(dir: &Path, shard: usize) -> Result<Wal> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("mkdir {}", dir.display()))?;
        let mut generation = 0u64;
        for entry in std::fs::read_dir(dir)
            .with_context(|| format!("scanning {}", dir.display()))?
        {
            let name = entry?.file_name();
            let Some(name) = name.to_str() else { continue };
            for g in [
                parse_gen(name, "wal", shard, "log"),
                parse_gen(name, "snap", shard, "json"),
            ]
            .into_iter()
            .flatten()
            {
                generation = generation.max(g);
            }
        }
        Ok(Wal { dir: dir.to_path_buf(), shard, generation })
    }

    /// True when any WAL or snapshot file for `shard` exists in `dir`.
    pub fn exists(dir: &Path, shard: usize) -> bool {
        let Ok(entries) = std::fs::read_dir(dir) else { return false };
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if parse_gen(name, "wal", shard, "log").is_some()
                || parse_gen(name, "snap", shard, "json").is_some()
            {
                return true;
            }
        }
        false
    }

    /// The generation currently being appended to.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// The current generation's log file.
    pub fn log_file(&self) -> PathBuf {
        log_path(&self.dir, self.shard, self.generation)
    }

    /// Durably append one record (fsync before return — see
    /// `util::fsio::append_sync`).
    pub fn append(&self, record: &WalRecord) -> Result<()> {
        append_sync(&self.log_file(), encode_record(record).as_bytes())
    }

    /// Load the current generation: its snapshot (if compaction ever
    /// ran) plus every record appended since, torn tail dropped.
    pub fn load(&self) -> Result<(Option<ShardSnapshot>, Vec<WalRecord>)> {
        let snap = snap_path(&self.dir, self.shard, self.generation);
        let snapshot = if snap.is_file() {
            let text = std::fs::read_to_string(&snap)
                .with_context(|| format!("reading {}", snap.display()))?;
            let root = parse(&text).map_err(|e| {
                anyhow!("parsing {}: {e}", snap.display())
            })?;
            Some(shard_snapshot_from_json(&root)?)
        } else {
            None
        };
        let log = self.log_file();
        let records = if log.is_file() {
            let bytes = std::fs::read(&log)
                .with_context(|| format!("reading {}", log.display()))?;
            decode_stream(&bytes)
                .with_context(|| format!("replaying {}", log.display()))?
        } else {
            Vec::new()
        };
        Ok((snapshot, records))
    }

    /// Snapshot + truncate: durably write `studies` as generation G+1,
    /// switch appends to the new generation, then retire generation G's
    /// files (best-effort — stale files are ignored by recovery, which
    /// always loads the highest generation).
    pub fn compact(&mut self, studies: Vec<StudySnapshot>) -> Result<()> {
        let next = self.generation + 1;
        let snap = ShardSnapshot { generation: next, studies };
        let body = write(&shard_snapshot_to_json(&snap));
        atomic_write_sync(
            &snap_path(&self.dir, self.shard, next),
            body.as_bytes(),
        )?;
        let old = self.generation;
        self.generation = next;
        std::fs::remove_file(log_path(&self.dir, self.shard, old)).ok();
        std::fs::remove_file(snap_path(&self.dir, self.shard, old)).ok();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn outcome(loss: f64) -> TrialOutcome {
        TrialOutcome {
            loss,
            dropout_losses: vec![loss * 2.0],
            predictions: None,
            dropout_predictions: vec![],
            cost: Duration::from_millis(3),
        }
    }

    fn records() -> Vec<WalRecord> {
        vec![
            WalRecord::Create {
                study: "s1".into(),
                config_toml: "[hpo]\nseed = 1\n".into(),
            },
            WalRecord::Ask {
                study: "s1".into(),
                eval_id: 0,
                trials: vec![0, 1],
            },
            WalRecord::Tell {
                study: "s1".into(),
                eval_id: 0,
                trial: 0,
                outcome: outcome(0.5),
            },
            WalRecord::Requeue { study: "s1".into(), eval_id: 0 },
            WalRecord::Stop { study: "s1".into() },
            WalRecord::Evict { study: "s1".into() },
        ]
    }

    #[test]
    fn stream_roundtrips() {
        let mut buf = String::new();
        for r in records() {
            buf.push_str(&encode_record(&r));
        }
        let back = decode_stream(buf.as_bytes()).unwrap();
        assert_eq!(back.len(), records().len());
        match (&back[2], &records()[2]) {
            (
                WalRecord::Tell { outcome: a, .. },
                WalRecord::Tell { outcome: b, .. },
            ) => assert_eq!(a.loss.to_bits(), b.loss.to_bits()),
            _ => panic!("record order changed"),
        }
    }

    #[test]
    fn torn_tail_is_dropped_not_fatal() {
        let mut buf = String::new();
        for r in records().into_iter().take(3) {
            buf.push_str(&encode_record(&r));
        }
        let full = decode_stream(buf.as_bytes()).unwrap().len();
        // Chop bytes off the end: every prefix decodes to ≤ full
        // records and never errors (the torn record simply vanishes).
        for cut in 1..60 {
            let bytes = &buf.as_bytes()[..buf.len() - cut];
            let got = decode_stream(bytes).unwrap();
            assert!(got.len() <= full);
        }
    }

    #[test]
    fn mid_stream_corruption_is_fatal() {
        let mut buf = String::new();
        for r in records().into_iter().take(2) {
            buf.push_str(&encode_record(&r));
        }
        let mut bytes = buf.into_bytes();
        // Flip a byte inside the FIRST record's JSON body.
        bytes[10] ^= 0x55;
        assert!(decode_stream(&bytes).is_err());
    }

    #[test]
    fn wal_open_append_load_compact() {
        let dir =
            std::env::temp_dir().join("hyppo_wal_test_open_append");
        std::fs::remove_dir_all(&dir).ok();
        let mut wal = Wal::open(&dir, 0).unwrap();
        assert_eq!(wal.generation(), 0);
        assert!(!Wal::exists(&dir, 0));
        for r in records().into_iter().take(2) {
            wal.append(&r).unwrap();
        }
        assert!(Wal::exists(&dir, 0));
        let (snap, recs) = wal.load().unwrap();
        assert!(snap.is_none());
        assert_eq!(recs.len(), 2);

        // Compaction bumps the generation and retires the old log.
        wal.compact(vec![]).unwrap();
        assert_eq!(wal.generation(), 1);
        let (snap, recs) = wal.load().unwrap();
        assert_eq!(snap.unwrap().generation, 1);
        assert!(recs.is_empty());

        // Reopen resumes the highest generation.
        let again = Wal::open(&dir, 0).unwrap();
        assert_eq!(again.generation(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
