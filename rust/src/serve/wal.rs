//! Per-shard append-only write-ahead log (DESIGN.md §15).
//!
//! The shard's in-memory state (a fleet of `exec::Session`s) is the
//! *cache*; the WAL is the truth. Every state-changing command — study
//! creation, evaluation hand-out, outcome delivery, requeue, stop,
//! migration — is appended as one length-prefixed JSON record and
//! `fsync`ed (`util::fsio::append_sync`) before the command is
//! acknowledged, so replaying the log from an empty shard rebuilds a
//! **bit-identical** session fleet: same RNG stream, same histories,
//! same refit counters (proven in `tests/serve.rs`).
//!
//! Ask records are logged too, not just tells: a proposal-creating
//! `ask` advances the session RNG and depends on the history at ask
//! time, so the ask stream is part of the decision state. Each ask
//! record carries the evaluation id and trial set it handed out, which
//! replay verifies against the rebuilt session — any divergence is a
//! corruption error, never a silently different experiment.
//!
//! # Framing
//!
//! One record per line: `<len> <json>\n`, where `len` is the byte
//! length of the JSON text. A crash mid-append leaves a torn tail —
//! a record whose bytes run out before `len` (or whose trailing
//! newline is missing) — which recovery tolerates by dropping it: it
//! was never acknowledged. Malformed bytes *followed by more records*
//! are corruption and fail loudly.
//!
//! # Generations and compaction
//!
//! Files are `wal-<shard>.<gen>.log` plus `snap-<shard>.<gen>.json`.
//! Compaction snapshots every study (config + `Checkpoint` wire form,
//! reusing the `Checkpoint::wire_roundtrip` plumbing) into generation
//! G+1 with one atomic durable write, then retires generation G. A
//! snapshot restore rebuilds the surrogate by preloading the recorded
//! history (a full refit), so refit *counters* reset across a
//! compaction boundary — histories stay bit-identical (the same
//! semantics as the chaos testbed's checkpoint restarts). The same
//! `StudySnapshot` unit is the migration hand-off between shards.
//!
//! # Failover chain (DESIGN.md §16)
//!
//! Under `wal_failure = failover` the WAL carries a secondary
//! directory. When an append to the primary fails, the log *switches*:
//! a `WalSwitch` frame is appended to the same generation's log file in
//! the failover directory, followed by the record that failed, and all
//! subsequent appends go there. Replay chases the chain — primary
//! records first (a torn tail from the failed append is dropped as
//! usual), then, after verifying the `WalSwitch` frame names this shard
//! and generation, the failover records — so a switched log replays
//! exactly like an unswitched one. `WalSwitch` frames are consumed by
//! the chain logic and never surface to the shard. Disk access goes
//! through the [`WalIo`] trait so `cluster::faults` can inject append
//! errors, torn tails, and slow fsyncs underneath an unmodified shard.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::eval::TrialOutcome;
use crate::exec::Checkpoint;
use crate::serve::proto::{outcome_from_json, outcome_to_json};
use crate::util::fsio::{append_sync, atomic_write_sync};
use crate::util::json::{parse, write, Json};

/// WAL format version tag carried by every record and snapshot.
pub const WAL_VERSION: &str = "hyppo-wal-v1";

/// What a shard does when a WAL append fails (`[serve] wal_failure`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WalFailure {
    /// Wedge the shard: every subsequent command is rejected with
    /// `Internal` (PR 9 behaviour, and the safest default — nothing
    /// unlogged is ever acknowledged).
    Wedge,
    /// Degrade to read-only: mutations are rejected with
    /// `ShardDegraded`, but `study_status` / `list_studies` keep
    /// working so operators can see what is stranded.
    Readonly,
    /// Switch appends to the configured failover directory, recording a
    /// `WalSwitch` frame so replay chases the chain.
    Failover,
}

impl WalFailure {
    /// Stable config-file identifier.
    pub fn as_str(&self) -> &'static str {
        match self {
            WalFailure::Wedge => "wedge",
            WalFailure::Readonly => "readonly",
            WalFailure::Failover => "failover",
        }
    }

    /// Parse a config-file identifier.
    pub fn from_str(s: &str) -> Result<WalFailure> {
        Ok(match s {
            "wedge" => WalFailure::Wedge,
            "readonly" => WalFailure::Readonly,
            "failover" => WalFailure::Failover,
            other => bail!(
                "unknown wal_failure policy {other:?} \
                 (expected wedge | readonly | failover)"
            ),
        })
    }
}

/// Durable-storage access used by [`Wal`]. The production
/// implementation is [`FsWalIo`] (fsync-on-append via `util::fsio`);
/// `cluster::faults::FaultyWalIo` wraps one to inject append errors,
/// torn tails, and slow fsyncs for the chaos suite.
pub trait WalIo: Send + std::fmt::Debug {
    /// Durably append `bytes` to `path` (create if absent).
    fn append(&mut self, path: &Path, bytes: &[u8]) -> Result<()>;
    /// Atomically and durably replace `path` with `bytes`.
    fn atomic_write(&mut self, path: &Path, bytes: &[u8]) -> Result<()>;
}

/// The real filesystem: `util::fsio`'s crash-durable primitives.
#[derive(Debug, Default)]
pub struct FsWalIo;

impl WalIo for FsWalIo {
    fn append(&mut self, path: &Path, bytes: &[u8]) -> Result<()> {
        append_sync(path, bytes)
    }
    fn atomic_write(&mut self, path: &Path, bytes: &[u8]) -> Result<()> {
        atomic_write_sync(path, bytes)
    }
}

/// One logged state transition of a shard.
#[derive(Debug, Clone)]
pub enum WalRecord {
    /// A study was registered with this config document.
    Create { study: String, config_toml: String },
    /// `ask_eval` handed out `trials` of evaluation `eval_id`. Replay
    /// re-asks and verifies the session hands out exactly this.
    Ask { study: String, eval_id: usize, trials: Vec<usize> },
    /// One trial outcome was absorbed.
    Tell {
        study: String,
        eval_id: usize,
        trial: usize,
        outcome: TrialOutcome,
    },
    /// An in-flight evaluation was requeued (lease expiry or recovery).
    Requeue { study: String, eval_id: usize },
    /// An evaluation exhausted its retry budget and was quarantined:
    /// every outstanding trial was scored as `penalty`. The penalty is
    /// logged *in the record* so replay reproduces the history even if
    /// the configured penalty changes between runs.
    Poison { study: String, eval_id: usize, penalty: f64 },
    /// The log switched to the failover directory mid-generation. Only
    /// legal as the first frame of a failover log; consumed by
    /// [`Wal::load`], never surfaced to the shard.
    WalSwitch { shard: usize, generation: u64, from: String },
    /// The study stopped handing out work.
    Stop { study: String },
    /// The study migrated away from this shard.
    Evict { study: String },
    /// The study migrated onto this shard with this snapshot.
    Import(StudySnapshot),
}

/// A study's durable form: everything needed to rebuild its session on
/// another shard (migration) or after compaction.
#[derive(Debug, Clone)]
pub struct StudySnapshot {
    /// Study id.
    pub study: String,
    /// The run-config document the study was created with.
    pub config_toml: String,
    /// Whether the study was stopped.
    pub stopped: bool,
    /// Evaluations quarantined so far (monotone counter; the penalty
    /// records themselves live in the checkpoint history).
    pub poisoned: usize,
    /// Lease-expiry strike counts for still-pending evaluations, by
    /// evaluation id — the quarantine decision state, which must
    /// survive compaction and migration or a pathological trial's
    /// count would reset with every snapshot.
    pub fail_counts: std::collections::BTreeMap<usize, usize>,
    /// The session's decision state in checkpoint wire form.
    pub checkpoint: Checkpoint,
}

/// A whole-shard snapshot written by compaction.
#[derive(Debug, Clone)]
pub struct ShardSnapshot {
    /// Generation this snapshot begins.
    pub generation: u64,
    /// Every study owned by the shard, sorted by id.
    pub studies: Vec<StudySnapshot>,
}

// ---------------------------------------------------------------------
// JSON forms
// ---------------------------------------------------------------------

fn study_snapshot_to_json(s: &StudySnapshot) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert("study".into(), Json::Str(s.study.clone()));
    m.insert("config_toml".into(), Json::Str(s.config_toml.clone()));
    m.insert("stopped".into(), Json::Bool(s.stopped));
    m.insert("poisoned".into(), Json::Num(s.poisoned as f64));
    let mut fc = std::collections::BTreeMap::new();
    for (id, strikes) in &s.fail_counts {
        fc.insert(id.to_string(), Json::Num(*strikes as f64));
    }
    m.insert("fail_counts".into(), Json::Obj(fc));
    // The checkpoint travels in its own wire format (a JSON string),
    // so WAL snapshots exercise exactly the kill/resume serialization.
    m.insert(
        "checkpoint".into(),
        Json::Str(s.checkpoint.to_json_string()),
    );
    Json::Obj(m)
}

fn study_snapshot_from_json(v: &Json) -> Result<StudySnapshot> {
    let ckpt_text =
        v.get("checkpoint").as_str().context("snapshot checkpoint")?;
    // `poisoned` / `fail_counts` are absent in pre-quarantine
    // snapshots; default to a clean record.
    let poisoned = match v.get("poisoned") {
        Json::Null => 0,
        other => usize_field(other, "snapshot poisoned")?,
    };
    let mut fail_counts = std::collections::BTreeMap::new();
    if let Json::Obj(fc) = v.get("fail_counts") {
        for (id, strikes) in fc {
            fail_counts.insert(
                id.parse::<usize>().with_context(|| {
                    format!("snapshot fail_counts key {id:?}")
                })?,
                usize_field(strikes, "snapshot fail_counts value")?,
            );
        }
    }
    Ok(StudySnapshot {
        study: v
            .get("study")
            .as_str()
            .context("snapshot study")?
            .to_string(),
        config_toml: v
            .get("config_toml")
            .as_str()
            .context("snapshot config_toml")?
            .to_string(),
        stopped: v.get("stopped").as_bool().context("snapshot stopped")?,
        poisoned,
        fail_counts,
        checkpoint: Checkpoint::from_json_str(ckpt_text)
            .context("snapshot checkpoint body")?,
    })
}

fn record_to_json(r: &WalRecord) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert("v".into(), Json::Str(WAL_VERSION.into()));
    match r {
        WalRecord::Create { study, config_toml } => {
            m.insert("t".into(), Json::Str("create".into()));
            m.insert("study".into(), Json::Str(study.clone()));
            m.insert("config_toml".into(), Json::Str(config_toml.clone()));
        }
        WalRecord::Ask { study, eval_id, trials } => {
            m.insert("t".into(), Json::Str("ask".into()));
            m.insert("study".into(), Json::Str(study.clone()));
            m.insert("eval".into(), Json::Num(*eval_id as f64));
            m.insert(
                "trials".into(),
                Json::Arr(
                    trials.iter().map(|t| Json::Num(*t as f64)).collect(),
                ),
            );
        }
        WalRecord::Tell { study, eval_id, trial, outcome } => {
            m.insert("t".into(), Json::Str("tell".into()));
            m.insert("study".into(), Json::Str(study.clone()));
            m.insert("eval".into(), Json::Num(*eval_id as f64));
            m.insert("trial".into(), Json::Num(*trial as f64));
            m.insert("outcome".into(), outcome_to_json(outcome));
        }
        WalRecord::Requeue { study, eval_id } => {
            m.insert("t".into(), Json::Str("requeue".into()));
            m.insert("study".into(), Json::Str(study.clone()));
            m.insert("eval".into(), Json::Num(*eval_id as f64));
        }
        WalRecord::Poison { study, eval_id, penalty } => {
            m.insert("t".into(), Json::Str("poison".into()));
            m.insert("study".into(), Json::Str(study.clone()));
            m.insert("eval".into(), Json::Num(*eval_id as f64));
            m.insert("penalty".into(), Json::Num(*penalty));
        }
        WalRecord::WalSwitch { shard, generation, from } => {
            m.insert("t".into(), Json::Str("walswitch".into()));
            m.insert("shard".into(), Json::Num(*shard as f64));
            m.insert(
                "generation".into(),
                Json::Str(generation.to_string()),
            );
            m.insert("from".into(), Json::Str(from.clone()));
        }
        WalRecord::Stop { study } => {
            m.insert("t".into(), Json::Str("stop".into()));
            m.insert("study".into(), Json::Str(study.clone()));
        }
        WalRecord::Evict { study } => {
            m.insert("t".into(), Json::Str("evict".into()));
            m.insert("study".into(), Json::Str(study.clone()));
        }
        WalRecord::Import(snap) => {
            m.insert("t".into(), Json::Str("import".into()));
            m.insert("snapshot".into(), study_snapshot_to_json(snap));
        }
    }
    Json::Obj(m)
}

fn usize_field(v: &Json, what: &str) -> Result<usize> {
    let i = v.as_i64().with_context(|| format!("{what}: expected int"))?;
    usize::try_from(i).map_err(|_| anyhow!("{what}: negative"))
}

fn str_field(v: &Json, what: &str) -> Result<String> {
    Ok(v.as_str()
        .with_context(|| format!("{what}: expected string"))?
        .to_string())
}

fn record_from_json(root: &Json) -> Result<WalRecord> {
    let ver = root.get("v").as_str().context("record version")?;
    if ver != WAL_VERSION {
        bail!("WAL version mismatch: got {ver:?}, want {WAL_VERSION:?}");
    }
    let tag = root.get("t").as_str().context("record tag")?;
    let study = || str_field(root.get("study"), "record study");
    Ok(match tag {
        "create" => WalRecord::Create {
            study: study()?,
            config_toml: str_field(
                root.get("config_toml"),
                "record config_toml",
            )?,
        },
        "ask" => WalRecord::Ask {
            study: study()?,
            eval_id: usize_field(root.get("eval"), "record eval")?,
            trials: root
                .get("trials")
                .as_arr()
                .context("record trials")?
                .iter()
                .map(|t| usize_field(t, "record trial"))
                .collect::<Result<Vec<_>>>()?,
        },
        "tell" => WalRecord::Tell {
            study: study()?,
            eval_id: usize_field(root.get("eval"), "record eval")?,
            trial: usize_field(root.get("trial"), "record trial")?,
            outcome: outcome_from_json(root.get("outcome"))?,
        },
        "requeue" => WalRecord::Requeue {
            study: study()?,
            eval_id: usize_field(root.get("eval"), "record eval")?,
        },
        "poison" => WalRecord::Poison {
            study: study()?,
            eval_id: usize_field(root.get("eval"), "record eval")?,
            penalty: root
                .get("penalty")
                .as_f64()
                .context("record penalty")?,
        },
        "walswitch" => WalRecord::WalSwitch {
            shard: usize_field(root.get("shard"), "record shard")?,
            generation: str_field(
                root.get("generation"),
                "record generation",
            )?
            .parse::<u64>()
            .context("record generation")?,
            from: str_field(root.get("from"), "record from")?,
        },
        "stop" => WalRecord::Stop { study: study()? },
        "evict" => WalRecord::Evict { study: study()? },
        "import" => WalRecord::Import(study_snapshot_from_json(
            root.get("snapshot"),
        )?),
        other => bail!("unknown WAL record tag {other:?}"),
    })
}

/// Encode one record in the `<len> <json>\n` framing.
pub fn encode_record(r: &WalRecord) -> String {
    let body = write(&record_to_json(r));
    format!("{} {}\n", body.len(), body)
}

/// Parse `<len> ` starting at byte `at`; returns `(len, body_start)`.
fn parse_len(bytes: &[u8], mut at: usize) -> Option<(usize, usize)> {
    let mut len = 0usize;
    let mut digits = 0usize;
    loop {
        match bytes.get(at) {
            Some(b @ b'0'..=b'9') => {
                len = len
                    .checked_mul(10)?
                    .checked_add(usize::from(b - b'0'))?;
                digits += 1;
                at += 1;
            }
            Some(b' ') if digits > 0 => return Some((len, at + 1)),
            _ => return None,
        }
    }
}

/// Decode a record stream. The torn tail a crash mid-append leaves —
/// a final record whose bytes run out early or whose newline is
/// missing — is silently dropped (it was never acknowledged); any
/// malformation *before* the end of the stream is a hard error.
pub fn decode_stream(bytes: &[u8]) -> Result<Vec<WalRecord>> {
    let mut records = Vec::new();
    let mut at = 0usize;
    while at < bytes.len() {
        let Some((len, body_start)) = parse_len(bytes, at) else {
            // No complete `<len> ` prefix: only legal as a torn tail.
            if bytes.get(at..).map(|r| r.contains(&b'\n')).unwrap_or(false)
            {
                bail!("corrupt WAL framing at byte {at}");
            }
            break;
        };
        let body_end = body_start.saturating_add(len);
        let Some(body) = bytes.get(body_start..body_end) else {
            break; // body runs past EOF: torn tail
        };
        match bytes.get(body_end) {
            Some(b'\n') => {}
            None => break, // newline missing at EOF: torn tail
            Some(_) => bail!(
                "corrupt WAL record at byte {at}: missing newline"
            ),
        }
        let text = std::str::from_utf8(body)
            .map_err(|_| anyhow!("corrupt WAL record at byte {at}"))?;
        let root = parse(text).map_err(|e| {
            anyhow!("corrupt WAL record at byte {at}: {e}")
        })?;
        records.push(record_from_json(&root)?);
        at = body_end + 1;
    }
    Ok(records)
}

fn shard_snapshot_to_json(s: &ShardSnapshot) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert("v".into(), Json::Str(WAL_VERSION.into()));
    m.insert("generation".into(), Json::Str(s.generation.to_string()));
    m.insert(
        "studies".into(),
        Json::Arr(s.studies.iter().map(study_snapshot_to_json).collect()),
    );
    Json::Obj(m)
}

fn shard_snapshot_from_json(root: &Json) -> Result<ShardSnapshot> {
    let ver = root.get("v").as_str().context("snapshot version")?;
    if ver != WAL_VERSION {
        bail!("snapshot version mismatch: got {ver:?}");
    }
    let generation = root
        .get("generation")
        .as_str()
        .context("snapshot generation")?
        .parse::<u64>()
        .context("snapshot generation")?;
    Ok(ShardSnapshot {
        generation,
        studies: root
            .get("studies")
            .as_arr()
            .context("snapshot studies")?
            .iter()
            .map(study_snapshot_from_json)
            .collect::<Result<Vec<_>>>()?,
    })
}

// ---------------------------------------------------------------------
// On-disk layout
// ---------------------------------------------------------------------

/// One shard's log handle: the current generation's append target plus
/// the compaction machinery and (optionally) a failover directory the
/// log can switch to when the primary disk fails.
#[derive(Debug)]
pub struct Wal {
    dir: PathBuf,
    failover: Option<PathBuf>,
    shard: usize,
    generation: u64,
    switched: bool,
    io: Box<dyn WalIo>,
}

fn log_path(dir: &Path, shard: usize, generation: u64) -> PathBuf {
    dir.join(format!("wal-{shard}.{generation}.log"))
}

fn snap_path(dir: &Path, shard: usize, generation: u64) -> PathBuf {
    dir.join(format!("snap-{shard}.{generation}.json"))
}

/// Parse `<stem>-<shard>.<gen>.<ext>`; returns the generation when the
/// name belongs to this shard.
fn parse_gen(name: &str, stem: &str, shard: usize, ext: &str) -> Option<u64> {
    let rest = name.strip_prefix(&format!("{stem}-{shard}."))?;
    rest.strip_suffix(&format!(".{ext}"))?.parse().ok()
}

impl Wal {
    /// Open (or initialize) the shard's WAL under `dir`, resuming the
    /// highest generation present on disk. No failover directory, real
    /// filesystem io.
    pub fn open(dir: &Path, shard: usize) -> Result<Wal> {
        Wal::open_with(dir, None, shard, Box::new(FsWalIo))
    }

    /// Open with an optional failover directory and injectable storage.
    /// The resumed generation is the highest present in *either*
    /// directory, and the log counts as already switched when the
    /// failover directory holds files at that generation (a prior run
    /// failed over, or compacted after failing over).
    pub fn open_with(
        dir: &Path,
        failover: Option<&Path>,
        shard: usize,
        io: Box<dyn WalIo>,
    ) -> Result<Wal> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("mkdir {}", dir.display()))?;
        let mut generation = 0u64;
        for d in [Some(dir), failover].into_iter().flatten() {
            if !d.is_dir() {
                continue; // failover dir is created lazily on switch
            }
            for entry in std::fs::read_dir(d)
                .with_context(|| format!("scanning {}", d.display()))?
            {
                let name = entry?.file_name();
                let Some(name) = name.to_str() else { continue };
                for g in [
                    parse_gen(name, "wal", shard, "log"),
                    parse_gen(name, "snap", shard, "json"),
                ]
                .into_iter()
                .flatten()
                {
                    generation = generation.max(g);
                }
            }
        }
        let switched = failover.is_some_and(|f| {
            log_path(f, shard, generation).is_file()
                || snap_path(f, shard, generation).is_file()
        });
        Ok(Wal {
            dir: dir.to_path_buf(),
            failover: failover.map(Path::to_path_buf),
            shard,
            generation,
            switched,
            io,
        })
    }

    /// True when any WAL or snapshot file for `shard` exists in `dir`.
    pub fn exists(dir: &Path, shard: usize) -> bool {
        let Ok(entries) = std::fs::read_dir(dir) else { return false };
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if parse_gen(name, "wal", shard, "log").is_some()
                || parse_gen(name, "snap", shard, "json").is_some()
            {
                return true;
            }
        }
        false
    }

    /// The generation currently being appended to.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// True when appends have switched to the failover directory.
    pub fn is_switched(&self) -> bool {
        self.switched
    }

    /// The directory currently receiving appends.
    fn active_dir(&self) -> &Path {
        if self.switched {
            self.failover.as_deref().unwrap_or(&self.dir)
        } else {
            &self.dir
        }
    }

    /// The current generation's append-target log file.
    pub fn log_file(&self) -> PathBuf {
        log_path(self.active_dir(), self.shard, self.generation)
    }

    /// Durably append one record (fsync before return). Returns `true`
    /// when this call failed over to the secondary directory: the
    /// primary append failed, and a `WalSwitch` frame plus the record
    /// itself landed in the failover log instead. Without a failover
    /// directory (or when the failover itself fails) the error
    /// propagates — the caller applies its `wal_failure` policy.
    pub fn append(&mut self, record: &WalRecord) -> Result<bool> {
        let framed = encode_record(record);
        let target = self.log_file();
        let primary_err = match self.io.append(&target, framed.as_bytes())
        {
            Ok(()) => return Ok(false),
            Err(e) => e,
        };
        if self.switched {
            return Err(primary_err);
        }
        let Some(fdir) = self.failover.clone() else {
            return Err(primary_err);
        };
        std::fs::create_dir_all(&fdir)
            .with_context(|| format!("mkdir {}", fdir.display()))?;
        let switch = WalRecord::WalSwitch {
            shard: self.shard,
            generation: self.generation,
            from: self.dir.display().to_string(),
        };
        let flog = log_path(&fdir, self.shard, self.generation);
        self.io
            .append(&flog, encode_record(&switch).as_bytes())
            .with_context(|| {
                format!(
                    "failover append to {} after primary failure: \
                     {primary_err:#}",
                    flog.display()
                )
            })?;
        // The switch frame is durable: from here on this generation's
        // tail lives in the failover log, even if re-appending the
        // record below fails (recovery then sees an empty tail).
        self.switched = true;
        self.io.append(&flog, framed.as_bytes()).with_context(|| {
            format!("re-appending record to {}", flog.display())
        })?;
        Ok(true)
    }

    /// Load the current generation: its snapshot (if compaction ever
    /// ran) plus every record appended since, torn tail dropped. When a
    /// failover log exists for this generation the record stream is the
    /// primary log followed by the failover log's records (its leading
    /// `WalSwitch` frame verified and stripped).
    pub fn load(&self) -> Result<(Option<ShardSnapshot>, Vec<WalRecord>)> {
        let mut snapshot = None;
        for d in [Some(self.dir.as_path()), self.failover.as_deref()]
            .into_iter()
            .flatten()
        {
            let snap = snap_path(d, self.shard, self.generation);
            if snap.is_file() {
                let text = std::fs::read_to_string(&snap).with_context(
                    || format!("reading {}", snap.display()),
                )?;
                let root = parse(&text).map_err(|e| {
                    anyhow!("parsing {}: {e}", snap.display())
                })?;
                snapshot = Some(shard_snapshot_from_json(&root)?);
                break;
            }
        }
        let plog = log_path(&self.dir, self.shard, self.generation);
        let mut records = if plog.is_file() {
            let bytes = std::fs::read(&plog)
                .with_context(|| format!("reading {}", plog.display()))?;
            decode_stream(&bytes)
                .with_context(|| format!("replaying {}", plog.display()))?
        } else {
            Vec::new()
        };
        if let Some(fdir) = &self.failover {
            let flog = log_path(fdir, self.shard, self.generation);
            if flog.is_file() {
                let bytes = std::fs::read(&flog).with_context(|| {
                    format!("reading {}", flog.display())
                })?;
                let mut tail = decode_stream(&bytes).with_context(
                    || format!("replaying {}", flog.display()),
                )?;
                match tail.first() {
                    Some(WalRecord::WalSwitch {
                        shard,
                        generation,
                        ..
                    }) => {
                        if *shard != self.shard
                            || *generation != self.generation
                        {
                            bail!(
                                "{}: WalSwitch frame names shard {shard} \
                                 gen {generation}, expected shard {} gen \
                                 {}",
                                flog.display(),
                                self.shard,
                                self.generation
                            );
                        }
                        records.extend(tail.drain(..).skip(1));
                    }
                    _ if !plog.is_file() => {
                        // A generation born in the failover directory
                        // (compaction after a switch) has no frame.
                        records = tail;
                    }
                    _ => bail!(
                        "{}: failover log lacks a leading WalSwitch \
                         frame while the primary log exists",
                        flog.display()
                    ),
                }
            }
        }
        if records
            .iter()
            .any(|r| matches!(r, WalRecord::WalSwitch { .. }))
        {
            bail!("WalSwitch frame in the middle of a record stream");
        }
        Ok((snapshot, records))
    }

    /// Snapshot + truncate: durably write `studies` as generation G+1
    /// into the active directory, switch appends to the new generation,
    /// then retire generation G's files in both directories
    /// (best-effort — stale files are ignored by recovery, which
    /// always loads the highest generation).
    pub fn compact(&mut self, studies: Vec<StudySnapshot>) -> Result<()> {
        let next = self.generation + 1;
        let snap = ShardSnapshot { generation: next, studies };
        let body = write(&shard_snapshot_to_json(&snap));
        let target = snap_path(self.active_dir(), self.shard, next);
        self.io.atomic_write(&target, body.as_bytes())?;
        let old = self.generation;
        self.generation = next;
        for d in [Some(self.dir.clone()), self.failover.clone()]
            .into_iter()
            .flatten()
        {
            std::fs::remove_file(log_path(&d, self.shard, old)).ok();
            std::fs::remove_file(snap_path(&d, self.shard, old)).ok();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn outcome(loss: f64) -> TrialOutcome {
        TrialOutcome {
            loss,
            dropout_losses: vec![loss * 2.0],
            predictions: None,
            dropout_predictions: vec![],
            cost: Duration::from_millis(3),
        }
    }

    fn records() -> Vec<WalRecord> {
        vec![
            WalRecord::Create {
                study: "s1".into(),
                config_toml: "[hpo]\nseed = 1\n".into(),
            },
            WalRecord::Ask {
                study: "s1".into(),
                eval_id: 0,
                trials: vec![0, 1],
            },
            WalRecord::Tell {
                study: "s1".into(),
                eval_id: 0,
                trial: 0,
                outcome: outcome(0.5),
            },
            WalRecord::Requeue { study: "s1".into(), eval_id: 0 },
            WalRecord::Poison {
                study: "s1".into(),
                eval_id: 0,
                penalty: 1.0e9,
            },
            WalRecord::Stop { study: "s1".into() },
            WalRecord::Evict { study: "s1".into() },
        ]
    }

    #[test]
    fn stream_roundtrips() {
        let mut buf = String::new();
        for r in records() {
            buf.push_str(&encode_record(&r));
        }
        let back = decode_stream(buf.as_bytes()).unwrap();
        assert_eq!(back.len(), records().len());
        match (&back[2], &records()[2]) {
            (
                WalRecord::Tell { outcome: a, .. },
                WalRecord::Tell { outcome: b, .. },
            ) => assert_eq!(a.loss.to_bits(), b.loss.to_bits()),
            _ => panic!("record order changed"),
        }
    }

    #[test]
    fn torn_tail_is_dropped_not_fatal() {
        let mut buf = String::new();
        for r in records().into_iter().take(3) {
            buf.push_str(&encode_record(&r));
        }
        let full = decode_stream(buf.as_bytes()).unwrap().len();
        // Chop bytes off the end: every prefix decodes to ≤ full
        // records and never errors (the torn record simply vanishes).
        for cut in 1..60 {
            let bytes = &buf.as_bytes()[..buf.len() - cut];
            let got = decode_stream(bytes).unwrap();
            assert!(got.len() <= full);
        }
    }

    #[test]
    fn mid_stream_corruption_is_fatal() {
        let mut buf = String::new();
        for r in records().into_iter().take(2) {
            buf.push_str(&encode_record(&r));
        }
        let mut bytes = buf.into_bytes();
        // Flip a byte inside the FIRST record's JSON body.
        bytes[10] ^= 0x55;
        assert!(decode_stream(&bytes).is_err());
    }

    #[test]
    fn wal_open_append_load_compact() {
        let dir =
            std::env::temp_dir().join("hyppo_wal_test_open_append");
        std::fs::remove_dir_all(&dir).ok();
        let mut wal = Wal::open(&dir, 0).unwrap();
        assert_eq!(wal.generation(), 0);
        assert!(!Wal::exists(&dir, 0));
        for r in records().into_iter().take(2) {
            wal.append(&r).unwrap();
        }
        assert!(Wal::exists(&dir, 0));
        let (snap, recs) = wal.load().unwrap();
        assert!(snap.is_none());
        assert_eq!(recs.len(), 2);

        // Compaction bumps the generation and retires the old log.
        wal.compact(vec![]).unwrap();
        assert_eq!(wal.generation(), 1);
        let (snap, recs) = wal.load().unwrap();
        assert_eq!(snap.unwrap().generation, 1);
        assert!(recs.is_empty());

        // Reopen resumes the highest generation.
        let again = Wal::open(&dir, 0).unwrap();
        assert_eq!(again.generation(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wal_failure_policy_parses() {
        for p in
            [WalFailure::Wedge, WalFailure::Readonly, WalFailure::Failover]
        {
            assert_eq!(WalFailure::from_str(p.as_str()).unwrap(), p);
        }
        assert!(WalFailure::from_str("explode").is_err());
    }

    #[test]
    fn poison_and_walswitch_records_roundtrip() {
        let rs = vec![
            WalRecord::Poison {
                study: "s".into(),
                eval_id: 9,
                penalty: 0.123456789123456789,
            },
            WalRecord::WalSwitch {
                shard: 3,
                generation: u64::MAX - 7,
                from: "/tmp/primary".into(),
            },
        ];
        let mut buf = String::new();
        for r in &rs {
            buf.push_str(&encode_record(r));
        }
        let back = decode_stream(buf.as_bytes()).unwrap();
        match (&back[0], &rs[0]) {
            (
                WalRecord::Poison { eval_id: ea, penalty: pa, .. },
                WalRecord::Poison { eval_id: eb, penalty: pb, .. },
            ) => {
                assert_eq!(ea, eb);
                assert_eq!(pa.to_bits(), pb.to_bits());
            }
            _ => panic!("poison did not roundtrip"),
        }
        match &back[1] {
            WalRecord::WalSwitch { shard, generation, from } => {
                assert_eq!(*shard, 3);
                assert_eq!(*generation, u64::MAX - 7);
                assert_eq!(from, "/tmp/primary");
            }
            _ => panic!("walswitch did not roundtrip"),
        }
    }

    /// Io that fails every append under `primary`, delegating the rest
    /// to the real filesystem — the minimal dead-primary-disk model.
    #[derive(Debug)]
    struct PrimaryDies {
        primary: PathBuf,
        dead: bool,
    }

    impl WalIo for PrimaryDies {
        fn append(&mut self, path: &Path, bytes: &[u8]) -> Result<()> {
            if self.dead && path.starts_with(&self.primary) {
                bail!("injected: primary disk gone");
            }
            append_sync(path, bytes)
        }
        fn atomic_write(&mut self, path: &Path, bytes: &[u8]) -> Result<()> {
            atomic_write_sync(path, bytes)
        }
    }

    #[test]
    fn failover_chain_appends_switch_and_replay_identically() {
        let base = std::env::temp_dir().join("hyppo_wal_test_failover");
        std::fs::remove_dir_all(&base).ok();
        let primary = base.join("primary");
        let failover = base.join("failover");

        // Two healthy appends, then the primary disk dies.
        let io = PrimaryDies { primary: primary.clone(), dead: false };
        let mut wal = Wal::open_with(
            &primary,
            Some(&failover),
            0,
            Box::new(io),
        )
        .unwrap();
        let rs = records();
        assert!(!wal.append(&rs[0]).unwrap());
        assert!(!wal.append(&rs[1]).unwrap());

        let io = PrimaryDies { primary: primary.clone(), dead: true };
        let mut wal = Wal::open_with(
            &primary,
            Some(&failover),
            0,
            Box::new(io),
        )
        .unwrap();
        assert!(!wal.is_switched());
        // This append fails over: WalSwitch frame + the record itself.
        assert!(wal.append(&rs[2]).unwrap());
        assert!(wal.is_switched());
        // Subsequent appends go straight to the failover log.
        assert!(!wal.append(&rs[3]).unwrap());

        // Replay chases the chain and strips the WalSwitch frame.
        let (snap, got) = wal.load().unwrap();
        assert!(snap.is_none());
        assert_eq!(got.len(), 4);
        assert!(matches!(&got[3], WalRecord::Requeue { eval_id: 0, .. }));

        // A fresh open detects the switch and replays identically.
        let reopened = Wal::open_with(
            &primary,
            Some(&failover),
            0,
            Box::new(FsWalIo),
        )
        .unwrap();
        assert!(reopened.is_switched());
        let (_, again) = reopened.load().unwrap();
        assert_eq!(
            got.iter().map(encode_record).collect::<Vec<_>>(),
            again.iter().map(encode_record).collect::<Vec<_>>(),
        );

        // Compaction lands in the failover dir and retires generation
        // 0 from both directories.
        let mut wal = reopened;
        wal.compact(vec![]).unwrap();
        assert_eq!(wal.generation(), 1);
        assert!(!log_path(&primary, 0, 0).is_file());
        assert!(!log_path(&failover, 0, 0).is_file());
        let resumed = Wal::open_with(
            &primary,
            Some(&failover),
            0,
            Box::new(FsWalIo),
        )
        .unwrap();
        assert_eq!(resumed.generation(), 1);
        assert!(resumed.is_switched(), "post-switch gen stays failover");
        let (snap, tail) = resumed.load().unwrap();
        assert_eq!(snap.unwrap().generation, 1);
        assert!(tail.is_empty());
        std::fs::remove_dir_all(&base).ok();
    }
}
