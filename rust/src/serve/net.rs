//! TCP transport for the `hyppo-serve-v1` protocol (DESIGN.md §15–16).
//!
//! The server is an accept loop handing each connection its own
//! thread; every request line is routed through the shared
//! [`LineServer`], so per-shard FIFO ordering (and therefore
//! determinism and WAL consistency) is enforced by the pool, not the
//! socket layer. Malformed lines get a typed `protocol` error reply
//! and the connection stays up — a flaky worker can't poison the
//! service.
//!
//! # Retry + dedup (DESIGN.md §16)
//!
//! The failure mode a line protocol cannot hide is the *lost ack*: a
//! worker sends a tell, the connection dies, and the worker cannot know
//! whether the service applied it. [`RetryClient`] resends the same
//! request under a fresh connection with the same `req` sequence
//! number; the [`LineServer`] keeps a one-deep dedup window per
//! `(study, worker)` and answers a replayed sequence number from cache
//! without re-executing. A duplicated *ask* (no dedup hit, e.g. after
//! the window advanced) is still safe: the extra lease expires and its
//! trials re-enter the queue with identical `(θ, seed)`, so recorded
//! history stays byte-for-byte identical.
//!
//! [`TcpClient`] remains the bare one-connection [`Client`] for tests
//! and debugging; production workers wrap a [`Connector`] in
//! [`RetryClient`].

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::sampling::rng::Rng;
use crate::serve::pool::ShardPool;
use crate::serve::proto::{
    request_from_line_seq, request_to_line,
    request_to_line_seq, response_from_line, response_from_line_seq,
    response_to_line, response_to_line_seq, Client, ErrorCode, Request,
    Response,
};

/// Stale responses a [`RetryClient`] will read past while hunting for
/// its own sequence number (bounds the damage of a reordering peer).
const MAX_STALE_RESPONSES: usize = 32;

/// Requests that carry a worker identity are idempotently resendable;
/// the dedup window keys on `(study, worker)`.
fn dedup_key(req: &Request) -> Option<String> {
    match req {
        Request::Ask { study, worker }
        | Request::Tell { study, worker, .. }
        | Request::Heartbeat { study, worker, .. } => {
            // U+001F as separator: not a character any sane study or
            // worker id contains, so keys don't collide in practice.
            Some(format!("{study}\u{1f}{worker}"))
        }
        _ => None,
    }
}

/// Shared line-level service: parses, dedups, routes through the pool,
/// and serializes the reply. One instance serves every connection so
/// the dedup window survives worker reconnects.
pub struct LineServer {
    pool: Arc<ShardPool>,
    /// Latest `(seq, cached response line)` per `(study, worker)`.
    window: Mutex<BTreeMap<String, (u64, String)>>,
}

impl LineServer {
    /// A line server over `pool` with an empty dedup window.
    pub fn new(pool: Arc<ShardPool>) -> LineServer {
        LineServer { pool, window: Mutex::new(BTreeMap::new()) }
    }

    /// The underlying pool (status inspection, tests).
    pub fn pool(&self) -> &Arc<ShardPool> {
        &self.pool
    }

    fn lock_window(
        &self,
    ) -> std::sync::MutexGuard<'_, BTreeMap<String, (u64, String)>> {
        match self.window.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Process one request line into one response line (no trailing
    /// newline). A replayed `(study, worker, seq)` returns the cached
    /// response without re-executing — the typed no-op that makes
    /// resend-after-lost-ack safe.
    pub fn serve(&self, line: &str) -> String {
        let (seq, req) = match request_from_line_seq(line) {
            Ok(parsed) => parsed,
            Err(e) => {
                return response_to_line_seq(
                    &Response::error(
                        ErrorCode::Protocol,
                        format!("{e:#}"),
                    ),
                    None,
                )
            }
        };
        let key = match (&seq, dedup_key(&req)) {
            (Some(seq), Some(key)) => {
                let window = self.lock_window();
                if let Some((cached_seq, cached)) = window.get(&key) {
                    if cached_seq == seq {
                        return cached.clone();
                    }
                }
                Some(key)
            }
            _ => None,
        };
        let resp = self.pool.call(&req);
        let out = response_to_line_seq(&resp, seq);
        if let (Some(seq), Some(key)) = (seq, key) {
            self.lock_window().insert(key, (seq, out.clone()));
        }
        out
    }
}

/// Serve one established connection until the peer hangs up.
pub fn handle_conn(stream: TcpStream, server: &LineServer) -> Result<()> {
    let reader = BufReader::new(stream.try_clone().context("clone stream")?);
    let mut writer = stream;
    for line in reader.lines() {
        let line = line.context("reading request line")?;
        if line.trim().is_empty() {
            continue;
        }
        let mut out = server.serve(&line);
        out.push('\n');
        writer
            .write_all(out.as_bytes())
            .context("writing response line")?;
    }
    Ok(())
}

/// Accept loop: one thread per connection, all sharing one
/// [`LineServer`] (and therefore one dedup window). Runs until the
/// listener errors (normally: forever).
pub fn serve_listener(
    listener: TcpListener,
    pool: Arc<ShardPool>,
) -> Result<()> {
    let server = Arc::new(LineServer::new(pool));
    for conn in listener.incoming() {
        let stream = conn.context("accepting connection")?;
        let server = Arc::clone(&server);
        std::thread::spawn(move || {
            // Peer disconnects are routine; real errors surface when a
            // test or operator inspects the shard state instead.
            let _ = handle_conn(stream, &server);
        });
    }
    Ok(())
}

/// One request/response exchange surface, injectable for fault
/// simulation (`cluster::faults` scripts implementations that drop,
/// duplicate, and reorder).
pub trait Transport: Send {
    /// Send one request line (no trailing newline).
    fn send_line(&mut self, line: &str) -> Result<()>;
    /// Receive one response line (no trailing newline).
    fn recv_line(&mut self) -> Result<String>;
}

/// Builds fresh [`Transport`]s; called once per (re)connection.
pub trait Connector: Send {
    /// Establish a new transport.
    fn connect(&mut self) -> Result<Box<dyn Transport>>;
}

/// Plain TCP [`Transport`].
pub struct TcpTransport {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl TcpTransport {
    /// Wrap an established stream.
    pub fn new(stream: TcpStream) -> Result<TcpTransport> {
        let reader =
            BufReader::new(stream.try_clone().context("clone stream")?);
        Ok(TcpTransport { reader, writer: stream })
    }
}

impl Transport for TcpTransport {
    fn send_line(&mut self, line: &str) -> Result<()> {
        self.writer
            .write_all(line.as_bytes())
            .and_then(|()| self.writer.write_all(b"\n"))
            .context("sending request")
    }

    fn recv_line(&mut self) -> Result<String> {
        let mut buf = String::new();
        let n = self.reader.read_line(&mut buf).context("awaiting response")?;
        if n == 0 {
            bail!("server closed the connection");
        }
        while buf.ends_with('\n') || buf.ends_with('\r') {
            buf.pop();
        }
        Ok(buf)
    }
}

/// Reconnects to a fixed address.
pub struct TcpConnector {
    addr: String,
}

impl TcpConnector {
    /// A connector for `addr`, e.g. `127.0.0.1:7077`.
    pub fn new(addr: impl Into<String>) -> TcpConnector {
        TcpConnector { addr: addr.into() }
    }
}

impl Connector for TcpConnector {
    fn connect(&mut self) -> Result<Box<dyn Transport>> {
        let stream = TcpStream::connect(&self.addr)
            .with_context(|| format!("connecting to {}", self.addr))?;
        Ok(Box::new(TcpTransport::new(stream)?))
    }
}

/// Client-side retry knobs.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Attempts per logical request (first try included).
    pub max_attempts: u32,
    /// Backoff envelope base, milliseconds (attempt 2 waits in
    /// `[base/2, base]`).
    pub backoff_base_ms: u64,
    /// Backoff envelope cap, milliseconds.
    pub backoff_max_ms: u64,
    /// Jitter stream seed (decorrelates a fleet of workers retrying
    /// after the same outage).
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 8,
            backoff_base_ms: 50,
            backoff_max_ms: 2_000,
            jitter_seed: 0xbac0_ff,
        }
    }
}

/// A [`Client`] that survives connection loss: each logical request is
/// stamped with a sequence number and resent over a fresh connection
/// under capped jittered backoff until a response with the matching
/// number (or no number — a pre-seq peer) arrives. Combined with the
/// server's dedup window this makes every request idempotently
/// resendable.
pub struct RetryClient {
    connector: Box<dyn Connector>,
    policy: RetryPolicy,
    rng: Rng,
    transport: Option<Box<dyn Transport>>,
    seq: u64,
}

impl RetryClient {
    /// A retrying client over `connector`.
    pub fn new(
        connector: Box<dyn Connector>,
        policy: RetryPolicy,
    ) -> RetryClient {
        let rng = Rng::new(policy.jitter_seed);
        RetryClient { connector, policy, rng, transport: None, seq: 0 }
    }

    /// Convenience: retrying client for a TCP address.
    pub fn tcp(addr: impl Into<String>, policy: RetryPolicy) -> RetryClient {
        RetryClient::new(Box::new(TcpConnector::new(addr)), policy)
    }

    /// Sequence number of the most recent logical request.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Full-jitter delay before retry `attempt` (2-based: the first
    /// retry is attempt 2).
    fn backoff_ms(&mut self, attempt: u32) -> u64 {
        let exp = self
            .policy
            .backoff_base_ms
            .saturating_mul(
                1u64.checked_shl(attempt.saturating_sub(2))
                    .unwrap_or(u64::MAX),
            )
            .min(self.policy.backoff_max_ms);
        let span = exp - exp / 2;
        exp / 2
            + if span > 0 { self.rng.next_u64() % (span + 1) } else { 0 }
    }

    /// One wire exchange: connect if needed, send, then read until the
    /// response matching `self.seq` appears (skipping stale lines a
    /// reordering peer may deliver first).
    fn attempt(&mut self, line: &str) -> Result<Response> {
        if self.transport.is_none() {
            self.transport = Some(self.connector.connect()?);
        }
        let Some(t) = self.transport.as_mut() else {
            bail!("transport vanished after connect");
        };
        t.send_line(line)?;
        for _ in 0..MAX_STALE_RESPONSES {
            let resp_line = t.recv_line()?;
            let (seq, resp) = response_from_line_seq(&resp_line)?;
            match seq {
                Some(s) if s == self.seq => return Ok(resp),
                // Stale response from a resent predecessor: skip.
                Some(_) => continue,
                // Peer doesn't echo sequence numbers: trust ordering.
                None => return Ok(resp),
            }
        }
        bail!(
            "no response matched request seq {} within {} lines",
            self.seq,
            MAX_STALE_RESPONSES
        );
    }
}

impl Client for RetryClient {
    fn call(&mut self, req: &Request) -> Result<Response> {
        self.seq = self.seq.wrapping_add(1);
        let line = request_to_line_seq(req, self.seq);
        let mut last_err = None;
        for attempt in 1..=self.policy.max_attempts.max(1) {
            if attempt > 1 {
                let ms = self.backoff_ms(attempt);
                std::thread::sleep(Duration::from_millis(ms));
            }
            match self.attempt(&line) {
                Ok(resp) => return Ok(resp),
                Err(e) => {
                    // Connection state is unknown; rebuild it next
                    // attempt and resend under the same seq (the
                    // server's dedup window absorbs the duplicate).
                    self.transport = None;
                    last_err = Some(e);
                }
            }
        }
        match last_err {
            Some(e) => Err(e.context(format!(
                "request failed after {} attempts",
                self.policy.max_attempts.max(1)
            ))),
            None => bail!("request failed with no attempts made"),
        }
    }
}

/// Blocking single-connection line-protocol client (tests, debugging).
pub struct TcpClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl TcpClient {
    /// Connect to a `hyppo serve` endpoint, e.g. `127.0.0.1:7077`.
    pub fn connect(addr: &str) -> Result<TcpClient> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to {addr}"))?;
        let reader =
            BufReader::new(stream.try_clone().context("clone stream")?);
        Ok(TcpClient { reader, writer: stream })
    }
}

impl Client for TcpClient {
    fn call(&mut self, req: &Request) -> Result<Response> {
        let mut line = request_to_line(req);
        line.push('\n');
        self.writer
            .write_all(line.as_bytes())
            .context("sending request")?;
        let mut buf = String::new();
        let n = self
            .reader
            .read_line(&mut buf)
            .context("awaiting response")?;
        if n == 0 {
            bail!("server closed the connection");
        }
        response_from_line(&buf)
    }
}
