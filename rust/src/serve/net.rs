//! TCP transport for the `hyppo-serve-v1` protocol (DESIGN.md §15).
//!
//! The server is an accept loop handing each connection its own
//! thread; every request line is routed through the shared
//! [`ShardPool`], so per-shard FIFO ordering (and therefore
//! determinism and WAL consistency) is enforced by the pool, not the
//! socket layer. Malformed lines get a typed `protocol` error reply
//! and the connection stays up — a flaky worker can't poison the
//! service.
//!
//! [`TcpClient`] is the matching [`Client`] implementation: one
//! request line out, one response line back, blocking.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::serve::pool::ShardPool;
use crate::serve::proto::{
    request_from_line, request_to_line, response_from_line,
    response_to_line, Client, ErrorCode, Request, Response,
};

/// Serve one established connection until the peer hangs up.
pub fn handle_conn(stream: TcpStream, pool: &ShardPool) -> Result<()> {
    let reader = BufReader::new(stream.try_clone().context("clone stream")?);
    let mut writer = stream;
    for line in reader.lines() {
        let line = line.context("reading request line")?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = match request_from_line(&line) {
            Ok(req) => pool.call(&req),
            Err(e) => {
                Response::error(ErrorCode::Protocol, format!("{e:#}"))
            }
        };
        let mut out = response_to_line(&resp);
        out.push('\n');
        writer
            .write_all(out.as_bytes())
            .context("writing response line")?;
    }
    Ok(())
}

/// Accept loop: one thread per connection, all sharing `pool`. Runs
/// until the listener errors (normally: forever).
pub fn serve_listener(
    listener: TcpListener,
    pool: Arc<ShardPool>,
) -> Result<()> {
    for conn in listener.incoming() {
        let stream = conn.context("accepting connection")?;
        let pool = Arc::clone(&pool);
        std::thread::spawn(move || {
            // Peer disconnects are routine; real errors surface when a
            // test or operator inspects the shard state instead.
            let _ = handle_conn(stream, &pool);
        });
    }
    Ok(())
}

/// Blocking line-protocol client over TCP.
pub struct TcpClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl TcpClient {
    /// Connect to a `hyppo serve` endpoint, e.g. `127.0.0.1:7077`.
    pub fn connect(addr: &str) -> Result<TcpClient> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to {addr}"))?;
        let reader =
            BufReader::new(stream.try_clone().context("clone stream")?);
        Ok(TcpClient { reader, writer: stream })
    }
}

impl Client for TcpClient {
    fn call(&mut self, req: &Request) -> Result<Response> {
        let mut line = request_to_line(req);
        line.push('\n');
        self.writer
            .write_all(line.as_bytes())
            .context("sending request")?;
        let mut buf = String::new();
        let n = self
            .reader
            .read_line(&mut buf)
            .context("awaiting response")?;
        if n == 0 {
            bail!("server closed the connection");
        }
        response_from_line(&buf)
    }
}
