//! Shard core: the single-owner state machine behind the service
//! (DESIGN.md §15).
//!
//! A [`ShardCore`] owns a disjoint set of studies — each a full
//! `exec::Session` plus its lease table — and processes one command at
//! a time. All concurrency lives *outside* this type: the threaded
//! shell (`serve::pool`) gives each core its own thread and a FIFO
//! command queue, so a core never needs interior locking and its
//! behaviour is a pure function of the command arrival order. That is
//! the service's determinism contract: same commands, same order, same
//! clock readings → bit-identical sessions.
//!
//! Durability follows write-ahead discipline: a command is (1) checked
//! against the session (rejections log nothing), (2) applied, (3)
//! appended to the WAL, and only then (4) acknowledged. If the append
//! fails the core **wedges** — it refuses every further command with
//! [`ErrorCode::Internal`] — because its in-memory state is now ahead
//! of the log; the unacknowledged command is simply absent from the
//! replay, which is exactly the crash the WAL already handles.
//!
//! Leases make worker death survivable: `ask` grants an
//! evaluation-granular lease of `lease_ms` clock-milliseconds, renewed
//! by `heartbeat`; on every command (and on idle `tick`s) expired
//! leases are requeued — the evaluation re-emerges from a later `ask`
//! with the same id, θ, and seed, which `exec::Session` guarantees
//! keeps the decision sequence bit-identical. Time is read only
//! through the injected [`Clock`], never from the OS.
//!
//! The server side never runs trials, so the session's evaluator is a
//! [`SyntheticEvaluator`] built deterministically from the study's
//! config — only its *pure* surface (space, `n_params`,
//! `loss_of_mean_prediction`) is exercised, by proposal scoring and
//! aggregation. Workers run the actual trials client-side.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::config;
use crate::eval::synthetic::SyntheticEvaluator;
use crate::eval::Evaluator;
use crate::exec::{Session, TellCheck};
use crate::optimizer::{HpoConfig, RefitStats};
use crate::serve::clock::Clock;
use crate::serve::proto::{
    ErrorCode, Request, Response, WireBest, WireJob,
};
use crate::serve::wal::{StudySnapshot, Wal, WalRecord};

/// An evaluation-granular work grant: `worker` may deliver trials of
/// the evaluation until `expires_ms` on the shard's clock.
#[derive(Debug, Clone)]
pub struct Lease {
    /// Worker id that asked for the evaluation.
    pub worker: String,
    /// Clock reading after which the lease is expired.
    pub expires_ms: u64,
}

/// One study owned by a shard.
struct Study {
    config_toml: String,
    gamma: f64,
    session: Session<'static>,
    /// Live leases by evaluation id.
    leases: BTreeMap<usize, Lease>,
    stopped: bool,
}

/// Operational counters (not part of the replayed state).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardCounters {
    /// Evaluations handed out.
    pub asks: u64,
    /// Trial outcomes absorbed.
    pub tells: u64,
    /// Lease-expiry and recovery requeues.
    pub requeues: u64,
    /// WAL records durably appended.
    pub wal_appends: u64,
    /// Snapshot+truncate compactions performed.
    pub compactions: u64,
}

/// Build a study's session (and γ) from its config document. The
/// evaluator is synthetic and derived from the config alone, so every
/// replica of the study — server, replayed server, worker — agrees on
/// the search space bit-for-bit.
fn build_parts(
    config_toml: &str,
) -> Result<(Box<dyn Evaluator>, HpoConfig, f64)> {
    let doc = config::parse(config_toml).context("parsing study config")?;
    let cfg = config::build(&doc).context("building study config")?;
    let ev: Box<dyn Evaluator> = Box::new(SyntheticEvaluator::new(
        cfg.space.clone(),
        cfg.hpo.seed,
    ));
    let gamma = cfg.hpo.gamma;
    Ok((ev, cfg.hpo, gamma))
}

fn fresh_study(config_toml: &str) -> Result<Study> {
    let (ev, hpo, gamma) = build_parts(config_toml)?;
    Ok(Study {
        config_toml: config_toml.to_string(),
        gamma,
        session: Session::new_boxed(ev, &hpo),
        leases: BTreeMap::new(),
        stopped: false,
    })
}

fn restored_study(snap: &StudySnapshot) -> Result<Study> {
    let (ev, hpo, gamma) = build_parts(&snap.config_toml)?;
    let session = Session::restore_boxed(ev, &hpo, &snap.checkpoint)
        .with_context(|| {
            format!("restoring study {:?}", snap.study)
        })?;
    Ok(Study {
        config_toml: snap.config_toml.clone(),
        gamma,
        session,
        leases: BTreeMap::new(),
        stopped: snap.stopped,
    })
}

/// A shard: a disjoint set of studies, their leases, and (optionally)
/// their write-ahead log. Single-owner — see the module docs.
pub struct ShardCore {
    id: usize,
    clock: Arc<dyn Clock>,
    lease_ms: u64,
    /// Compact after this many WAL appends; 0 disables.
    compact_every: usize,
    appends_since_compact: usize,
    wal: Option<Wal>,
    wedged: bool,
    studies: BTreeMap<String, Study>,
    counters: ShardCounters,
}

impl ShardCore {
    /// A fresh, empty shard. `wal` of `None` runs without durability
    /// (pure in-memory service).
    pub fn new(
        id: usize,
        clock: Arc<dyn Clock>,
        lease_ms: u64,
        compact_every: usize,
        wal: Option<Wal>,
    ) -> ShardCore {
        ShardCore {
            id,
            clock,
            lease_ms,
            compact_every,
            appends_since_compact: 0,
            wal,
            wedged: false,
            studies: BTreeMap::new(),
            counters: ShardCounters::default(),
        }
    }

    /// Rebuild a shard from its WAL directory: load the newest
    /// snapshot, replay every record appended since (verifying ask
    /// divergence), then requeue every evaluation that was in a
    /// worker's hands at the crash — their leases died with the
    /// process, so they must re-emerge from future asks.
    pub fn recover(
        id: usize,
        clock: Arc<dyn Clock>,
        lease_ms: u64,
        compact_every: usize,
        dir: &std::path::Path,
    ) -> Result<ShardCore> {
        let wal = Wal::open(dir, id)?;
        let (snapshot, records) = wal.load()?;
        let mut core =
            ShardCore::new(id, clock, lease_ms, compact_every, None);
        if let Some(snap) = snapshot {
            for s in &snap.studies {
                core.studies
                    .insert(s.study.clone(), restored_study(s)?);
            }
        }
        for rec in records {
            core.replay(rec)?;
        }
        // Orphaned in-flight work: logged Ask, no live worker. (Studies
        // restored from a snapshot re-hand their in-flight evaluations
        // automatically — checkpoints don't capture hand-out state — so
        // only post-snapshot asks appear here.)
        core.wal = Some(wal);
        let orphans: Vec<(String, usize)> = core
            .studies
            .iter()
            .flat_map(|(name, st)| {
                st.session
                    .outstanding_ids()
                    .into_iter()
                    .map(move |id| (name.clone(), id))
            })
            .collect();
        for (study, eval_id) in orphans {
            core.append(&WalRecord::Requeue {
                study: study.clone(),
                eval_id,
            })?;
            if let Some(st) = core.studies.get_mut(&study) {
                st.session.requeue(eval_id).with_context(|| {
                    format!("requeueing orphan {eval_id} of {study:?}")
                })?;
                core.counters.requeues += 1;
            }
        }
        Ok(core)
    }

    /// Apply one replayed WAL record. Rebuilds must match what the
    /// live shard did — a session that answers differently than the
    /// log claims is corruption and fails loudly.
    fn replay(&mut self, rec: WalRecord) -> Result<()> {
        match rec {
            WalRecord::Create { study, config_toml } => {
                if self.studies.contains_key(&study) {
                    bail!("replay: duplicate create for {study:?}");
                }
                self.studies
                    .insert(study, fresh_study(&config_toml)?);
            }
            WalRecord::Ask { study, eval_id, trials } => {
                let st = self.study_mut(&study)?;
                let job = st.session.ask_eval().ok_or_else(|| {
                    anyhow!(
                        "replay diverged: log asks {eval_id} of \
                         {study:?} but the session has nothing to hand \
                         out"
                    )
                })?;
                if job.id != eval_id || job.trials != trials {
                    bail!(
                        "replay diverged on {study:?}: log handed out \
                         evaluation {eval_id} trials {trials:?}, \
                         session hands out {} trials {:?}",
                        job.id,
                        job.trials
                    );
                }
            }
            WalRecord::Tell { study, eval_id, trial, outcome } => {
                self.study_mut(&study)?
                    .session
                    .tell(eval_id, trial, outcome)
                    .with_context(|| format!("replay tell on {study:?}"))?;
            }
            WalRecord::Requeue { study, eval_id } => {
                self.study_mut(&study)?
                    .session
                    .requeue(eval_id)
                    .with_context(|| {
                        format!("replay requeue on {study:?}")
                    })?;
            }
            WalRecord::Stop { study } => {
                self.study_mut(&study)?.stopped = true;
            }
            WalRecord::Evict { study } => {
                self.studies.remove(&study);
            }
            WalRecord::Import(snap) => {
                let study = snap.study.clone();
                self.studies.insert(study, restored_study(&snap)?);
            }
        }
        Ok(())
    }

    fn study_mut(&mut self, name: &str) -> Result<&mut Study> {
        self.studies
            .get_mut(name)
            .ok_or_else(|| anyhow!("unknown study {name:?}"))
    }

    /// Durably append one record; wedge on failure. Returns the error
    /// response to emit instead of an acknowledgement.
    fn append(&mut self, rec: &WalRecord) -> Result<()> {
        if let Some(w) = &self.wal {
            w.append(rec)?;
            self.counters.wal_appends += 1;
            self.appends_since_compact += 1;
        }
        Ok(())
    }

    fn log_or_wedge(&mut self, rec: WalRecord) -> Option<Response> {
        match self.append(&rec) {
            Ok(()) => None,
            Err(e) => {
                self.wedged = true;
                Some(Response::error(
                    ErrorCode::Internal,
                    format!(
                        "shard {}: write-ahead log append failed: {e:#}",
                        self.id
                    ),
                ))
            }
        }
    }

    /// Snapshot every study into the next WAL generation and retire
    /// the old one. Note refit counters reset across this boundary
    /// (snapshot restore refits from scratch); histories and the RNG
    /// stream are bit-identical.
    pub fn compact(&mut self) -> Result<()> {
        let Some(wal) = &mut self.wal else { return Ok(()) };
        let studies = self
            .studies
            .iter()
            .map(|(name, st)| StudySnapshot {
                study: name.clone(),
                config_toml: st.config_toml.clone(),
                stopped: st.stopped,
                checkpoint: st.session.snapshot(),
            })
            .collect();
        wal.compact(studies)?;
        self.appends_since_compact = 0;
        self.counters.compactions += 1;
        Ok(())
    }

    fn maybe_compact(&mut self) {
        if self.compact_every > 0
            && self.appends_since_compact >= self.compact_every
            && self.compact().is_err()
        {
            // A failed compaction leaves the previous generation
            // intact and authoritative; wedging is not needed, but we
            // stop trying until the next threshold crossing.
            self.appends_since_compact = 0;
        }
    }

    /// Requeue every expired lease (WAL-logged, so replay reproduces
    /// the timeout decision). Called on every command and on idle
    /// ticks.
    fn expire_leases(&mut self) {
        let now = self.clock.now_ms();
        let expired: Vec<(String, usize)> = self
            .studies
            .iter()
            .flat_map(|(name, st)| {
                st.leases
                    .iter()
                    .filter(|(_, l)| l.expires_ms <= now)
                    .map(move |(id, _)| (name.clone(), *id))
            })
            .collect();
        for (study, eval_id) in expired {
            // Apply, then log: the record is only written for requeues
            // that actually happened, so replay can never diverge. A
            // failed append wedges the shard (state ahead of the log).
            let requeued = match self.studies.get_mut(&study) {
                Some(st) => {
                    st.leases.remove(&eval_id);
                    st.session.requeue(eval_id).is_ok()
                }
                None => false,
            };
            if !requeued {
                continue;
            }
            self.counters.requeues += 1;
            if self
                .log_or_wedge(WalRecord::Requeue {
                    study: study.clone(),
                    eval_id,
                })
                .is_some()
            {
                return; // wedged; stop mutating
            }
        }
    }

    /// Idle maintenance: lease expiry (and any due compaction).
    pub fn tick(&mut self) {
        if self.wedged {
            return;
        }
        self.expire_leases();
        self.maybe_compact();
    }

    /// Process one command. Never blocks, never panics; all failures
    /// are typed [`Response::Error`]s.
    pub fn handle(&mut self, req: &Request) -> Response {
        if self.wedged {
            return Response::error(
                ErrorCode::Internal,
                format!(
                    "shard {} is wedged after a WAL write failure; \
                     restart and recover from the log",
                    self.id
                ),
            );
        }
        self.expire_leases();
        if self.wedged {
            return Response::error(
                ErrorCode::Internal,
                format!("shard {} wedged during lease expiry", self.id),
            );
        }
        let resp = self.dispatch(req);
        self.maybe_compact();
        resp
    }

    fn dispatch(&mut self, req: &Request) -> Response {
        match req {
            Request::CreateStudy { study, config_toml } => {
                self.handle_create(study, config_toml)
            }
            Request::Ask { study, worker } => self.handle_ask(study, worker),
            Request::Tell { study, worker, eval_id, trial, outcome } => {
                self.handle_tell(study, worker, *eval_id, *trial, outcome)
            }
            Request::Heartbeat { study, worker } => {
                self.handle_heartbeat(study, worker)
            }
            Request::StudyStatus { study } => self.handle_status(study),
            Request::StopStudy { study } => self.handle_stop(study),
            Request::ListStudies => Response::Studies {
                studies: self.studies.keys().cloned().collect(),
            },
        }
    }

    fn unknown(study: &str) -> Response {
        Response::error(
            ErrorCode::UnknownStudy,
            format!("no study {study:?} on this shard"),
        )
    }

    fn handle_create(&mut self, study: &str, config_toml: &str) -> Response {
        if self.studies.contains_key(study) {
            return Response::error(
                ErrorCode::DuplicateStudy,
                format!("study {study:?} already exists"),
            );
        }
        let st = match fresh_study(config_toml) {
            Ok(st) => st,
            Err(e) => {
                return Response::error(
                    ErrorCode::BadConfig,
                    format!("study {study:?}: {e:#}"),
                )
            }
        };
        if let Some(resp) = self.log_or_wedge(WalRecord::Create {
            study: study.to_string(),
            config_toml: config_toml.to_string(),
        }) {
            return resp;
        }
        self.studies.insert(study.to_string(), st);
        Response::Created { study: study.to_string() }
    }

    fn handle_ask(&mut self, study: &str, worker: &str) -> Response {
        let lease_ms = self.lease_ms;
        let now = self.clock.now_ms();
        let Some(st) = self.studies.get_mut(study) else {
            return Self::unknown(study);
        };
        if st.stopped || st.session.is_complete() {
            return Response::Asked {
                study: study.to_string(),
                job: None,
                done: true,
            };
        }
        let Some(job) = st.session.ask_eval() else {
            return Response::Asked {
                study: study.to_string(),
                job: None,
                done: false, // work in flight; ask again after tells
            };
        };
        st.leases.insert(
            job.id,
            Lease {
                worker: worker.to_string(),
                expires_ms: now.saturating_add(lease_ms),
            },
        );
        if let Some(resp) = self.log_or_wedge(WalRecord::Ask {
            study: study.to_string(),
            eval_id: job.id,
            trials: job.trials.clone(),
        }) {
            return resp;
        }
        self.counters.asks += 1;
        Response::Asked {
            study: study.to_string(),
            job: Some(WireJob {
                eval_id: job.id,
                theta: job.theta,
                seed: job.seed,
                trials: job.trials,
                lease_ms,
            }),
            done: false,
        }
    }

    fn handle_tell(
        &mut self,
        study: &str,
        _worker: &str,
        eval_id: usize,
        trial: usize,
        outcome: &crate::eval::TrialOutcome,
    ) -> Response {
        let Some(st) = self.studies.get_mut(study) else {
            return Self::unknown(study);
        };
        // Typed pre-flight: rejections must not mutate the session or
        // the log, so redelivered tells are idempotent no-ops.
        match st.session.check_tell(eval_id, trial) {
            TellCheck::Accept => {}
            TellCheck::UnknownEval => {
                return Response::error(
                    ErrorCode::UnknownEval,
                    format!(
                        "study {study:?} has no evaluation {eval_id}"
                    ),
                )
            }
            TellCheck::BadTrial => {
                return Response::error(
                    ErrorCode::BadTrial,
                    format!(
                        "trial {trial} outside evaluation {eval_id}'s \
                         planned set"
                    ),
                )
            }
            TellCheck::Duplicate => {
                return Response::error(
                    ErrorCode::DuplicateTell,
                    format!(
                        "outcome for evaluation {eval_id} trial {trial} \
                         already delivered"
                    ),
                )
            }
        }
        if let Some(resp) = self.log_or_wedge(WalRecord::Tell {
            study: study.to_string(),
            eval_id,
            trial,
            outcome: outcome.clone(),
        }) {
            return resp;
        }
        let Some(st) = self.studies.get_mut(study) else {
            return Self::unknown(study);
        };
        let told = match st.session.tell(eval_id, trial, outcome.clone()) {
            Ok(t) => t,
            Err(e) => {
                // check_tell said Accept, so this is an invariant break.
                self.wedged = true;
                return Response::error(
                    ErrorCode::Internal,
                    format!("tell accepted then failed: {e:#}"),
                );
            }
        };
        // Leases are per evaluation: release those whose evaluation is
        // no longer in a worker's hands (recorded, buffered, or
        // requeued).
        let live: BTreeSet<usize> =
            st.session.outstanding_ids().into_iter().collect();
        st.leases.retain(|id, _| live.contains(id));
        self.counters.tells += 1;
        Response::Told { recorded: told.recorded, extended: told.extended }
    }

    fn handle_heartbeat(&mut self, study: &str, worker: &str) -> Response {
        let now = self.clock.now_ms();
        let lease_ms = self.lease_ms;
        let Some(st) = self.studies.get_mut(study) else {
            return Self::unknown(study);
        };
        let mut renewed = 0usize;
        for lease in st.leases.values_mut() {
            if lease.worker == worker {
                lease.expires_ms = now.saturating_add(lease_ms);
                renewed += 1;
            }
        }
        Response::Beat { renewed }
    }

    fn handle_status(&self, study: &str) -> Response {
        let Some(st) = self.studies.get(study) else {
            return Self::unknown(study);
        };
        let best = st.session.history().best(st.gamma).map(|r| WireBest {
            eval_id: r.id,
            objective: r.objective(st.gamma),
        });
        Response::Status {
            study: study.to_string(),
            recorded: st.session.history().len(),
            in_flight: st.session.in_flight(),
            complete: st.session.is_complete(),
            stopped: st.stopped,
            best,
            config_toml: st.config_toml.clone(),
        }
    }

    fn handle_stop(&mut self, study: &str) -> Response {
        let Some(st) = self.studies.get(study) else {
            return Self::unknown(study);
        };
        if !st.stopped {
            if let Some(resp) = self
                .log_or_wedge(WalRecord::Stop { study: study.to_string() })
            {
                return resp;
            }
            if let Some(st) = self.studies.get_mut(study) {
                st.stopped = true;
            }
        }
        Response::Stopped { study: study.to_string() }
    }

    // -- migration ----------------------------------------------------

    /// Hand a study off: log the eviction, remove the study, and return
    /// its durable snapshot for the receiving shard's
    /// [`ShardCore::import_study`].
    pub fn export_study(&mut self, study: &str) -> Result<StudySnapshot> {
        let st = self
            .studies
            .get(study)
            .ok_or_else(|| anyhow!("unknown study {study:?}"))?;
        let snap = StudySnapshot {
            study: study.to_string(),
            config_toml: st.config_toml.clone(),
            stopped: st.stopped,
            checkpoint: st.session.snapshot(),
        };
        self.append(&WalRecord::Evict { study: study.to_string() })?;
        self.studies.remove(study);
        Ok(snap)
    }

    /// Accept a migrated study. Its in-flight evaluations re-emerge
    /// from future asks (hand-out state is not part of a checkpoint),
    /// so no requeue is needed; old leases die with the old shard.
    pub fn import_study(&mut self, snap: StudySnapshot) -> Result<()> {
        if self.studies.contains_key(&snap.study) {
            bail!("study {:?} already on shard {}", snap.study, self.id);
        }
        let st = restored_study(&snap)?;
        self.append(&WalRecord::Import(snap.clone()))?;
        self.studies.insert(snap.study, st);
        Ok(())
    }

    // -- inspection ---------------------------------------------------

    /// Shard index.
    pub fn id(&self) -> usize {
        self.id
    }

    /// True once a WAL append failed and the shard refuses commands.
    pub fn is_wedged(&self) -> bool {
        self.wedged
    }

    /// Operational counters.
    pub fn counters(&self) -> ShardCounters {
        self.counters
    }

    /// Sorted study ids owned by this shard.
    pub fn study_names(&self) -> Vec<String> {
        self.studies.keys().cloned().collect()
    }

    /// A study's recorded history (None if unknown).
    pub fn history(
        &self,
        study: &str,
    ) -> Option<&crate::optimizer::History> {
        self.studies.get(study).map(|st| st.session.history())
    }

    /// A study's surrogate refit counters (None if unknown).
    pub fn stats(&self, study: &str) -> Option<RefitStats> {
        self.studies.get(study).map(|st| st.session.stats())
    }

    /// Live leases of a study, by evaluation id.
    pub fn leases(&self, study: &str) -> Vec<(usize, Lease)> {
        self.studies
            .get(study)
            .map(|st| {
                st.leases
                    .iter()
                    .map(|(id, l)| (*id, l.clone()))
                    .collect()
            })
            .unwrap_or_default()
    }
}
