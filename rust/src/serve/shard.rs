//! Shard core: the single-owner state machine behind the service
//! (DESIGN.md §15).
//!
//! A [`ShardCore`] owns a disjoint set of studies — each a full
//! `exec::Session` plus its lease table — and processes one command at
//! a time. All concurrency lives *outside* this type: the threaded
//! shell (`serve::pool`) gives each core its own thread and a FIFO
//! command queue, so a core never needs interior locking and its
//! behaviour is a pure function of the command arrival order. That is
//! the service's determinism contract: same commands, same order, same
//! clock readings → bit-identical sessions.
//!
//! Durability follows write-ahead discipline: a command is (1) checked
//! against the session (rejections log nothing), (2) applied, (3)
//! appended to the WAL, and only then (4) acknowledged. What happens
//! when the append fails is the `wal_failure` policy
//! ([`crate::serve::wal::WalFailure`]): **wedge** (refuse every
//! further command with [`ErrorCode::Internal`] — state is ahead of
//! the log, and the unacknowledged command is simply absent from the
//! replay, which is exactly the crash the WAL already handles),
//! **readonly** (enter [`ShardHealth::Degraded`]: mutations are
//! rejected with [`ErrorCode::ShardDegraded`] but status queries keep
//! working), or **failover** (the WAL switches to a secondary
//! directory — see the failover-chain docs in `serve::wal` — and the
//! shard keeps serving; only a failed failover wedges).
//!
//! Leases make worker death survivable: `ask` grants an
//! evaluation-granular lease of `lease_ms` clock-milliseconds, renewed
//! by `heartbeat`; on every command (and on idle `tick`s) expired
//! leases are requeued — the evaluation re-emerges from a later `ask`
//! with the same id, θ, and seed, which `exec::Session` guarantees
//! keeps the decision sequence bit-identical. Time is read only
//! through the injected [`Clock`], never from the OS. Ties are pinned:
//! a lease with `expires_ms <= now` is expired *before* the incoming
//! command is dispatched, so a heartbeat landing exactly at the expiry
//! tick finds its lease already gone (and gets the typed
//! [`ErrorCode::UnknownLease`] when it named the evaluation).
//!
//! A trial that kills every worker it lands on would requeue forever
//! under that scheme, wedging the study's tail. Quarantine bounds it:
//! each lease expiry is a *strike* against the evaluation, and on the
//! `max_eval_retries`-th strike the shard poisons it instead of
//! requeueing — every outstanding trial is scored as the configured
//! `poison_penalty` via [`Session::poison`] and the evaluation becomes
//! a regular (loudly marked-by-value) history record, so the study
//! completes and the incident is remembered rather than silently
//! dropped. The strike counts live in the study snapshot and the
//! requeue/poison WAL records, so replay reproduces the decision.
//!
//! The server side never runs trials, so the session's evaluator is a
//! [`SyntheticEvaluator`] built deterministically from the study's
//! config — only its *pure* surface (space, `n_params`,
//! `loss_of_mean_prediction`) is exercised, by proposal scoring and
//! aggregation. Workers run the actual trials client-side.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::config;
use crate::eval::synthetic::SyntheticEvaluator;
use crate::eval::Evaluator;
use crate::exec::{Session, TellCheck};
use crate::optimizer::{HpoConfig, RefitStats};
use crate::serve::clock::Clock;
use crate::serve::proto::{
    ErrorCode, Request, Response, WireBest, WireJob,
};
use crate::serve::wal::{StudySnapshot, Wal, WalFailure, WalRecord};

/// An evaluation-granular work grant: `worker` may deliver trials of
/// the evaluation until `expires_ms` on the shard's clock.
#[derive(Debug, Clone)]
pub struct Lease {
    /// Worker id that asked for the evaluation.
    pub worker: String,
    /// Clock reading after which the lease is expired.
    pub expires_ms: u64,
}

/// One study owned by a shard.
struct Study {
    config_toml: String,
    gamma: f64,
    session: Session<'static>,
    /// Live leases by evaluation id.
    leases: BTreeMap<usize, Lease>,
    /// Lease-expiry strikes per pending evaluation (quarantine state).
    fail_counts: BTreeMap<usize, usize>,
    /// Evaluations quarantined so far.
    poisoned: usize,
    stopped: bool,
}

/// Operational counters (not part of the replayed state).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardCounters {
    /// Evaluations handed out.
    pub asks: u64,
    /// Trial outcomes absorbed.
    pub tells: u64,
    /// Lease-expiry and recovery requeues.
    pub requeues: u64,
    /// Evaluations quarantined with a penalty score.
    pub poisoned: u64,
    /// WAL records durably appended.
    pub wal_appends: u64,
    /// Appends that switched to the failover directory.
    pub wal_failovers: u64,
    /// Snapshot+truncate compactions performed.
    pub compactions: u64,
}

/// A shard's operational state. Transitions are one-way within a
/// process lifetime — only the supervisor's restart-from-WAL (or an
/// operator restart) returns a shard to `Healthy`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShardHealth {
    /// Serving normally.
    Healthy,
    /// A WAL append failed with in-memory state ahead of the log (or
    /// an invariant broke): every command is rejected with
    /// [`ErrorCode::Internal`] until restart + recovery.
    Wedged,
    /// Restart budget exhausted or read-only WAL policy engaged:
    /// mutations are rejected with [`ErrorCode::ShardDegraded`], but
    /// `study_status` / `list_studies` still work.
    Degraded {
        /// Human-readable cause, echoed in every rejection.
        reason: String,
    },
}

/// Per-shard behaviour knobs (`[serve]` config). Split from the
/// constructor arguments so adding a knob does not ripple through
/// every call site.
#[derive(Debug, Clone)]
pub struct ShardOpts {
    /// Lease duration granted by `ask`, in clock-milliseconds.
    pub lease_ms: u64,
    /// Compact after this many WAL appends; 0 disables.
    pub compact_every: usize,
    /// Lease-expiry strikes before an evaluation is quarantined;
    /// 0 disables quarantine (PR 9 behaviour: requeue forever).
    pub max_eval_retries: usize,
    /// Loss scored for every outstanding trial of a quarantined
    /// evaluation. Must be finite; pick it worse than any real loss.
    pub poison_penalty: f64,
    /// What to do when a WAL append fails.
    pub wal_failure: WalFailure,
}

impl Default for ShardOpts {
    fn default() -> ShardOpts {
        ShardOpts {
            lease_ms: 30_000,
            compact_every: 0,
            max_eval_retries: 8,
            poison_penalty: 1.0e9,
            wal_failure: WalFailure::Wedge,
        }
    }
}

/// Build a study's session (and γ) from its config document. The
/// evaluator is synthetic and derived from the config alone, so every
/// replica of the study — server, replayed server, worker — agrees on
/// the search space bit-for-bit.
fn build_parts(
    config_toml: &str,
) -> Result<(Box<dyn Evaluator>, HpoConfig, f64)> {
    let doc = config::parse(config_toml).context("parsing study config")?;
    let cfg = config::build(&doc).context("building study config")?;
    let ev: Box<dyn Evaluator> = Box::new(SyntheticEvaluator::new(
        cfg.space.clone(),
        cfg.hpo.seed,
    ));
    let gamma = cfg.hpo.gamma;
    Ok((ev, cfg.hpo, gamma))
}

fn fresh_study(config_toml: &str) -> Result<Study> {
    let (ev, hpo, gamma) = build_parts(config_toml)?;
    Ok(Study {
        config_toml: config_toml.to_string(),
        gamma,
        session: Session::new_boxed(ev, &hpo),
        leases: BTreeMap::new(),
        fail_counts: BTreeMap::new(),
        poisoned: 0,
        stopped: false,
    })
}

fn restored_study(snap: &StudySnapshot) -> Result<Study> {
    let (ev, hpo, gamma) = build_parts(&snap.config_toml)?;
    let session = Session::restore_boxed(ev, &hpo, &snap.checkpoint)
        .with_context(|| {
            format!("restoring study {:?}", snap.study)
        })?;
    Ok(Study {
        config_toml: snap.config_toml.clone(),
        gamma,
        session,
        leases: BTreeMap::new(),
        fail_counts: snap.fail_counts.clone(),
        poisoned: snap.poisoned,
        stopped: snap.stopped,
    })
}

/// A shard: a disjoint set of studies, their leases, and (optionally)
/// their write-ahead log. Single-owner — see the module docs.
pub struct ShardCore {
    id: usize,
    clock: Arc<dyn Clock>,
    opts: ShardOpts,
    appends_since_compact: usize,
    wal: Option<Wal>,
    health: ShardHealth,
    studies: BTreeMap<String, Study>,
    counters: ShardCounters,
}

impl ShardCore {
    /// A fresh, empty shard. `wal` of `None` runs without durability
    /// (pure in-memory service).
    pub fn new(
        id: usize,
        clock: Arc<dyn Clock>,
        opts: ShardOpts,
        wal: Option<Wal>,
    ) -> ShardCore {
        ShardCore {
            id,
            clock,
            opts,
            appends_since_compact: 0,
            wal,
            health: ShardHealth::Healthy,
            studies: BTreeMap::new(),
            counters: ShardCounters::default(),
        }
    }

    /// Rebuild a shard from an opened WAL: load the newest snapshot,
    /// replay every record appended since (verifying ask divergence),
    /// then requeue every evaluation that was in a worker's hands at
    /// the crash — their leases died with the process, so they must
    /// re-emerge from future asks. Each such requeue is a quarantine
    /// strike, so an evaluation that crashes the *shard* on every
    /// hand-out also runs out of retries.
    pub fn recover(
        id: usize,
        clock: Arc<dyn Clock>,
        opts: ShardOpts,
        wal: Wal,
    ) -> Result<ShardCore> {
        let (snapshot, records) = wal.load()?;
        let mut core = ShardCore::new(id, clock, opts, None);
        if let Some(snap) = snapshot {
            for s in &snap.studies {
                core.studies
                    .insert(s.study.clone(), restored_study(s)?);
            }
        }
        for rec in records {
            core.replay(rec)?;
        }
        // Orphaned in-flight work: logged Ask, no live worker. (Studies
        // restored from a snapshot re-hand their in-flight evaluations
        // automatically — checkpoints don't capture hand-out state — so
        // only post-snapshot asks appear here.)
        core.wal = Some(wal);
        let orphans: Vec<(String, usize)> = core
            .studies
            .iter()
            .flat_map(|(name, st)| {
                st.session
                    .outstanding_ids()
                    .into_iter()
                    .map(move |id| (name.clone(), id))
            })
            .collect();
        for (study, eval_id) in orphans {
            let strikes = core
                .studies
                .get(&study)
                .and_then(|st| st.fail_counts.get(&eval_id))
                .copied()
                .unwrap_or(0)
                + 1;
            let max = core.opts.max_eval_retries;
            if max > 0 && strikes >= max {
                let penalty = core.opts.poison_penalty;
                core.append(&WalRecord::Poison {
                    study: study.clone(),
                    eval_id,
                    penalty,
                })?;
                if let Some(st) = core.studies.get_mut(&study) {
                    st.session.poison(eval_id, penalty).with_context(
                        || {
                            format!(
                                "quarantining orphan {eval_id} of \
                                 {study:?}"
                            )
                        },
                    )?;
                    st.fail_counts.remove(&eval_id);
                    st.poisoned += 1;
                    core.counters.poisoned += 1;
                }
            } else {
                core.append(&WalRecord::Requeue {
                    study: study.clone(),
                    eval_id,
                })?;
                if let Some(st) = core.studies.get_mut(&study) {
                    st.session.requeue(eval_id).with_context(|| {
                        format!(
                            "requeueing orphan {eval_id} of {study:?}"
                        )
                    })?;
                    st.fail_counts.insert(eval_id, strikes);
                    core.counters.requeues += 1;
                }
            }
        }
        Ok(core)
    }

    /// Apply one replayed WAL record. Rebuilds must match what the
    /// live shard did — a session that answers differently than the
    /// log claims is corruption and fails loudly.
    fn replay(&mut self, rec: WalRecord) -> Result<()> {
        match rec {
            WalRecord::Create { study, config_toml } => {
                if self.studies.contains_key(&study) {
                    bail!("replay: duplicate create for {study:?}");
                }
                self.studies
                    .insert(study, fresh_study(&config_toml)?);
            }
            WalRecord::Ask { study, eval_id, trials } => {
                let st = self.study_mut(&study)?;
                let job = st.session.ask_eval().ok_or_else(|| {
                    anyhow!(
                        "replay diverged: log asks {eval_id} of \
                         {study:?} but the session has nothing to hand \
                         out"
                    )
                })?;
                if job.id != eval_id || job.trials != trials {
                    bail!(
                        "replay diverged on {study:?}: log handed out \
                         evaluation {eval_id} trials {trials:?}, \
                         session hands out {} trials {:?}",
                        job.id,
                        job.trials
                    );
                }
            }
            WalRecord::Tell { study, eval_id, trial, outcome } => {
                let st = self.study_mut(&study)?;
                st.session
                    .tell(eval_id, trial, outcome)
                    .with_context(|| format!("replay tell on {study:?}"))?;
                let pending: BTreeSet<usize> =
                    st.session.pending_ids().into_iter().collect();
                st.fail_counts.retain(|id, _| pending.contains(id));
            }
            WalRecord::Requeue { study, eval_id } => {
                let st = self.study_mut(&study)?;
                st.session
                    .requeue(eval_id)
                    .with_context(|| {
                        format!("replay requeue on {study:?}")
                    })?;
                *st.fail_counts.entry(eval_id).or_insert(0) += 1;
            }
            WalRecord::Poison { study, eval_id, penalty } => {
                // The penalty comes from the record, not the current
                // config — replay reproduces the logged decision.
                let st = self.study_mut(&study)?;
                st.session.poison(eval_id, penalty).with_context(
                    || format!("replay poison on {study:?}"),
                )?;
                st.fail_counts.remove(&eval_id);
                st.poisoned += 1;
            }
            WalRecord::WalSwitch { .. } => {
                // `Wal::load` consumes switch frames while chasing the
                // failover chain; one reaching replay is corruption.
                bail!("WalSwitch record surfaced to shard replay");
            }
            WalRecord::Stop { study } => {
                self.study_mut(&study)?.stopped = true;
            }
            WalRecord::Evict { study } => {
                self.studies.remove(&study);
            }
            WalRecord::Import(snap) => {
                let study = snap.study.clone();
                self.studies.insert(study, restored_study(&snap)?);
            }
        }
        Ok(())
    }

    fn study_mut(&mut self, name: &str) -> Result<&mut Study> {
        self.studies
            .get_mut(name)
            .ok_or_else(|| anyhow!("unknown study {name:?}"))
    }

    /// Durably append one record, counting a failover switch when the
    /// WAL reports one.
    fn append(&mut self, rec: &WalRecord) -> Result<()> {
        if let Some(w) = &mut self.wal {
            if w.append(rec)? {
                self.counters.wal_failovers += 1;
            }
            self.counters.wal_appends += 1;
            self.appends_since_compact += 1;
        }
        Ok(())
    }

    /// Durably append one record, applying the `wal_failure` policy on
    /// failure. Returns the error response to emit instead of an
    /// acknowledgement. (A `Failover` policy that still fails here
    /// means the failover append itself failed — state is ahead of the
    /// log, so it wedges like `Wedge`.)
    fn log_or_degrade(&mut self, rec: WalRecord) -> Option<Response> {
        match self.append(&rec) {
            Ok(()) => None,
            Err(e) => match self.opts.wal_failure {
                WalFailure::Readonly => {
                    let reason = format!(
                        "WAL append failed under the read-only \
                         policy: {e:#}"
                    );
                    self.health =
                        ShardHealth::Degraded { reason: reason.clone() };
                    Some(Response::error(
                        ErrorCode::ShardDegraded,
                        format!("shard {}: {reason}", self.id),
                    ))
                }
                WalFailure::Wedge | WalFailure::Failover => {
                    self.health = ShardHealth::Wedged;
                    Some(Response::error(
                        ErrorCode::Internal,
                        format!(
                            "shard {}: write-ahead log append failed: \
                             {e:#}",
                            self.id
                        ),
                    ))
                }
            },
        }
    }

    /// Snapshot every study into the next WAL generation and retire
    /// the old one. Note refit counters reset across this boundary
    /// (snapshot restore refits from scratch); histories and the RNG
    /// stream are bit-identical.
    pub fn compact(&mut self) -> Result<()> {
        let Some(wal) = &mut self.wal else { return Ok(()) };
        let studies = self
            .studies
            .iter()
            .map(|(name, st)| StudySnapshot {
                study: name.clone(),
                config_toml: st.config_toml.clone(),
                stopped: st.stopped,
                poisoned: st.poisoned,
                fail_counts: st.fail_counts.clone(),
                checkpoint: st.session.snapshot(),
            })
            .collect();
        wal.compact(studies)?;
        self.appends_since_compact = 0;
        self.counters.compactions += 1;
        Ok(())
    }

    fn maybe_compact(&mut self) {
        if self.opts.compact_every > 0
            && self.appends_since_compact >= self.opts.compact_every
            && self.compact().is_err()
        {
            // A failed compaction leaves the previous generation
            // intact and authoritative; wedging is not needed, but we
            // stop trying until the next threshold crossing.
            self.appends_since_compact = 0;
        }
    }

    /// Requeue — or, on the `max_eval_retries`-th strike, quarantine —
    /// every expired lease (WAL-logged, so replay reproduces both
    /// decisions). Called on every command and on idle ticks, *before*
    /// dispatch, which pins the tie-break: at the exact expiry tick
    /// (`expires_ms == now`) the lease is already gone when the
    /// command runs.
    fn expire_leases(&mut self) {
        let now = self.clock.now_ms();
        let expired: Vec<(String, usize)> = self
            .studies
            .iter()
            .flat_map(|(name, st)| {
                st.leases
                    .iter()
                    .filter(|(_, l)| l.expires_ms <= now)
                    .map(move |(id, _)| (name.clone(), *id))
            })
            .collect();
        for (study, eval_id) in expired {
            // Apply, then log: the record is only written for
            // transitions that actually happened, so replay can never
            // diverge. A failed append engages the wal_failure policy.
            let max = self.opts.max_eval_retries;
            let penalty = self.opts.poison_penalty;
            let Some(st) = self.studies.get_mut(&study) else {
                continue;
            };
            st.leases.remove(&eval_id);
            let strikes =
                st.fail_counts.get(&eval_id).copied().unwrap_or(0) + 1;
            if max > 0 && strikes >= max {
                if st.session.poison(eval_id, penalty).is_err() {
                    continue;
                }
                st.fail_counts.remove(&eval_id);
                st.poisoned += 1;
                self.counters.poisoned += 1;
                if self
                    .log_or_degrade(WalRecord::Poison {
                        study: study.clone(),
                        eval_id,
                        penalty,
                    })
                    .is_some()
                {
                    return; // unhealthy; stop mutating
                }
            } else {
                if st.session.requeue(eval_id).is_err() {
                    continue;
                }
                st.fail_counts.insert(eval_id, strikes);
                self.counters.requeues += 1;
                if self
                    .log_or_degrade(WalRecord::Requeue {
                        study: study.clone(),
                        eval_id,
                    })
                    .is_some()
                {
                    return; // unhealthy; stop mutating
                }
            }
        }
    }

    /// Idle maintenance: lease expiry (and any due compaction).
    pub fn tick(&mut self) {
        if !matches!(self.health, ShardHealth::Healthy) {
            return;
        }
        self.expire_leases();
        self.maybe_compact();
    }

    /// The typed rejection for the current (unhealthy) state.
    fn health_error(&self, when: &str) -> Response {
        match &self.health {
            ShardHealth::Wedged => Response::error(
                ErrorCode::Internal,
                format!(
                    "shard {} is wedged after a WAL write \
                     failure{when}; restart and recover from the log",
                    self.id
                ),
            ),
            ShardHealth::Degraded { reason } => Response::error(
                ErrorCode::ShardDegraded,
                format!("shard {} is degraded{when}: {reason}", self.id),
            ),
            ShardHealth::Healthy => Response::error(
                ErrorCode::Internal,
                format!("shard {}: spurious health rejection", self.id),
            ),
        }
    }

    /// Process one command. Never blocks, never panics; all failures
    /// are typed [`Response::Error`]s. A degraded shard still answers
    /// status queries — that is the point of `Degraded` over `Wedged`:
    /// operators can see what is stranded.
    pub fn handle(&mut self, req: &Request) -> Response {
        match &self.health {
            ShardHealth::Healthy => {}
            ShardHealth::Wedged => return self.health_error(""),
            ShardHealth::Degraded { .. } => {
                return match req {
                    Request::StudyStatus { study } => {
                        self.handle_status(study)
                    }
                    Request::ListStudies => Response::Studies {
                        studies: self.studies.keys().cloned().collect(),
                    },
                    _ => self.health_error(""),
                }
            }
        }
        self.expire_leases();
        if !matches!(self.health, ShardHealth::Healthy) {
            return self.health_error(" during lease expiry");
        }
        let resp = self.dispatch(req);
        self.maybe_compact();
        resp
    }

    fn dispatch(&mut self, req: &Request) -> Response {
        match req {
            Request::CreateStudy { study, config_toml } => {
                self.handle_create(study, config_toml)
            }
            Request::Ask { study, worker } => self.handle_ask(study, worker),
            Request::Tell { study, worker, eval_id, trial, outcome } => {
                self.handle_tell(study, worker, *eval_id, *trial, outcome)
            }
            Request::Heartbeat { study, worker, eval } => {
                self.handle_heartbeat(study, worker, *eval)
            }
            Request::StudyStatus { study } => self.handle_status(study),
            Request::StopStudy { study } => self.handle_stop(study),
            Request::ListStudies => Response::Studies {
                studies: self.studies.keys().cloned().collect(),
            },
        }
    }

    fn unknown(study: &str) -> Response {
        Response::error(
            ErrorCode::UnknownStudy,
            format!("no study {study:?} on this shard"),
        )
    }

    fn handle_create(&mut self, study: &str, config_toml: &str) -> Response {
        if self.studies.contains_key(study) {
            return Response::error(
                ErrorCode::DuplicateStudy,
                format!("study {study:?} already exists"),
            );
        }
        let st = match fresh_study(config_toml) {
            Ok(st) => st,
            Err(e) => {
                return Response::error(
                    ErrorCode::BadConfig,
                    format!("study {study:?}: {e:#}"),
                )
            }
        };
        if let Some(resp) = self.log_or_degrade(WalRecord::Create {
            study: study.to_string(),
            config_toml: config_toml.to_string(),
        }) {
            return resp;
        }
        self.studies.insert(study.to_string(), st);
        Response::Created { study: study.to_string() }
    }

    fn handle_ask(&mut self, study: &str, worker: &str) -> Response {
        let lease_ms = self.opts.lease_ms;
        let now = self.clock.now_ms();
        let Some(st) = self.studies.get_mut(study) else {
            return Self::unknown(study);
        };
        if st.stopped || st.session.is_complete() {
            return Response::Asked {
                study: study.to_string(),
                job: None,
                done: true,
            };
        }
        let Some(job) = st.session.ask_eval() else {
            return Response::Asked {
                study: study.to_string(),
                job: None,
                done: false, // work in flight; ask again after tells
            };
        };
        st.leases.insert(
            job.id,
            Lease {
                worker: worker.to_string(),
                expires_ms: now.saturating_add(lease_ms),
            },
        );
        if let Some(resp) = self.log_or_degrade(WalRecord::Ask {
            study: study.to_string(),
            eval_id: job.id,
            trials: job.trials.clone(),
        }) {
            return resp;
        }
        self.counters.asks += 1;
        Response::Asked {
            study: study.to_string(),
            job: Some(WireJob {
                eval_id: job.id,
                theta: job.theta,
                seed: job.seed,
                trials: job.trials,
                lease_ms,
            }),
            done: false,
        }
    }

    fn handle_tell(
        &mut self,
        study: &str,
        _worker: &str,
        eval_id: usize,
        trial: usize,
        outcome: &crate::eval::TrialOutcome,
    ) -> Response {
        let Some(st) = self.studies.get_mut(study) else {
            return Self::unknown(study);
        };
        // Typed pre-flight: rejections must not mutate the session or
        // the log, so redelivered tells are idempotent no-ops.
        match st.session.check_tell(eval_id, trial) {
            TellCheck::Accept => {}
            TellCheck::UnknownEval => {
                return Response::error(
                    ErrorCode::UnknownEval,
                    format!(
                        "study {study:?} has no evaluation {eval_id}"
                    ),
                )
            }
            TellCheck::BadTrial => {
                return Response::error(
                    ErrorCode::BadTrial,
                    format!(
                        "trial {trial} outside evaluation {eval_id}'s \
                         planned set"
                    ),
                )
            }
            TellCheck::Duplicate => {
                return Response::error(
                    ErrorCode::DuplicateTell,
                    format!(
                        "outcome for evaluation {eval_id} trial {trial} \
                         already delivered"
                    ),
                )
            }
        }
        if let Some(resp) = self.log_or_degrade(WalRecord::Tell {
            study: study.to_string(),
            eval_id,
            trial,
            outcome: outcome.clone(),
        }) {
            return resp;
        }
        let Some(st) = self.studies.get_mut(study) else {
            return Self::unknown(study);
        };
        let told = match st.session.tell(eval_id, trial, outcome.clone()) {
            Ok(t) => t,
            Err(e) => {
                // check_tell said Accept, so this is an invariant break.
                self.health = ShardHealth::Wedged;
                return Response::error(
                    ErrorCode::Internal,
                    format!("tell accepted then failed: {e:#}"),
                );
            }
        };
        // Leases are per evaluation: release those whose evaluation is
        // no longer in a worker's hands (recorded, buffered, or
        // requeued).
        let live: BTreeSet<usize> =
            st.session.outstanding_ids().into_iter().collect();
        st.leases.retain(|id, _| live.contains(id));
        // Strike counts die with their evaluation: drop those whose
        // evaluation left the pending set (recorded or barrier-flushed
        // — requeued and buffered evaluations are still pending and
        // keep theirs).
        let pending: BTreeSet<usize> =
            st.session.pending_ids().into_iter().collect();
        st.fail_counts.retain(|id, _| pending.contains(id));
        self.counters.tells += 1;
        Response::Told { recorded: told.recorded, extended: told.extended }
    }

    fn handle_heartbeat(
        &mut self,
        study: &str,
        worker: &str,
        eval: Option<usize>,
    ) -> Response {
        let now = self.clock.now_ms();
        let lease_ms = self.opts.lease_ms;
        let Some(st) = self.studies.get_mut(study) else {
            return Self::unknown(study);
        };
        match eval {
            None => {
                let mut renewed = 0usize;
                for lease in st.leases.values_mut() {
                    if lease.worker == worker {
                        lease.expires_ms = now.saturating_add(lease_ms);
                        renewed += 1;
                    }
                }
                Response::Beat { renewed }
            }
            Some(id) => match st.leases.get_mut(&id) {
                Some(l) if l.worker == worker => {
                    l.expires_ms = now.saturating_add(lease_ms);
                    Response::Beat { renewed: 1 }
                }
                // Expired, never granted, or someone else's: a typed
                // no-op — the worker learns its lease is gone without
                // perturbing anyone's state.
                _ => Response::error(
                    ErrorCode::UnknownLease,
                    format!(
                        "worker {worker:?} holds no live lease on \
                         evaluation {id} of study {study:?}"
                    ),
                ),
            },
        }
    }

    fn handle_status(&self, study: &str) -> Response {
        let Some(st) = self.studies.get(study) else {
            return Self::unknown(study);
        };
        let best = st.session.history().best(st.gamma).map(|r| WireBest {
            eval_id: r.id,
            objective: r.objective(st.gamma),
        });
        Response::Status {
            study: study.to_string(),
            recorded: st.session.history().len(),
            in_flight: st.session.in_flight(),
            complete: st.session.is_complete(),
            stopped: st.stopped,
            poisoned: st.poisoned,
            best,
            config_toml: st.config_toml.clone(),
        }
    }

    fn handle_stop(&mut self, study: &str) -> Response {
        let Some(st) = self.studies.get(study) else {
            return Self::unknown(study);
        };
        if !st.stopped {
            if let Some(resp) = self.log_or_degrade(WalRecord::Stop {
                study: study.to_string(),
            }) {
                return resp;
            }
            if let Some(st) = self.studies.get_mut(study) {
                st.stopped = true;
            }
        }
        Response::Stopped { study: study.to_string() }
    }

    // -- migration ----------------------------------------------------

    /// Hand a study off: log the eviction, remove the study, and return
    /// its durable snapshot for the receiving shard's
    /// [`ShardCore::import_study`].
    pub fn export_study(&mut self, study: &str) -> Result<StudySnapshot> {
        let st = self
            .studies
            .get(study)
            .ok_or_else(|| anyhow!("unknown study {study:?}"))?;
        let snap = StudySnapshot {
            study: study.to_string(),
            config_toml: st.config_toml.clone(),
            stopped: st.stopped,
            poisoned: st.poisoned,
            fail_counts: st.fail_counts.clone(),
            checkpoint: st.session.snapshot(),
        };
        self.append(&WalRecord::Evict { study: study.to_string() })?;
        self.studies.remove(study);
        Ok(snap)
    }

    /// Accept a migrated study. Its in-flight evaluations re-emerge
    /// from future asks (hand-out state is not part of a checkpoint),
    /// so no requeue is needed; old leases die with the old shard.
    pub fn import_study(&mut self, snap: StudySnapshot) -> Result<()> {
        if self.studies.contains_key(&snap.study) {
            bail!("study {:?} already on shard {}", snap.study, self.id);
        }
        let st = restored_study(&snap)?;
        self.append(&WalRecord::Import(snap.clone()))?;
        self.studies.insert(snap.study, st);
        Ok(())
    }

    // -- inspection ---------------------------------------------------

    /// Shard index.
    pub fn id(&self) -> usize {
        self.id
    }

    /// True once a WAL append failed (or an invariant broke) and the
    /// shard refuses every command.
    pub fn is_wedged(&self) -> bool {
        matches!(self.health, ShardHealth::Wedged)
    }

    /// True when the shard rejects mutations but still serves status.
    pub fn is_degraded(&self) -> bool {
        matches!(self.health, ShardHealth::Degraded { .. })
    }

    /// The shard's operational state.
    pub fn health(&self) -> &ShardHealth {
        &self.health
    }

    /// Force the shard into [`ShardHealth::Degraded`] — the
    /// supervisor's terminal state once a shard's restart budget is
    /// exhausted. Status queries keep working; mutations are rejected
    /// with [`ErrorCode::ShardDegraded`].
    pub fn set_degraded(&mut self, reason: impl Into<String>) {
        self.health = ShardHealth::Degraded { reason: reason.into() };
    }

    /// The shard's behaviour knobs.
    pub fn opts(&self) -> &ShardOpts {
        &self.opts
    }

    /// Operational counters.
    pub fn counters(&self) -> ShardCounters {
        self.counters
    }

    /// Sorted study ids owned by this shard.
    pub fn study_names(&self) -> Vec<String> {
        self.studies.keys().cloned().collect()
    }

    /// A study's recorded history (None if unknown).
    pub fn history(
        &self,
        study: &str,
    ) -> Option<&crate::optimizer::History> {
        self.studies.get(study).map(|st| st.session.history())
    }

    /// A study's surrogate refit counters (None if unknown).
    pub fn stats(&self, study: &str) -> Option<RefitStats> {
        self.studies.get(study).map(|st| st.session.stats())
    }

    /// Live leases of a study, by evaluation id.
    pub fn leases(&self, study: &str) -> Vec<(usize, Lease)> {
        self.studies
            .get(study)
            .map(|st| {
                st.leases
                    .iter()
                    .map(|(id, l)| (*id, l.clone()))
                    .collect()
            })
            .unwrap_or_default()
    }
}
