//! HYPPO-RS: surrogate-based multi-level-parallelism hyperparameter
//! optimization — a Rust + JAX + Pallas reproduction of Dumont et al.,
//! MLHPC 2021 (DOI 10.1109/MLHPC54614.2021.00013).
//!
//! Layer 3 (this crate) owns the HPO engine, UQ aggregation, the simulated
//! SLURM cluster, and the PJRT runtime that executes the AOT artifacts
//! produced by `python/compile` (Layers 1-2). See DESIGN.md.

pub mod analysis;
pub mod baselines;
pub mod cluster;
pub mod config;
pub mod data;
pub mod exec;
pub mod linalg;
pub mod eval;
pub mod optimizer;
pub mod report;
pub mod runtime;
pub mod sampling;
pub mod serve;
pub mod space;
pub mod surrogate;
pub mod tomo;
pub mod uq;
pub mod util;
