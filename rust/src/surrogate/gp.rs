//! Gaussian-process surrogate (paper Eq. 11) with expected improvement.
//!
//!   m(θ) = ν + Z(θ),  Z ~ N(0, s²) with Gaussian correlation
//!   corr(a, b) = exp(−Σ_k ϑ (a_k − b_k)²)
//!
//! ν and s² follow the standard kriging closed forms ([2] Eqs. 7-13):
//! ν̂ = (1ᵀK⁻¹y)/(1ᵀK⁻¹1), s̄² per-point from the correlation vector. The
//! length-scale ϑ is set by the median-distance heuristic and refined by a
//! small 1-D grid on the profile log-likelihood; a nugget keeps the
//! covariance SPD under repeated stochastic evaluations of the same θ.

use crate::linalg::{cholesky, cholesky_solve, forward_solve, Mat};
use crate::surrogate::Surrogate;

#[derive(Debug, Clone)]
pub struct GpSurrogate {
    pub nugget: f64,
    theta: f64,
    xs: Vec<Vec<f64>>,
    l: Option<Mat>,
    alpha: Vec<f64>, // K^{-1} (y - nu)
    nu: f64,
    sigma2: f64,
    fitted: bool,
}

impl Default for GpSurrogate {
    fn default() -> Self {
        GpSurrogate {
            nugget: 1e-6,
            theta: 1.0,
            xs: Vec::new(),
            l: None,
            alpha: Vec::new(),
            nu: 0.0,
            sigma2: 1.0,
            fitted: false,
        }
    }
}

fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

impl GpSurrogate {
    pub fn new() -> Self {
        Self::default()
    }

    fn corr(&self, a: &[f64], b: &[f64]) -> f64 {
        (-self.theta * dist2(a, b)).exp()
    }

    fn build_k(&self, xs: &[Vec<f64>]) -> Mat {
        let n = xs.len();
        let mut k = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let c = self.corr(&xs[i], &xs[j]);
                k[(i, j)] = c;
                k[(j, i)] = c;
            }
            k[(i, i)] += self.nugget;
        }
        k
    }

    /// Negative profile log-likelihood for length-scale selection.
    fn neg_loglik(&mut self, xs: &[Vec<f64>], ys: &[f64], theta: f64) -> f64 {
        self.theta = theta;
        let n = xs.len();
        let k = self.build_k(xs);
        let Some(l) = cholesky(&k) else {
            return f64::INFINITY;
        };
        let ones = vec![1.0; n];
        let kinv_y = cholesky_solve(&l, ys);
        let kinv_1 = cholesky_solve(&l, &ones);
        let nu = ys.iter().zip(&kinv_1).map(|(y, a)| y * a).sum::<f64>()
            / kinv_1.iter().sum::<f64>().max(1e-300);
        let resid: Vec<f64> = ys.iter().map(|y| y - nu).collect();
        let kinv_r: Vec<f64> = kinv_y
            .iter()
            .zip(&kinv_1)
            .map(|(a, b)| a - nu * b)
            .collect();
        let sigma2 = resid
            .iter()
            .zip(&kinv_r)
            .map(|(r, a)| r * a)
            .sum::<f64>()
            / n as f64;
        if sigma2 <= 0.0 {
            return f64::INFINITY;
        }
        let logdet: f64 =
            (0..n).map(|i| l[(i, i)].ln()).sum::<f64>() * 2.0;
        0.5 * (n as f64 * sigma2.ln() + logdet)
    }
}

impl Surrogate for GpSurrogate {
    fn fit(&mut self, xs: &[Vec<f64>], ys: &[f64]) -> bool {
        assert_eq!(xs.len(), ys.len());
        self.fitted = false;
        if xs.is_empty() {
            return false;
        }
        let n = xs.len();

        // Median-distance heuristic as the center of the theta grid.
        let mut d2s: Vec<f64> = Vec::new();
        for i in 0..n {
            for j in 0..i {
                let d = dist2(&xs[i], &xs[j]);
                if d > 1e-15 {
                    d2s.push(d);
                }
            }
        }
        let med = if d2s.is_empty() {
            1.0
        } else {
            d2s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            d2s[d2s.len() / 2]
        };
        let center = 1.0 / med.max(1e-9);

        // Profile-likelihood grid around the heuristic.
        let mut best = (f64::INFINITY, center);
        for mult in [0.1, 0.3, 1.0, 3.0, 10.0] {
            let th = center * mult;
            let nll = self.neg_loglik(xs, ys, th);
            if nll < best.0 {
                best = (nll, th);
            }
        }
        self.theta = best.1;

        let k = self.build_k(xs);
        let Some(l) = cholesky(&k) else {
            return false;
        };
        let ones = vec![1.0; n];
        let kinv_y = cholesky_solve(&l, ys);
        let kinv_1 = cholesky_solve(&l, &ones);
        let denom = kinv_1.iter().sum::<f64>();
        if denom.abs() < 1e-300 {
            return false;
        }
        self.nu =
            ys.iter().zip(&kinv_1).map(|(y, a)| y * a).sum::<f64>() / denom;
        self.alpha = kinv_y
            .iter()
            .zip(&kinv_1)
            .map(|(a, b)| a - self.nu * b)
            .collect();
        let resid: Vec<f64> = ys.iter().map(|y| y - self.nu).collect();
        self.sigma2 = resid
            .iter()
            .zip(&self.alpha)
            .map(|(r, a)| r * a)
            .sum::<f64>()
            .max(1e-12)
            / n as f64;
        self.xs = xs.to_vec();
        self.l = Some(l);
        self.fitted = true;
        true
    }

    fn predict(&self, x: &[f64]) -> f64 {
        assert!(self.fitted, "predict before fit");
        let kvec: Vec<f64> =
            self.xs.iter().map(|xi| self.corr(xi, x)).collect();
        self.nu
            + kvec
                .iter()
                .zip(&self.alpha)
                .map(|(k, a)| k * a)
                .sum::<f64>()
    }

    fn predict_std(&self, x: &[f64]) -> Option<f64> {
        assert!(self.fitted, "predict_std before fit");
        let l = self.l.as_ref()?;
        let kvec: Vec<f64> =
            self.xs.iter().map(|xi| self.corr(xi, x)).collect();
        // var = sigma2 * (1 + nugget - k^T K^-1 k), ignoring the small
        // correction for estimating nu.
        let v = forward_solve(l, &kvec);
        let kk: f64 = v.iter().map(|a| a * a).sum();
        let var = self.sigma2 * (1.0 + self.nugget - kk);
        Some(var.max(0.0).sqrt())
    }
}

/// Expected improvement (Jones et al. 1998) for minimization: the
/// acquisition the paper maximizes with a genetic algorithm.
pub fn expected_improvement(pred: f64, std: f64, best: f64) -> f64 {
    if std <= 1e-14 {
        return (best - pred).max(0.0);
    }
    let z = (best - pred) / std;
    // max(0): the closed form can go epsilon-negative in floating point
    // for deeply hopeless points (z << 0).
    ((best - pred) * normal_cdf(z) + std * normal_pdf(z)).max(0.0)
}

fn normal_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Φ via the Abramowitz-Stegun 7.1.26 erf approximation (|err| < 1.5e-7).
fn normal_cdf(z: f64) -> f64 {
    let x = z / std::f64::consts::SQRT_2;
    let t = 1.0 / (1.0 + 0.3275911 * x.abs());
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t
            - 0.284496736)
            * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    let erf = if x >= 0.0 { y } else { -y };
    0.5 * (1.0 + erf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::sampling::rng::Rng;
    use crate::util::prop::forall;

    fn toy(n: usize, rng: &mut Rng) -> (Vec<Vec<f64>>, Vec<f64>) {
        let xs: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.f64(), rng.f64()]).collect();
        let ys = xs
            .iter()
            .map(|x| (x[0] - 0.5).powi(2) + 0.3 * x[1])
            .collect();
        (xs, ys)
    }

    #[test]
    fn gp_interpolates_with_small_nugget() {
        forall("GP near-interpolation", 20, |rng| {
            let (xs, ys) = toy(12, rng);
            let mut gp = GpSurrogate::new();
            if !gp.fit(&xs, &ys) {
                return Ok(());
            }
            for (x, y) in xs.iter().zip(&ys) {
                let p = gp.predict(x);
                prop_assert!((p - y).abs() < 1e-2, "{p} vs {y}");
            }
            Ok(())
        });
    }

    #[test]
    fn gp_std_small_at_data_large_far_away() {
        let mut rng = Rng::new(0);
        let (xs, ys) = toy(15, &mut rng);
        let mut gp = GpSurrogate::new();
        assert!(gp.fit(&xs, &ys));
        let at_data = gp.predict_std(&xs[0]).unwrap();
        let far = gp.predict_std(&[10.0, 10.0]).unwrap();
        assert!(
            at_data < far * 0.5,
            "at_data {at_data} vs far {far}"
        );
    }

    #[test]
    fn gp_handles_duplicate_points_via_nugget() {
        let xs = vec![
            vec![0.2, 0.2],
            vec![0.2, 0.2],
            vec![0.8, 0.3],
            vec![0.5, 0.9],
        ];
        let ys = vec![1.0, 1.2, 2.0, 3.0];
        let mut gp = GpSurrogate::new();
        assert!(gp.fit(&xs, &ys), "nugget must absorb duplicates");
        let p = gp.predict(&[0.2, 0.2]);
        assert!((0.8..1.4).contains(&p), "{p}");
    }

    #[test]
    fn ei_properties() {
        // Zero std: EI is the plain improvement.
        assert_eq!(expected_improvement(1.0, 0.0, 2.0), 1.0);
        assert_eq!(expected_improvement(3.0, 0.0, 2.0), 0.0);
        // Positive std: EI > deterministic improvement, and EI grows
        // with uncertainty.
        let e1 = expected_improvement(2.5, 0.1, 2.0);
        let e2 = expected_improvement(2.5, 1.0, 2.0);
        assert!(e1 >= 0.0 && e2 > e1);
        // Monotone in predicted value.
        assert!(
            expected_improvement(1.5, 0.5, 2.0)
                > expected_improvement(2.5, 0.5, 2.0)
        );
    }

    #[test]
    fn normal_cdf_sane() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!(normal_cdf(5.0) > 0.9999);
        assert!(normal_cdf(-5.0) < 0.0001);
        let d = normal_cdf(1.0) - 0.8413447;
        assert!(d.abs() < 1e-5, "{d}");
    }
}
