//! Gaussian-process surrogate (paper Eq. 11) with expected improvement.
//!
//!   m(θ) = ν + Z(θ),  Z ~ N(0, s²) with Gaussian correlation
//!   corr(a, b) = exp(−Σ_k ϑ (a_k − b_k)²)
//!
//! ν and s² follow the standard kriging closed forms ([2] Eqs. 7-13):
//! ν̂ = (1ᵀK⁻¹y)/(1ᵀK⁻¹1), s̄² per-point from the correlation vector. The
//! length-scale ϑ is set by the median-distance heuristic and refined by a
//! small 1-D grid on the profile log-likelihood; a nugget keeps the
//! covariance SPD under repeated stochastic evaluations of the same θ.

use crate::linalg::{
    cholesky_solve_into, cholesky_solve_many_ws, cholesky_ws,
    forward_solve, forward_solve_into, Mat, Workspace,
};
use crate::surrogate::Surrogate;

/// Solve `K⁻¹ [y | 1]` over one Cholesky factor: the kriging closed
/// forms need both columns, and the multi-RHS solve walks the factor
/// once with the identical per-column op sequence as two
/// `cholesky_solve` calls (so results are bit-equal). The RHS matrix,
/// the solve scratch, and the returned column vectors all come from the
/// workspace pool; callers `give` the columns back when done.
fn kinv_y_and_1(
    l: &Mat,
    ys: &[f64],
    ws: &mut Workspace,
) -> (Vec<f64>, Vec<f64>) {
    let n = ys.len();
    let mut rhs = ws.take_mat(n, 2);
    for (row, y) in rhs.data.chunks_exact_mut(2).zip(ys) {
        if let [r0, r1] = row {
            *r0 = *y;
            *r1 = 1.0;
        }
    }
    let sol = cholesky_solve_many_ws(l, &rhs, ws);
    let mut kinv_y = ws.take(n);
    let mut kinv_1 = ws.take(n);
    for ((row, a), b) in sol
        .data
        .chunks_exact(2)
        .zip(kinv_y.iter_mut())
        .zip(kinv_1.iter_mut())
    {
        if let [s0, s1] = row {
            *a = *s0;
            *b = *s1;
        }
    }
    ws.give_mat(rhs);
    ws.give_mat(sol);
    (kinv_y, kinv_1)
}

/// Kriging surrogate state: correlation length-scale, Cholesky factor of
/// the covariance, and the closed-form mean/scale estimates.
#[derive(Debug, Clone)]
pub struct GpSurrogate {
    /// Diagonal jitter keeping the covariance SPD under duplicate /
    /// near-duplicate evaluations of the same θ.
    pub nugget: f64,
    theta: f64,
    xs: Vec<Vec<f64>>,
    ys: Vec<f64>,
    l: Option<Mat>,
    alpha: Vec<f64>, // K^{-1} (y - nu)
    nu: f64,
    sigma2: f64,
    fitted: bool,
}

impl Default for GpSurrogate {
    fn default() -> Self {
        GpSurrogate {
            nugget: 1e-6,
            theta: 1.0,
            xs: Vec::new(),
            ys: Vec::new(),
            l: None,
            alpha: Vec::new(),
            nu: 0.0,
            sigma2: 1.0,
            fitted: false,
        }
    }
}

fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

impl GpSurrogate {
    /// A fresh, unfitted surrogate with the default nugget.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of training points currently absorbed.
    pub fn n_points(&self) -> usize {
        self.xs.len()
    }

    /// Whether `fit` (or `fit_incremental`) has produced a usable model.
    pub fn is_fitted(&self) -> bool {
        self.fitted
    }

    /// The current Gaussian-correlation length-scale parameter ϑ.
    pub fn length_scale(&self) -> f64 {
        self.theta
    }

    fn corr(&self, a: &[f64], b: &[f64]) -> f64 {
        (-self.theta * dist2(a, b)).exp()
    }

    fn build_k_ws(&self, xs: &[Vec<f64>], ws: &mut Workspace) -> Mat {
        let n = xs.len();
        let mut k = ws.take_mat(n, n);
        for i in 0..n {
            for j in 0..=i {
                let c = self.corr(&xs[i], &xs[j]);
                k[(i, j)] = c;
                k[(j, i)] = c;
            }
            k[(i, i)] += self.nugget;
        }
        k
    }

    /// Refit on `(xs, ys)` keeping the **current** length-scale ϑ (no
    /// profile-likelihood search). This is the full-refit fallback the
    /// incremental path cross-checks against: after a successful sequence
    /// of `fit_incremental` calls, `refit_full` over the same data and ϑ
    /// produces the same model (up to fp round-off).
    pub fn refit_full(&mut self, xs: &[Vec<f64>], ys: &[f64]) -> bool {
        let mut ws = Workspace::new();
        self.refit_full_ws(xs, ys, &mut ws)
    }

    /// [`GpSurrogate::refit_full`] with every intermediate — covariance,
    /// factor, kriging RHS/solution columns — drawn from a caller-owned
    /// [`Workspace`]; the evicted previous factor is recycled into the
    /// pool, so a steady-state refit loop runs with zero heap traffic
    /// (metered by [`Workspace::alloc_bytes`]). Identical operation
    /// sequence to `refit_full`.
    pub fn refit_full_ws(
        &mut self,
        xs: &[Vec<f64>],
        ys: &[f64],
        ws: &mut Workspace,
    ) -> bool {
        assert_eq!(xs.len(), ys.len());
        self.fitted = false;
        if xs.is_empty() {
            return false;
        }
        let n = xs.len();
        let k = self.build_k_ws(xs, ws);
        if let Some(old) = self.l.take() {
            ws.give_mat(old);
        }
        let factor = cholesky_ws(&k, ws);
        ws.give_mat(k);
        let Some(l) = factor else {
            return false;
        };
        let (kinv_y, kinv_1) = kinv_y_and_1(&l, ys, ws);
        let denom = kinv_1.iter().sum::<f64>();
        if denom.abs() < 1e-300 {
            ws.give(kinv_y);
            ws.give(kinv_1);
            ws.give_mat(l);
            return false;
        }
        self.nu =
            ys.iter().zip(&kinv_1).map(|(y, a)| y * a).sum::<f64>() / denom;
        self.alpha.clear();
        self.alpha.extend(
            kinv_y
                .iter()
                .zip(&kinv_1)
                .map(|(a, b)| a - self.nu * b),
        );
        self.sigma2 = ys
            .iter()
            .map(|y| y - self.nu)
            .zip(&self.alpha)
            .map(|(r, a)| r * a)
            .sum::<f64>()
            .max(1e-12)
            / n as f64;
        ws.give(kinv_y);
        ws.give(kinv_1);
        self.xs.resize_with(xs.len(), Vec::new);
        for (dst, src) in self.xs.iter_mut().zip(xs) {
            dst.clear();
            dst.extend_from_slice(src);
        }
        self.ys.clear();
        self.ys.extend_from_slice(ys);
        self.l = Some(l);
        self.fitted = true;
        true
    }

    /// Cross-correlation block K(X, X_train): row `i` holds
    /// `corr(train_j, xs[i])` for every training point `j`, in training
    /// order — exactly the vector the scalar `predict`/`predict_std`
    /// rebuild per call, built once per batch into a workspace buffer.
    fn corr_block(&self, xs: &[Vec<f64>], ws: &mut Workspace) -> Mat {
        let n = self.xs.len();
        let mut data = ws.take(xs.len() * n);
        for (row, x) in data.chunks_mut(n).zip(xs) {
            for (c, xi) in row.iter_mut().zip(&self.xs) {
                *c = self.corr(xi, x);
            }
        }
        Mat { rows: xs.len(), cols: n, data }
    }

    /// Batched mean **and** std sharing one cross-correlation block —
    /// the EI scoring path pays one K(X_cand, X_train) build instead of
    /// two per candidate. Results are bit-identical to per-point
    /// `predict` / `predict_std` (same accumulation order).
    pub fn predict_mean_std_batch(
        &self,
        xs: &[Vec<f64>],
        ws: &mut Workspace,
        means: &mut Vec<f64>,
        stds: &mut Vec<f64>,
    ) {
        assert!(self.fitted, "predict before fit");
        means.clear();
        stds.clear();
        if xs.is_empty() {
            return;
        }
        let l = self.l.as_ref().expect("fitted GP holds its factor");
        let k = self.corr_block(xs, ws);
        let mut v = ws.take(k.cols);
        for row in k.data.chunks(k.cols) {
            means.push(
                self.nu
                    + row
                        .iter()
                        .zip(&self.alpha)
                        .map(|(kv, a)| kv * a)
                        .sum::<f64>(),
            );
            forward_solve_into(l, row, &mut v);
            let kk: f64 = v.iter().map(|a| a * a).sum();
            let var = self.sigma2 * (1.0 + self.nugget - kk);
            stds.push(var.max(0.0).sqrt());
        }
        ws.give(v);
        ws.give(k.data);
    }

    /// Negative profile log-likelihood for length-scale selection.
    /// All scratch comes from the workspace pool.
    fn neg_loglik(
        &mut self,
        xs: &[Vec<f64>],
        ys: &[f64],
        theta: f64,
        ws: &mut Workspace,
    ) -> f64 {
        self.theta = theta;
        let n = xs.len();
        let k = self.build_k_ws(xs, ws);
        let factor = cholesky_ws(&k, ws);
        ws.give_mat(k);
        let Some(l) = factor else {
            return f64::INFINITY;
        };
        let mut ones = ws.take(n);
        ones.fill(1.0);
        let mut kinv_y = ws.take(0);
        let mut kinv_1 = ws.take(0);
        cholesky_solve_into(&l, ys, &mut kinv_y);
        cholesky_solve_into(&l, &ones, &mut kinv_1);
        let nu = ys.iter().zip(&kinv_1).map(|(y, a)| y * a).sum::<f64>()
            / kinv_1.iter().sum::<f64>().max(1e-300);
        let sigma2 = ys
            .iter()
            .map(|y| y - nu)
            .zip(
                kinv_y
                    .iter()
                    .zip(&kinv_1)
                    .map(|(a, b)| a - nu * b),
            )
            .map(|(r, a)| r * a)
            .sum::<f64>()
            / n as f64;
        let logdet: f64 = l
            .data
            .iter()
            .step_by(n + 1)
            .map(|d| d.ln())
            .sum::<f64>()
            * 2.0;
        ws.give(ones);
        ws.give(kinv_y);
        ws.give(kinv_1);
        ws.give_mat(l);
        if sigma2 <= 0.0 {
            return f64::INFINITY;
        }
        0.5 * (n as f64 * sigma2.ln() + logdet)
    }
}

impl GpSurrogate {
    /// Full fit (length-scale search + refit) with all linear-algebra
    /// scratch drawn from a caller-owned [`Workspace`]. Identical
    /// operation sequence to the trait [`Surrogate::fit`].
    pub fn fit_ws(
        &mut self,
        xs: &[Vec<f64>],
        ys: &[f64],
        ws: &mut Workspace,
    ) -> bool {
        assert_eq!(xs.len(), ys.len());
        self.fitted = false;
        if xs.is_empty() {
            return false;
        }
        let n = xs.len();

        // Median-distance heuristic as the center of the theta grid.
        let mut d2s: Vec<f64> = Vec::new();
        for i in 0..n {
            for j in 0..i {
                let d = dist2(&xs[i], &xs[j]);
                if d > 1e-15 {
                    d2s.push(d);
                }
            }
        }
        let med = if d2s.is_empty() {
            1.0
        } else {
            d2s.sort_by(|a, b| a.total_cmp(b));
            d2s[d2s.len() / 2]
        };
        let center = 1.0 / med.max(1e-9);

        // Profile-likelihood grid around the heuristic.
        let mut best = (f64::INFINITY, center);
        for mult in [0.1, 0.3, 1.0, 3.0, 10.0] {
            let th = center * mult;
            let nll = self.neg_loglik(xs, ys, th, ws);
            if nll < best.0 {
                best = (nll, th);
            }
        }
        self.theta = best.1;
        self.refit_full_ws(xs, ys, ws)
    }

    /// Incremental (bordered-factor) update with all scratch drawn from
    /// a caller-owned [`Workspace`]; the superseded factor is recycled
    /// into the pool. Identical operation sequence to the trait
    /// [`Surrogate::fit_incremental`].
    pub fn fit_incremental_ws(
        &mut self,
        x: &[f64],
        y: f64,
        ws: &mut Workspace,
    ) -> bool {
        if !self.fitted {
            return false;
        }
        // A fitted model has at least one point; reject dimension
        // mismatches instead of letting dist2's zip silently truncate.
        if self.xs.first().map(Vec::len) != Some(x.len()) {
            return false;
        }
        let n = self.xs.len();
        let Some(l) = self.l.as_ref() else {
            return false;
        };
        // New row of the extended Cholesky factor: solving L w = k applies
        // exactly the recurrences a from-scratch factorization would use
        // for row n, so the extended factor matches `refit_full`.
        let mut kvec = ws.take(n);
        for (c, xi) in kvec.iter_mut().zip(&self.xs) {
            *c = self.corr(xi, x);
        }
        let mut w = ws.take(0);
        forward_solve_into(l, &kvec, &mut w);
        let d2 = 1.0 + self.nugget - w.iter().map(|v| v * v).sum::<f64>();
        if d2 <= 1e-10 {
            // Near-duplicate point: the rank-1 extension would be
            // numerically fragile. Let the caller refit fully (the nugget
            // absorbs duplicates there).
            ws.give(kvec);
            ws.give(w);
            return false;
        }
        let mut l2 = ws.take_mat(n + 1, n + 1);
        for (dst, src) in l2
            .data
            .chunks_exact_mut(n + 1)
            .zip(l.data.chunks_exact(n.max(1)))
        {
            for (d, s) in dst.iter_mut().zip(src) {
                *d = *s;
            }
        }
        if let Some(last) = l2.data.chunks_exact_mut(n + 1).nth(n) {
            for (d, s) in last.iter_mut().zip(&w) {
                *d = *s;
            }
            if let Some(diag) = last.get_mut(n) {
                *diag = d2.sqrt();
            }
        }
        ws.give(kvec);
        ws.give(w);

        self.xs.push(x.to_vec());
        self.ys.push(y);
        let m = n + 1;
        // O(n²): one multi-RHS triangular solve against the extended
        // factor (both kriging columns in a single walk).
        let (kinv_y, kinv_1) = kinv_y_and_1(&l2, &self.ys, ws);
        let denom = kinv_1.iter().sum::<f64>();
        if denom.abs() < 1e-300 {
            self.xs.pop();
            self.ys.pop();
            ws.give(kinv_y);
            ws.give(kinv_1);
            ws.give_mat(l2);
            return false;
        }
        self.nu = self
            .ys
            .iter()
            .zip(&kinv_1)
            .map(|(y, a)| y * a)
            .sum::<f64>()
            / denom;
        self.alpha.clear();
        self.alpha.extend(
            kinv_y
                .iter()
                .zip(&kinv_1)
                .map(|(a, b)| a - self.nu * b),
        );
        self.sigma2 = self
            .ys
            .iter()
            .map(|y| y - self.nu)
            .zip(&self.alpha)
            .map(|(r, a)| r * a)
            .sum::<f64>()
            .max(1e-12)
            / m as f64;
        ws.give(kinv_y);
        ws.give(kinv_1);
        if let Some(old) = self.l.replace(l2) {
            ws.give_mat(old);
        }
        true
    }
}

impl Surrogate for GpSurrogate {
    fn fit(&mut self, xs: &[Vec<f64>], ys: &[f64]) -> bool {
        let mut ws = Workspace::new();
        self.fit_ws(xs, ys, &mut ws)
    }

    fn fit_incremental(&mut self, x: &[f64], y: f64) -> bool {
        let mut ws = Workspace::new();
        self.fit_incremental_ws(x, y, &mut ws)
    }

    fn fit_ws(&mut self, xs: &[Vec<f64>], ys: &[f64], ws: &mut Workspace) -> bool {
        GpSurrogate::fit_ws(self, xs, ys, ws)
    }

    fn fit_incremental_ws(&mut self, x: &[f64], y: f64, ws: &mut Workspace) -> bool {
        GpSurrogate::fit_incremental_ws(self, x, y, ws)
    }

    fn predict(&self, x: &[f64]) -> f64 {
        assert!(self.fitted, "predict before fit");
        let kvec: Vec<f64> =
            self.xs.iter().map(|xi| self.corr(xi, x)).collect();
        self.nu
            + kvec
                .iter()
                .zip(&self.alpha)
                .map(|(k, a)| k * a)
                .sum::<f64>()
    }

    fn predict_std(&self, x: &[f64]) -> Option<f64> {
        assert!(self.fitted, "predict_std before fit");
        let l = self.l.as_ref()?;
        let kvec: Vec<f64> =
            self.xs.iter().map(|xi| self.corr(xi, x)).collect();
        // var = sigma2 * (1 + nugget - k^T K^-1 k), ignoring the small
        // correction for estimating nu.
        let v = forward_solve(l, &kvec);
        let kk: f64 = v.iter().map(|a| a * a).sum();
        let var = self.sigma2 * (1.0 + self.nugget - kk);
        Some(var.max(0.0).sqrt())
    }

    fn predict_batch(
        &self,
        xs: &[Vec<f64>],
        ws: &mut Workspace,
        out: &mut Vec<f64>,
    ) {
        assert!(self.fitted, "predict before fit");
        out.clear();
        if xs.is_empty() {
            return;
        }
        let k = self.corr_block(xs, ws);
        out.reserve(xs.len());
        for row in k.data.chunks(k.cols) {
            out.push(
                self.nu
                    + row
                        .iter()
                        .zip(&self.alpha)
                        .map(|(kv, a)| kv * a)
                        .sum::<f64>(),
            );
        }
        ws.give(k.data);
    }

    fn predict_std_batch(
        &self,
        xs: &[Vec<f64>],
        ws: &mut Workspace,
        out: &mut Vec<f64>,
    ) -> bool {
        assert!(self.fitted, "predict_std before fit");
        out.clear();
        let Some(l) = self.l.as_ref() else {
            return false;
        };
        if xs.is_empty() {
            return true;
        }
        let k = self.corr_block(xs, ws);
        let mut v = ws.take(k.cols);
        out.reserve(xs.len());
        for row in k.data.chunks(k.cols) {
            forward_solve_into(l, row, &mut v);
            let kk: f64 = v.iter().map(|a| a * a).sum();
            let var = self.sigma2 * (1.0 + self.nugget - kk);
            out.push(var.max(0.0).sqrt());
        }
        ws.give(v);
        ws.give(k.data);
        true
    }
}

/// Expected improvement (Jones et al. 1998) for minimization: the
/// acquisition the paper maximizes with a genetic algorithm.
pub fn expected_improvement(pred: f64, std: f64, best: f64) -> f64 {
    if std <= 1e-14 {
        return (best - pred).max(0.0);
    }
    let z = (best - pred) / std;
    // max(0): the closed form can go epsilon-negative in floating point
    // for deeply hopeless points (z << 0).
    ((best - pred) * normal_cdf(z) + std * normal_pdf(z)).max(0.0)
}

fn normal_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Φ via the Abramowitz-Stegun 7.1.26 erf approximation (|err| < 1.5e-7).
fn normal_cdf(z: f64) -> f64 {
    let x = z / std::f64::consts::SQRT_2;
    let t = 1.0 / (1.0 + 0.3275911 * x.abs());
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t
            - 0.284496736)
            * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    let erf = if x >= 0.0 { y } else { -y };
    0.5 * (1.0 + erf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::sampling::rng::Rng;
    use crate::util::prop::forall;

    fn toy(n: usize, rng: &mut Rng) -> (Vec<Vec<f64>>, Vec<f64>) {
        let xs: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.f64(), rng.f64()]).collect();
        let ys = xs
            .iter()
            .map(|x| (x[0] - 0.5).powi(2) + 0.3 * x[1])
            .collect();
        (xs, ys)
    }

    #[test]
    fn gp_interpolates_with_small_nugget() {
        forall("GP near-interpolation", 20, |rng| {
            let (xs, ys) = toy(12, rng);
            let mut gp = GpSurrogate::new();
            if !gp.fit(&xs, &ys) {
                return Ok(());
            }
            for (x, y) in xs.iter().zip(&ys) {
                let p = gp.predict(x);
                prop_assert!((p - y).abs() < 1e-2, "{p} vs {y}");
            }
            Ok(())
        });
    }

    #[test]
    fn gp_std_small_at_data_large_far_away() {
        let mut rng = Rng::new(0);
        let (xs, ys) = toy(15, &mut rng);
        let mut gp = GpSurrogate::new();
        assert!(gp.fit(&xs, &ys));
        let at_data = gp.predict_std(&xs[0]).unwrap();
        let far = gp.predict_std(&[10.0, 10.0]).unwrap();
        assert!(
            at_data < far * 0.5,
            "at_data {at_data} vs far {far}"
        );
    }

    #[test]
    fn gp_handles_duplicate_points_via_nugget() {
        let xs = vec![
            vec![0.2, 0.2],
            vec![0.2, 0.2],
            vec![0.8, 0.3],
            vec![0.5, 0.9],
        ];
        let ys = vec![1.0, 1.2, 2.0, 3.0];
        let mut gp = GpSurrogate::new();
        assert!(gp.fit(&xs, &ys), "nugget must absorb duplicates");
        let p = gp.predict(&[0.2, 0.2]);
        assert!((0.8..1.4).contains(&p), "{p}");
    }

    #[test]
    fn incremental_update_matches_fixed_theta_full_refit() {
        forall("GP incremental == full refit", 15, |rng| {
            let (xs, ys) = toy(24, rng);
            let mut inc = GpSurrogate::new();
            if !inc.fit(&xs[..12], &ys[..12]) {
                return Ok(());
            }
            for i in 12..24 {
                if !inc.fit_incremental(&xs[i], ys[i]) {
                    return Ok(()); // degenerate extension: caller refits
                }
            }
            // Full refit at the same length-scale over the same data.
            let mut full = inc.clone();
            prop_assert!(full.refit_full(&xs, &ys), "full refit failed");
            for _ in 0..20 {
                let q = vec![rng.f64() * 1.4 - 0.2, rng.f64() * 1.4 - 0.2];
                let (a, b) = (inc.predict(&q), full.predict(&q));
                prop_assert!((a - b).abs() < 1e-8, "mean {a} vs {b}");
                let sa = inc.predict_std(&q).unwrap();
                let sb = full.predict_std(&q).unwrap();
                prop_assert!((sa - sb).abs() < 1e-8, "std {sa} vs {sb}");
            }
            Ok(())
        });
    }

    #[test]
    fn incremental_requires_a_fitted_model() {
        let mut gp = GpSurrogate::new();
        assert!(!gp.fit_incremental(&[0.1, 0.2], 1.0));
    }

    #[test]
    fn incremental_absorbs_duplicates_like_full_refit() {
        let mut rng = Rng::new(4);
        let (mut xs, mut ys) = toy(10, &mut rng);
        let mut inc = GpSurrogate::new();
        assert!(inc.fit(&xs, &ys));
        // Re-observe an existing location with a different outcome: the
        // nugget absorbs it on both paths.
        let dup = xs[0].clone();
        xs.push(dup.clone());
        ys.push(ys[0] + 0.05);
        if inc.fit_incremental(&dup, ys[10]) {
            let mut full = inc.clone();
            assert!(full.refit_full(&xs, &ys));
            let q = vec![0.4, 0.6];
            assert!((inc.predict(&q) - full.predict(&q)).abs() < 1e-8);
        }
        assert!(inc.is_fitted());
    }

    #[test]
    fn batch_prediction_is_bitwise_scalar() {
        forall("GP batch == scalar (bitwise)", 15, |rng| {
            let (xs, ys) = toy(14, rng);
            let mut gp = GpSurrogate::new();
            if !gp.fit(&xs, &ys) {
                return Ok(());
            }
            let qs: Vec<Vec<f64>> = (0..40)
                .map(|_| {
                    vec![rng.f64() * 1.4 - 0.2, rng.f64() * 1.4 - 0.2]
                })
                .collect();
            let mut ws = Workspace::new();
            let (mut mu, mut sd) = (Vec::new(), Vec::new());
            gp.predict_batch(&qs, &mut ws, &mut mu);
            assert!(gp.predict_std_batch(&qs, &mut ws, &mut sd));
            let (mut mu2, mut sd2) = (Vec::new(), Vec::new());
            gp.predict_mean_std_batch(&qs, &mut ws, &mut mu2, &mut sd2);
            for (i, q) in qs.iter().enumerate() {
                let m = gp.predict(q);
                let s = gp.predict_std(q).unwrap();
                prop_assert!(
                    mu[i].to_bits() == m.to_bits()
                        && mu2[i].to_bits() == m.to_bits(),
                    "mean diverged at {i}: {} vs {m}",
                    mu[i]
                );
                prop_assert!(
                    sd[i].to_bits() == s.to_bits()
                        && sd2[i].to_bits() == s.to_bits(),
                    "std diverged at {i}: {} vs {s}",
                    sd[i]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn ei_properties() {
        // Zero std: EI is the plain improvement.
        assert_eq!(expected_improvement(1.0, 0.0, 2.0), 1.0);
        assert_eq!(expected_improvement(3.0, 0.0, 2.0), 0.0);
        // Positive std: EI > deterministic improvement, and EI grows
        // with uncertainty.
        let e1 = expected_improvement(2.5, 0.1, 2.0);
        let e2 = expected_improvement(2.5, 1.0, 2.0);
        assert!(e1 >= 0.0 && e2 > e1);
        // Monotone in predicted value.
        assert!(
            expected_improvement(1.5, 0.5, 2.0)
                > expected_improvement(2.5, 0.5, 2.0)
        );
    }

    #[test]
    fn normal_cdf_sane() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!(normal_cdf(5.0) > 0.9999);
        assert!(normal_cdf(-5.0) < 0.0001);
        let d = normal_cdf(1.0) - 0.8413447;
        assert!(d.abs() < 1e-5, "{d}");
    }
}
