//! RBF ensemble from confidence intervals (paper Sec. IV, Feature 1,
//! Eq. 8).
//!
//! Each evaluated θ_j carries a loss confidence interval
//! [lower, center, upper]. The ensemble draws, per member, one of the
//! three extremes uniformly at random per data point and fits an RBF to
//! that realization. Candidate scoring then uses μ(θ) + α σ(θ) over the
//! member predictions, with α ∈ [−2, 2] steering pessimistic (α > 0) vs
//! optimistic (α < 0) treatment of prediction variability.

use crate::linalg::Workspace;
use crate::sampling::rng::Rng;
use crate::surrogate::rbf::RbfSurrogate;
use crate::surrogate::Surrogate;
use crate::uq::LossInterval;

/// RBF ensemble over confidence-interval extremes (paper Eq. 8).
#[derive(Debug, Clone)]
pub struct RbfEnsemble {
    /// Number of member RBFs to fit.
    pub n_members: usize,
    /// α of Eq. (8).
    pub alpha: f64,
    members: Vec<RbfSurrogate>,
}

impl RbfEnsemble {
    /// A fresh ensemble (`n_members` ≥ 2, α ∈ \[−2, 2\]).
    pub fn new(n_members: usize, alpha: f64) -> Self {
        assert!(n_members >= 2, "ensemble needs >= 2 members");
        assert!(
            (-2.0..=2.0).contains(&alpha),
            "alpha must lie in [-2, 2] (paper Eq. 8)"
        );
        RbfEnsemble { n_members, alpha, members: Vec::new() }
    }

    /// Fit members to random CI-extreme realizations of the data.
    pub fn fit(
        &mut self,
        xs: &[Vec<f64>],
        intervals: &[LossInterval],
        rng: &mut Rng,
    ) -> bool {
        assert_eq!(xs.len(), intervals.len());
        self.members.clear();
        if xs.is_empty() {
            return false;
        }
        for m in 0..self.n_members {
            let ys: Vec<f64> = intervals
                .iter()
                .map(|ci| {
                    if m == 0 {
                        // Anchor member: always the centers, so the
                        // ensemble mean stays centered for small
                        // ensembles.
                        ci.center
                    } else {
                        match rng.usize_below(3) {
                            0 => ci.lower(),
                            1 => ci.center,
                            _ => ci.upper(),
                        }
                    }
                })
                .collect();
            let mut rbf = RbfSurrogate::new();
            if rbf.fit(xs, &ys) {
                self.members.push(rbf);
            }
        }
        !self.members.is_empty()
    }

    /// Number of members whose fit succeeded.
    pub fn n_fitted(&self) -> usize {
        self.members.len()
    }

    /// Ensemble mean and std at a point.
    pub fn mean_std(&self, x: &[f64]) -> (f64, f64) {
        assert!(!self.members.is_empty(), "predict before fit");
        let preds: Vec<f64> =
            self.members.iter().map(|m| m.predict(x)).collect();
        let mean = preds.iter().sum::<f64>() / preds.len() as f64;
        let var = preds
            .iter()
            .map(|p| (p - mean) * (p - mean))
            .sum::<f64>()
            / preds.len() as f64;
        (mean, var.sqrt())
    }

    /// The Eq. (8) acquisition value μ + α σ (lower is better).
    pub fn score(&self, x: &[f64]) -> f64 {
        let (mu, sigma) = self.mean_std(x);
        mu + self.alpha * sigma
    }

    /// Batched ensemble mean/std: each member predicts the whole
    /// candidate set once (through the RBF kernel-block batch path),
    /// then the member axis is reduced per candidate in member order —
    /// bit-identical to per-point [`RbfEnsemble::mean_std`].
    pub fn mean_std_batch(
        &self,
        xs: &[Vec<f64>],
        ws: &mut Workspace,
        means: &mut Vec<f64>,
        stds: &mut Vec<f64>,
    ) {
        assert!(!self.members.is_empty(), "predict before fit");
        means.clear();
        stds.clear();
        if xs.is_empty() {
            return;
        }
        let nm = self.members.len();
        let npts = xs.len();
        // Member-major prediction block: preds[k * npts + i] is member
        // k's prediction at xs[i].
        let mut preds = ws.take(nm * npts);
        let mut row: Vec<f64> = ws.take(0);
        for (k, member) in self.members.iter().enumerate() {
            member.predict_batch(xs, ws, &mut row);
            preds[k * npts..(k + 1) * npts].copy_from_slice(&row);
        }
        means.reserve(npts);
        stds.reserve(npts);
        for i in 0..npts {
            let mean = (0..nm)
                .map(|k| preds[k * npts + i])
                .sum::<f64>()
                / nm as f64;
            let var = (0..nm)
                .map(|k| {
                    let p = preds[k * npts + i];
                    (p - mean) * (p - mean)
                })
                .sum::<f64>()
                / nm as f64;
            means.push(mean);
            stds.push(var.sqrt());
        }
        ws.give(row);
        ws.give(preds);
    }

    /// Batched Eq. (8) scores μ + α σ, bit-identical to per-point
    /// [`RbfEnsemble::score`].
    pub fn score_batch(
        &self,
        xs: &[Vec<f64>],
        ws: &mut Workspace,
        out: &mut Vec<f64>,
    ) {
        let mut stds = ws.take(0);
        self.mean_std_batch(xs, ws, out, &mut stds);
        for (m, s) in out.iter_mut().zip(&stds) {
            *m += self.alpha * *s;
        }
        ws.give(stds);
    }
}

impl Surrogate for RbfEnsemble {
    fn fit(&mut self, xs: &[Vec<f64>], ys: &[f64]) -> bool {
        // Degenerate intervals (radius 0) when used through the generic
        // trait: every member sees the same data.
        let intervals: Vec<LossInterval> = ys
            .iter()
            .map(|y| LossInterval { center: *y, radius: 0.0 })
            .collect();
        let mut rng = Rng::new(0xE25E);
        RbfEnsemble::fit(self, xs, &intervals, &mut rng)
    }

    fn predict(&self, x: &[f64]) -> f64 {
        self.mean_std(x).0
    }

    fn predict_std(&self, x: &[f64]) -> Option<f64> {
        Some(self.mean_std(x).1)
    }

    fn predict_batch(
        &self,
        xs: &[Vec<f64>],
        ws: &mut Workspace,
        out: &mut Vec<f64>,
    ) {
        let mut stds = ws.take(0);
        self.mean_std_batch(xs, ws, out, &mut stds);
        ws.give(stds);
    }

    fn predict_std_batch(
        &self,
        xs: &[Vec<f64>],
        ws: &mut Workspace,
        out: &mut Vec<f64>,
    ) -> bool {
        let mut means = ws.take(0);
        self.mean_std_batch(xs, ws, &mut means, out);
        ws.give(means);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn data() -> (Vec<Vec<f64>>, Vec<LossInterval>) {
        let xs: Vec<Vec<f64>> = (0..10)
            .map(|i| {
                let t = i as f64 / 9.0;
                vec![t, (t * 7.0).sin() * 0.5 + 0.5]
            })
            .collect();
        let cis = xs
            .iter()
            .map(|x| LossInterval {
                center: x[0] * x[0] + x[1],
                radius: 0.2,
            })
            .collect();
        (xs, cis)
    }

    #[test]
    fn fit_and_spread() {
        let (xs, cis) = data();
        let mut ens = RbfEnsemble::new(8, 0.0);
        let mut rng = Rng::new(1);
        assert!(ens.fit(&xs, &cis, &mut rng));
        assert!(ens.n_fitted() >= 6);
        // Nonzero interval radius must induce member disagreement.
        let (_, sigma) = ens.mean_std(&[0.35, 0.6]);
        assert!(sigma > 0.0);
    }

    #[test]
    fn zero_radius_collapses_members() {
        let (xs, cis) = data();
        let degenerate: Vec<LossInterval> = cis
            .iter()
            .map(|c| LossInterval { center: c.center, radius: 0.0 })
            .collect();
        let mut ens = RbfEnsemble::new(6, 1.0);
        let mut rng = Rng::new(2);
        assert!(ens.fit(&xs, &degenerate, &mut rng));
        let (_, sigma) = ens.mean_std(&[0.5, 0.5]);
        assert!(sigma < 1e-9, "sigma {sigma}");
    }

    #[test]
    fn alpha_steers_pessimism() {
        let (xs, cis) = data();
        let mut rng = Rng::new(3);
        let mut pess = RbfEnsemble::new(8, 2.0);
        pess.fit(&xs, &cis, &mut rng);
        let mut opt = RbfEnsemble::new(8, -2.0);
        opt.members = pess.members.clone();
        let q = [0.4, 0.7];
        let (mu, sigma) = pess.mean_std(&q);
        assert!((pess.score(&q) - (mu + 2.0 * sigma)).abs() < 1e-12);
        assert!((opt.score(&q) - (mu - 2.0 * sigma)).abs() < 1e-12);
        assert!(pess.score(&q) >= opt.score(&q));
    }

    #[test]
    fn batch_scoring_is_bitwise_scalar() {
        let (xs, cis) = data();
        let mut ens = RbfEnsemble::new(8, 1.5);
        let mut rng = Rng::new(7);
        assert!(ens.fit(&xs, &cis, &mut rng));
        let qs: Vec<Vec<f64>> = (0..25)
            .map(|_| vec![rng.f64(), rng.f64()])
            .collect();
        let mut ws = Workspace::new();
        let (mut mu, mut sd, mut sc) =
            (Vec::new(), Vec::new(), Vec::new());
        ens.mean_std_batch(&qs, &mut ws, &mut mu, &mut sd);
        ens.score_batch(&qs, &mut ws, &mut sc);
        let (mut tmu, mut tsd) = (Vec::new(), Vec::new());
        ens.predict_batch(&qs, &mut ws, &mut tmu);
        assert!(ens.predict_std_batch(&qs, &mut ws, &mut tsd));
        for (i, q) in qs.iter().enumerate() {
            let (m, s) = ens.mean_std(q);
            assert_eq!(mu[i].to_bits(), m.to_bits(), "mean at {i}");
            assert_eq!(sd[i].to_bits(), s.to_bits(), "std at {i}");
            assert_eq!(
                sc[i].to_bits(),
                ens.score(q).to_bits(),
                "score at {i}"
            );
            assert_eq!(tmu[i].to_bits(), m.to_bits(), "trait mean {i}");
            assert_eq!(tsd[i].to_bits(), s.to_bits(), "trait std {i}");
        }
    }

    #[test]
    #[should_panic(expected = "alpha")]
    fn alpha_out_of_range_rejected() {
        let _ = RbfEnsemble::new(4, 3.0);
    }

    #[test]
    fn trait_impl_predicts_center_surface() {
        let (xs, cis) = data();
        let ys: Vec<f64> = cis.iter().map(|c| c.center).collect();
        let mut ens = RbfEnsemble::new(4, 0.0);
        assert!(Surrogate::fit(&mut ens, &xs, &ys));
        for (x, y) in xs.iter().zip(&ys) {
            assert!((ens.predict(x) - y).abs() < 1e-5);
        }
    }
}
